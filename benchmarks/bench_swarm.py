"""Stage-runtime benchmark: numeric swarm throughput + compile accounting.

Emits machine-readable ``artifacts/BENCH_swarm.json`` so the perf
trajectory (throughput, step time, compile/retrace counts, host wire
bytes) is tracked across PRs — CI uploads it as an artifact.

Three headline invariants:

* **shared compile cache** — on a 4-peer / 2-stage numeric run the
  runtime produces **one jit per (stage, kind)**: at least 2x fewer
  stage compiles than the per-peer re-tracing baseline of ``peers x
  stages`` (4 vs 8 here; the gap widens linearly with swarm size), and a
  second same-shape runner re-traces nothing;
* **span fusion** — the same workload served by span peers
  (``PipelineExecutor``, stages [0, 2) fused per peer, learned
  bottleneck codec on) reaches the SAME loss trajectory while moving
  strictly fewer boundary bytes through the host (zero, for whole-pipe
  spans), compiling exactly once per (span, kind, codec), with zero
  re-traces on a second runner;
* **async tick** — the same workload with in-flight boundary transfers
  and a bounded-staleness All-Reduce window (``overlap=True``,
  ``staleness=1``) is at least as fast as the blocking tick, with a
  nonzero fraction of wire time hidden behind compute;
* **kernel-backed hot path** — the codec workload re-run with
  ``cfg.kernels="pallas"`` (fused flash / rmsnorm / boundary-codec
  kernels) reaches the SAME loss trajectory at no lower simulated
  throughput with zero extra re-traces, and its per-kernel roofline
  numbers agree with ``benchmarks.roofline``'s cost model; the fused
  wire-quantized crossing (``cfg.wire_quant``) moves strictly fewer
  boundary bytes;
* **heterogeneous stages** — a mixed attention+SSM 4-stage swarm
  (``StagePlan``-driven per-kind stage runs) compiles one jit per
  (stage, kind, shapes) with zero re-traces on a second runner, and its
  throughput / wire bytes land in the JSON record under ``"hetero"``.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import SwarmRunner, SwarmConfig
from repro.models.config import ArchConfig, SSMConfig
from repro.optim import adamw
from repro.runtime import PipelineExecutor, compile_stats, \
    reset_compile_stats

PEERS_PER_STAGE, N_STAGES, STEPS = 2, 2, 2       # 4 peers, 2 stages

CFG = ArchConfig(name="bench-swarm-tiny", family="dense", n_layers=4,
                 d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab_size=256, head_dim=16, compute_dtype="float32",
                 param_dtype="float32")
# span comparison runs with the learned codec on (the acceptance bar:
# fewer host bytes at equal loss, codec active)
CFG_CODEC = CFG.with_overrides(name="bench-swarm-tiny-codec",
                               boundary_compression="bottleneck",
                               bottleneck_dim=16)
# kernel-backed hot path: same codec workload with cfg.kernels="pallas"
# (fused flash/rmsnorm/boundary kernels; equal-loss regression gate),
# plus the fused wire-quantized crossing (cfg.wire_quant)
CFG_PALLAS = CFG_CODEC.with_overrides(name="bench-swarm-tiny-pallas",
                                      kernels="pallas")
CFG_WIREQ = CFG_PALLAS.with_overrides(name="bench-swarm-tiny-wireq",
                                      wire_quant=True)
# mixed-kind pipeline: one layer per stage -> attn, attn, mamba, mamba
N_STAGES_HETERO = 4
CFG_HETERO = CFG.with_overrides(
    name="bench-swarm-hetero",
    block_pattern=("attn", "attn", "mamba", "mamba"),
    ssm=SSMConfig(state_dim=8, chunk=16))


def _scfg(codec, **kw) -> SwarmConfig:
    return SwarmConfig(n_stages=N_STAGES, microbatch_size=2, seq_len=32,
                       global_batch=8, n_trainers=3, rebalance_period=0.0,
                       codec=codec, max_steps=STEPS, **kw)


def _run_numeric(seed: int) -> tuple[SwarmRunner, float]:
    r = SwarmRunner(CFG, _scfg("none"), adamw(lr=1e-2), numeric=True,
                    seed=seed)
    r.build(peers_per_stage=PEERS_PER_STAGE)
    t0 = time.perf_counter()
    r.run(until=1e6)
    return r, time.perf_counter() - t0


def _run_async(seed: int) -> tuple[SwarmRunner, float]:
    """Same 4-peer/2-stage workload with the async tick on: in-flight
    boundary transfers (overlap) plus a bounded-staleness All-Reduce
    window (staleness=1 => DPU numerics inside the runner)."""
    r = SwarmRunner(CFG, _scfg("none", overlap=True, staleness=1),
                    adamw(lr=1e-2), numeric=True, seed=seed)
    r.build(peers_per_stage=PEERS_PER_STAGE)
    t0 = time.perf_counter()
    r.run(until=1e6)
    return r, time.perf_counter() - t0


def _run_codec(seed: int, span: bool) -> tuple[SwarmRunner, float]:
    """Same workload, codec on: all-single-stage peers vs all peers
    serving stages [0, 2) fused (span=True)."""
    r = SwarmRunner(CFG_CODEC, _scfg("bottleneck"), adamw(lr=1e-2),
                    numeric=True, seed=seed)
    if span:
        for _ in range(PEERS_PER_STAGE):
            r.add_peer(range(0, N_STAGES), executor=PipelineExecutor(
                CFG_CODEC, N_STAGES, 32, (0, N_STAGES),
                compress="bottleneck"))
        r.build(peers_per_stage=0)
    else:
        r.build(peers_per_stage=PEERS_PER_STAGE)
    t0 = time.perf_counter()
    r.run(until=1e6)
    return r, time.perf_counter() - t0


def _run_kernels(cfg: ArchConfig, seed: int) -> tuple[SwarmRunner, float]:
    """The CFG_CODEC workload with the Pallas hot path on (same seed and
    sample order as the jnp run, so losses must track)."""
    r = SwarmRunner(cfg, _scfg("bottleneck"), adamw(lr=1e-2),
                    numeric=True, seed=seed)
    r.build(peers_per_stage=PEERS_PER_STAGE)
    t0 = time.perf_counter()
    r.run(until=1e6)
    return r, time.perf_counter() - t0


def _kernel_rooflines() -> dict:
    """Analytic roofline terms for the hot-path kernels at THIS bench's
    shapes, derived via the same helper (and cost-model constants) as
    ``benchmarks.bench_kernels`` — cross-checked against
    ``benchmarks.roofline`` in the asserts below."""
    from benchmarks.bench_kernels import kernel_roofline
    B, S = 2, 32                                     # microbatch shape
    d, hd, H = CFG.d_model, CFG.head_dim, CFG.n_heads
    c = CFG_CODEC.bottleneck_dim
    T = B * S
    return {
        "flash_fwd": kernel_roofline(
            0.5 * 4.0 * B * H * S * S * hd,
            4 * (3 * T * H * hd + T * H * hd)),
        "rmsnorm": kernel_roofline(4.0 * T * d, 4 * (2 * T * d + d)),
        "encode_quantize[bottleneck]": kernel_roofline(
            2.0 * T * d * c + 10.0 * T * d,
            4 * T * d + 4 * d * c + T * c + 4 * (T * c // 16)),
    }


def _run_hetero(seed: int) -> tuple[SwarmRunner, float]:
    """Mixed attention+SSM pipeline, one layer per stage over 4 stages
    (plan runs: attn | attn | mamba | mamba), 2 peers per stage."""
    r = SwarmRunner(CFG_HETERO,
                    SwarmConfig(n_stages=N_STAGES_HETERO,
                                microbatch_size=2, seq_len=32,
                                global_batch=8, n_trainers=3,
                                rebalance_period=0.0, codec="none",
                                max_steps=STEPS),
                    adamw(lr=1e-2), numeric=True, seed=seed)
    r.build(peers_per_stage=PEERS_PER_STAGE)
    t0 = time.perf_counter()
    r.run(until=1e6)
    return r, time.perf_counter() - t0


def _span_trace_keys(stats: dict) -> dict:
    """per_key entries belonging to fused span programs (their stage slot
    is a (lo, hi) tuple rather than an int)."""
    return {k: v for k, v in stats["per_key"].items()
            if any(isinstance(e, tuple) and len(e) == 2
                   and all(isinstance(x, int) for x in e) for e in k[4:5])}


def run(csv=True, out_path: str = "artifacts/BENCH_swarm.json"):
    print("# stage-runtime: shared compile cache + swarm throughput")
    print("name,us_per_call,derived")
    reset_compile_stats()
    r1, wall1 = _run_numeric(seed=0)
    first = compile_stats()
    r2, wall2 = _run_numeric(seed=1)         # same shapes: cache hits only
    second = compile_stats()

    # ---- sync vs async tick (same shapes => reuses the warm cache)
    ra, wall_async = _run_async(seed=0)

    # ---- span vs single, codec on, same seed => same trajectory
    reset_compile_stats()
    rs_single, wall_single = _run_codec(seed=0, span=False)
    single_stats = compile_stats()
    rs_span, wall_span = _run_codec(seed=0, span=True)
    span_stats = compile_stats()
    span_keys = _span_trace_keys(span_stats)
    rs_span2, _ = _run_codec(seed=1, span=True)   # warm span cache
    span_stats2 = compile_stats()

    # ---- heterogeneous stage kinds (StagePlan-driven per-kind runs)
    reset_compile_stats()
    rh, wall_h = _run_hetero(seed=0)
    hetero_first = compile_stats()
    _run_hetero(seed=1)                  # same shapes: cache hits only
    hetero_second = compile_stats()

    # ---- kernel-backed hot path (pallas vs jnp at equal loss)
    reset_compile_stats()
    rk, wall_k = _run_kernels(CFG_PALLAS, seed=0)
    kernels_first = compile_stats()
    _run_kernels(CFG_PALLAS, seed=1)     # same shapes: cache hits only
    kernels_second = compile_stats()
    rq, _ = _run_kernels(CFG_WIREQ, seed=0)   # fused wire-QDQ crossing

    peers = PEERS_PER_STAGE * N_STAGES
    naive = peers * N_STAGES                 # per-peer re-trace baseline
    steps = r1.metrics["step_time"]
    mean_step = sum(steps) / max(len(steps), 1)
    steps_async = ra.metrics["step_time"]
    mean_step_async = sum(steps_async) / max(len(steps_async), 1)
    report = {
        "bench": "swarm_runtime",
        "config": {"peers": peers, "stages": N_STAGES, "steps": STEPS,
                   "microbatch_size": 2, "global_batch": 8,
                   "seq_len": 32, "model": CFG.name},
        "throughput_samples_per_s_sim": r1.throughput(),
        "mean_step_time_s_sim": mean_step,
        "wall_s_first_run": wall1,
        "wall_s_second_run": wall2,          # warm cache: no re-tracing
        "recomputed_microbatches": r1.metrics["recomputed_microbatches"],
        "compiles": {
            "total_first_run": first["traces"],
            "total_after_second_run": second["traces"],
            "peers_times_stages": naive,
            "per_key": {" ".join(map(str, k)): v
                        for k, v in sorted(first["per_key"].items())},
        },
        # sync vs async tick (ISSUE 7: in-flight boundary transfers +
        # bounded-staleness All-Reduce must not cost throughput):
        "async": {
            "overlap": True,
            "staleness": 1,
            "sync_throughput_sim": r1.throughput(),
            "async_throughput_sim": ra.throughput(),
            "sync_mean_step_s_sim": mean_step,
            "async_mean_step_s_sim": mean_step_async,
            "overlap_fraction": ra.metrics["overlap_fraction"],
            "inflight_bytes": ra.metrics["inflight_bytes"],
            "wall_s": wall_async,
        },
        # span-vs-single (codec on, identical seed/sample order):
        "span": {
            "model": CFG_CODEC.name,
            "span": [0, N_STAGES],
            "single_loss": rs_single.metrics["loss"],
            "span_loss": rs_span.metrics["loss"],
            "single_wire_bytes": rs_single.metrics["wire_bytes"],
            "span_wire_bytes": rs_span.metrics["wire_bytes"],
            "single_throughput_sim": rs_single.throughput(),
            "span_throughput_sim": rs_span.throughput(),
            "span_compiles": {" ".join(map(str, k)): v
                              for k, v in sorted(span_keys.items())},
            "span_compiles_after_second_runner":
                sum(_span_trace_keys(span_stats2).values()),
        },
        # kernel-backed hot path (ISSUE 9: pallas throughput >= jnp at
        # equal loss, zero extra re-traces, roofline cross-check):
        "kernels": {
            "model": CFG_PALLAS.name,
            "jnp_loss": rs_single.metrics["loss"],
            "pallas_loss": rk.metrics["loss"],
            "jnp_throughput_sim": rs_single.throughput(),
            "pallas_throughput_sim": rk.throughput(),
            "jnp_wire_bytes": rs_single.metrics["wire_bytes"],
            "pallas_wire_bytes": rk.metrics["wire_bytes"],
            "compiles_first_run": kernels_first["traces"],
            "compiles_after_second_run": kernels_second["traces"],
            "wall_s": wall_k,
            "wire_quant": {
                "model": CFG_WIREQ.name,
                "loss": rq.metrics["loss"],
                "wire_bytes": rq.metrics["wire_bytes"],
                "throughput_sim": rq.throughput(),
            },
            "roofline": _kernel_rooflines(),
        },
        # mixed attention+SSM 4-stage swarm (the StagePlan workload):
        "hetero": {
            "model": CFG_HETERO.name,
            "stages": N_STAGES_HETERO,
            "block_pattern": list(CFG_HETERO.block_kinds),
            "throughput_samples_per_s_sim": rh.throughput(),
            "loss": rh.metrics["loss"],
            "wire_bytes": rh.metrics["wire_bytes"],
            "compiles_first_run": hetero_first["traces"],
            "compiles_after_second_run": hetero_second["traces"],
            "per_key": {" ".join(map(str, k)): v
                        for k, v in sorted(
                            hetero_first["per_key"].items())},
            "wall_s": wall_h,
        },
    }
    # write the record FIRST: a regression must still leave the artifact
    # behind for diagnosis (CI uploads it with `if: always()`)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    assert first["traces"] * 2 <= naive, (
        f"shared compile cache regressed: {first['traces']} stage compiles "
        f"on a {peers}-peer/{N_STAGES}-stage run (need <= {naive // 2})")
    assert second["traces"] == first["traces"], (
        "second same-shape runner re-traced: "
        f"{second['traces']} vs {first['traces']}")

    # ---- async invariants (the ISSUE 7 acceptance bar): overlapping
    # the wire with compute must never cost throughput, and the run must
    # actually have put boundary bytes in flight
    asy = report["async"]
    assert asy["async_throughput_sim"] >= asy["sync_throughput_sim"], (
        "async tick slower than sync on the 4-peer/2-stage run: "
        f"{asy['async_throughput_sim']:.2f} vs "
        f"{asy['sync_throughput_sim']:.2f} samples/s")
    assert asy["overlap_fraction"] > 0, (
        "async run hid no wire time behind compute: "
        f"overlap_fraction={asy['overlap_fraction']}")

    # ---- span invariants (the ISSUE 5 acceptance bar)
    sp = report["span"]
    assert len(sp["span_loss"]) == STEPS and len(sp["single_loss"]) == STEPS
    for a, b in zip(sp["span_loss"], sp["single_loss"]):
        assert abs(a - b) < 2e-4, (
            f"span trajectory diverged from single-stage: {a} vs {b}")
    assert sp["span_wire_bytes"] < sp["single_wire_bytes"], (
        "span run did not reduce host boundary bytes: "
        f"{sp['span_wire_bytes']} vs {sp['single_wire_bytes']}")
    assert span_keys and all(v == 1 for v in span_keys.values()), (
        f"span program compiled more than once per (span, kind, shapes): "
        f"{span_keys}")
    assert sum(_span_trace_keys(span_stats2).values()) == \
        sum(span_keys.values()), "second span runner re-traced"

    print(f"swarm/compiles,0,first={first['traces']} naive={naive} "
          f"second_run_new=0")
    print(f"swarm/throughput,0,sim={r1.throughput():.2f}/s "
          f"mean_step={mean_step:.3f}s wall1={wall1:.1f}s "
          f"wall2={wall2:.1f}s")
    print(f"swarm/async,0,sim={asy['async_throughput_sim']:.2f}/s vs "
          f"{asy['sync_throughput_sim']:.2f}/s sync; overlap_fraction="
          f"{asy['overlap_fraction']:.2f} "
          f"inflight={asy['inflight_bytes'] / 1e6:.1f}MB staleness=1")
    # ---- hetero invariants: one jit per (stage, kind, shapes), zero
    # re-traces for the second same-shape mixed-kind runner
    het = report["hetero"]
    assert all(v == 1 for v in hetero_first["per_key"].values()), (
        f"mixed-kind stage re-traced within one run: "
        f"{hetero_first['per_key']}")
    assert het["compiles_after_second_run"] == \
        het["compiles_first_run"], (
        "second mixed-kind runner re-traced: "
        f"{het['compiles_after_second_run']} vs "
        f"{het['compiles_first_run']}")

    # ---- kernel-path invariants (ISSUE 9 acceptance bar): the pallas
    # hot path must cost nothing — same loss trajectory (the kernels
    # share every oracle's math), throughput at least the jnp path's
    # (the analytic cost model prices the fused path no higher), zero
    # re-traces for a second same-shape runner, and per-kernel roofline
    # numbers that agree with benchmarks.roofline's cost model.
    kn = report["kernels"]
    assert len(kn["pallas_loss"]) == STEPS
    for a, b in zip(kn["pallas_loss"], kn["jnp_loss"]):
        assert abs(a - b) < 1e-4, (
            f"pallas trajectory diverged from jnp at equal config: "
            f"{a} vs {b}")
    assert kn["pallas_throughput_sim"] >= kn["jnp_throughput_sim"], (
        "pallas path slower than jnp in the cost model: "
        f"{kn['pallas_throughput_sim']:.2f} vs "
        f"{kn['jnp_throughput_sim']:.2f} samples/s")
    assert kn["compiles_after_second_run"] == kn["compiles_first_run"], (
        "second pallas runner re-traced: "
        f"{kn['compiles_after_second_run']} vs "
        f"{kn['compiles_first_run']}")
    from benchmarks import roofline as _rl
    for name, r in kn["roofline"].items():
        assert abs(r["t_compute_s"] - r["flops"] / _rl.PEAK_FLOPS) < 1e-18
        assert abs(r["t_memory_s"] - r["bytes"] / _rl.HBM_BW) < 1e-18, (
            f"{name}: roofline terms disagree with benchmarks.roofline")
    wq = kn["wire_quant"]
    assert wq["wire_bytes"] < kn["jnp_wire_bytes"], (
        "wire-quantized crossing moved no fewer bytes: "
        f"{wq['wire_bytes']} vs {kn['jnp_wire_bytes']}")
    assert wq["throughput_sim"] >= kn["jnp_throughput_sim"], (
        "wire-quantized crossing cost throughput: "
        f"{wq['throughput_sim']:.2f} vs "
        f"{kn['jnp_throughput_sim']:.2f} samples/s")

    print(f"swarm/kernels,0,pallas={kn['pallas_throughput_sim']:.2f}/s vs "
          f"{kn['jnp_throughput_sim']:.2f}/s jnp; loss equal at 1e-4; "
          f"second_run_new=0; wire_quant bytes "
          f"{wq['wire_bytes']:.0f} vs {kn['jnp_wire_bytes']:.0f}")
    print(f"swarm/span,0,wire_bytes {sp['span_wire_bytes']:.0f} vs "
          f"{sp['single_wire_bytes']:.0f} single; span compiles "
          f"{sum(span_keys.values())} (1 per (span,kind)); loss equal "
          f"at 2e-4")
    print(f"swarm/hetero,0,sim={het['throughput_samples_per_s_sim']:.2f}/s "
          f"pattern={'|'.join(het['block_pattern'])} "
          f"wire={het['wire_bytes'] / 1e6:.1f}MB "
          f"compiles={het['compiles_first_run']} second_run_new=0")
    print(f"swarm/json,0,{out_path}")
    return report


if __name__ == "__main__":
    run()
