"""Stage-runtime benchmark: numeric swarm throughput + compile accounting.

Emits machine-readable ``artifacts/BENCH_swarm.json`` so the perf
trajectory (throughput, step time, compile/retrace counts) is tracked
across PRs — CI uploads it as an artifact.

The headline invariant: on a 4-peer / 2-stage numeric run the shared
compile cache of ``repro.runtime`` produces **one jit per (stage, kind)**
— at least 2x fewer stage compiles than the per-peer re-tracing baseline
of ``peers x stages`` (it is 4 vs 8 here, and the gap widens linearly
with swarm size).  A second same-shape runner re-traces nothing.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import SwarmRunner, SwarmConfig
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.runtime import compile_stats, reset_compile_stats

PEERS_PER_STAGE, N_STAGES, STEPS = 2, 2, 2       # 4 peers, 2 stages

CFG = ArchConfig(name="bench-swarm-tiny", family="dense", n_layers=4,
                 d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab_size=256, head_dim=16, compute_dtype="float32",
                 param_dtype="float32")


def _run_numeric(seed: int) -> tuple[SwarmRunner, float]:
    scfg = SwarmConfig(n_stages=N_STAGES, microbatch_size=2, seq_len=32,
                       global_batch=8, n_trainers=3, rebalance_period=0.0,
                       compress=False, max_steps=STEPS)
    r = SwarmRunner(CFG, scfg, adamw(lr=1e-2), numeric=True, seed=seed)
    r.build(peers_per_stage=PEERS_PER_STAGE)
    t0 = time.perf_counter()
    r.run(until=1e6)
    return r, time.perf_counter() - t0


def run(csv=True, out_path: str = "artifacts/BENCH_swarm.json"):
    print("# stage-runtime: shared compile cache + swarm throughput")
    print("name,us_per_call,derived")
    reset_compile_stats()
    r1, wall1 = _run_numeric(seed=0)
    first = compile_stats()
    r2, wall2 = _run_numeric(seed=1)         # same shapes: cache hits only
    second = compile_stats()

    peers = PEERS_PER_STAGE * N_STAGES
    naive = peers * N_STAGES                 # per-peer re-trace baseline
    steps = r1.metrics["step_time"]
    mean_step = sum(steps) / max(len(steps), 1)
    report = {
        "bench": "swarm_runtime",
        "config": {"peers": peers, "stages": N_STAGES, "steps": STEPS,
                   "microbatch_size": 2, "global_batch": 8,
                   "seq_len": 32, "model": CFG.name},
        "throughput_samples_per_s_sim": r1.throughput(),
        "mean_step_time_s_sim": mean_step,
        "wall_s_first_run": wall1,
        "wall_s_second_run": wall2,          # warm cache: no re-tracing
        "recomputed_microbatches": r1.metrics["recomputed_microbatches"],
        "compiles": {
            "total_first_run": first["traces"],
            "total_after_second_run": second["traces"],
            "peers_times_stages": naive,
            "per_key": {" ".join(map(str, k)): v
                        for k, v in sorted(first["per_key"].items())},
        },
    }
    # write the record FIRST: a regression must still leave the artifact
    # behind for diagnosis (CI uploads it with `if: always()`)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    assert first["traces"] * 2 <= naive, (
        f"shared compile cache regressed: {first['traces']} stage compiles "
        f"on a {peers}-peer/{N_STAGES}-stage run (need <= {naive // 2})")
    assert second["traces"] == first["traces"], (
        "second same-shape runner re-traced: "
        f"{second['traces']} vs {first['traces']}")
    print(f"swarm/compiles,0,first={first['traces']} naive={naive} "
          f"second_run_new=0")
    print(f"swarm/throughput,0,sim={r1.throughput():.2f}/s "
          f"mean_step={mean_step:.3f}s wall1={wall1:.1f}s "
          f"wall2={wall2:.1f}s")
    print(f"swarm/json,0,{out_path}")
    return report


if __name__ == "__main__":
    run()
