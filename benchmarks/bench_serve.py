"""Serving benchmark: tokens/s + latency percentiles, with and without
churn, through :class:`repro.serve.ServeRunner`.

Emits machine-readable ``artifacts/BENCH_serve.json`` so the serving
trajectory (throughput, p50/p99 latency, recovery work) is tracked
across PRs — CI uploads it as an artifact.

Three headline invariants, asserted here:

* **token-for-token** — the staged swarm's greedy outputs equal the
  single-process reference (``full_session_program``) in BOTH runs:
  span hand-offs, continuous batching, and churn recovery are
  numerically invisible;
* **exactly-once KV** — killing a decode-span peer mid-generation
  re-prefills exactly the dead span's stages (the strict
  :class:`~repro.core.ledger.SessionKVLedger` turns any double-prefill
  into a hard error, so a green run IS the proof);
* **no request lost** — every request completes under the churn trace.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.models.config import ArchConfig
from repro.serve import ServeConfig, ServeRunner
from repro.serve.runner import reference_generate

CFG = ArchConfig(name="bench-serve-tiny", family="dense", n_layers=4,
                 d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab_size=256, head_dim=16, compute_dtype="float32",
                 param_dtype="float32")
N_STAGES = 4


def _requests(n: int, prompt_len: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, size=(n, prompt_len),
                        dtype=np.int64)


def _no_churn(prompts, new_tokens: int) -> tuple[dict, np.ndarray]:
    """Disaggregated pools (2 narrow prefill + 2 wide decode peers)."""
    scfg = ServeConfig(n_stages=N_STAGES, max_batch=2, max_sessions=2)
    r = ServeRunner(CFG, scfg, seed=0)
    layout = r.build_pools(n_prefill=2, n_decode=2)
    reqs = [r.submit(p, new_tokens) for p in prompts]
    summary = r.run()
    summary["layout"] = layout
    return summary, np.stack([q.tokens for q in reqs]), r.params


def _churn(prompts, new_tokens: int, t_kill: float,
           t_revive: float) -> tuple[dict, np.ndarray]:
    """4-peer decode-only span swarm; one span peer dies mid-decode and
    later revives (cold: its KV re-prefills on next touch)."""
    scfg = ServeConfig(n_stages=N_STAGES, max_batch=2, max_sessions=1)
    r = ServeRunner(CFG, scfg, seed=0)
    for name, span in (("d0a", (0, 2)), ("d1a", (2, 4)),
                       ("d0b", (0, 2)), ("d1b", (2, 4))):
        r.add_peer(span, pool="decode", name=name)
    reqs = [r.submit(p, new_tokens) for p in prompts]
    r.schedule_fail(t_kill, "d1a")
    r.schedule_revive(t_revive, "d1a")
    summary = r.run()
    return summary, np.stack([q.tokens for q in reqs])


def run(csv: bool = True, out_path: str = "artifacts/BENCH_serve.json",
        smoke: bool = False) -> dict:
    n_req, prompt_len, new_tokens = (4, 8, 6) if smoke else (8, 16, 12)
    prompts = _requests(n_req, prompt_len)

    t0 = time.perf_counter()
    plain, got_plain, params = _no_churn(prompts, new_tokens)
    wall_plain = time.perf_counter() - t0

    t0 = time.perf_counter()
    churn, got_churn = _churn(prompts, new_tokens, t_kill=0.045,
                              t_revive=0.25)
    wall_churn = time.perf_counter() - t0

    ref = reference_generate(CFG, params, prompts, new_tokens)
    plain["match_reference"] = bool(np.array_equal(got_plain, ref))
    churn["match_reference"] = bool(np.array_equal(got_churn, ref))

    assert plain["match_reference"], "disaggregated serve != reference"
    assert churn["match_reference"], "churn serve != reference"
    assert plain["failed"] == 0 and churn["failed"] == 0
    assert churn["reprefills"] >= 1, "churn trace never exercised recovery"
    assert churn["reprefilled_stages"] == 2 * churn["reprefills"], \
        "recovery touched stages outside the dead (2, 4) span"

    report = {
        "bench": "serve",
        "config": {"model": CFG.name, "stages": N_STAGES,
                   "requests": n_req, "prompt_len": prompt_len,
                   "new_tokens": new_tokens, "smoke": smoke},
        "no_churn": plain,
        "churn": churn,
        "wall_s": {"no_churn": wall_plain, "churn": wall_churn},
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    if csv:
        print("name,us_per_call,derived")
        print(f"serve_tokens_per_s,,{plain['tokens_per_s']:.1f}")
        print(f"serve_p99_latency_s,,{plain['p99_latency_s']:.4f}")
        print(f"serve_churn_tokens_per_s,,{churn['tokens_per_s']:.1f}")
        print(f"serve_churn_p99_latency_s,,{churn['p99_latency_s']:.4f}")
        print(f"serve_churn_reprefilled_stages,,"
              f"{churn['reprefilled_stages']}")
        print(f"serve_match_reference,,"
              f"{plain['match_reference'] and churn['match_reference']}")
        print(f"# wrote {out_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the CI fast lane")
    ap.add_argument("--out", default="artifacts/BENCH_serve.json")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke)
