"""Paper Table 2: SWARM vs GPipe vs 1F1B vs ZeRO-Offload — training
throughput and All-Reduce time, 'xxlarge' and 'GPT-3' 4-layer stacks on 16
V100 workers at 500 Mb/s, with and without injected latency.

Calibration note: Tables 1 and 2 of the paper imply mutually inconsistent
effective per-GPU throughputs (§4.1's idle-time measurements put xxlarge
compute ~7x faster than §4.2's absolute samples/s would allow), so absolute
samples/s are not recoverable from the text.  We therefore use ONE
calibration — the square-cube efficiency curve fit to Table 1 — and report
the quantity the paper actually argues about: SWARM's throughput RELATIVE
to GPipe/1F1B/ZeRO-Offload, plus absolute All-Reduce seconds, which our
fp32-payload @ 27 MB/s model reproduces to within ~10% for both model
sizes (44.17 s and 403 s).
"""
from __future__ import annotations

import time

from repro.core import SwarmRunner, SwarmConfig
from repro.core.peer import DeviceProfile, MBPS
from repro.models.config import ArchConfig
from repro.models import flops as F
from repro.optim import adamw

# §4.2: "the pipeline does not contain embeddings or language modeling
# heads" — vocab is set to a token 2 so the head contributes nothing;
# standard (GELU, 2-matmul) FFN as in the paper's TransformerEncoderLayer.
XXLARGE = ArchConfig(name="xxlarge4", family="dense", n_layers=4,
                     d_model=4096, n_heads=32, n_kv_heads=32, d_ff=16384,
                     vocab_size=2, act="gelu", tie_embeddings=True)
GPT3 = ArchConfig(name="gpt3-4", family="dense", n_layers=4,
                  d_model=12288, n_heads=96, n_kv_heads=96, d_ff=49152,
                  vocab_size=2, act="gelu", tie_embeddings=True)

from repro.core import square_cube as sc


def _eff(d_model: int) -> float:
    return sc.PEAK_FLOPS * sc.matmul_efficiency(d_model)


AR_BW = 27e6                  # all-reduce effective bytes/s (fit: Table 2)
PCIE_BW = 4e9                 # pinned-memory PCIe streaming (fit)
OFFLOAD_SLOWDOWN = 1.15       # optimizer-offload stall factor (fit)

PAPER = {  # (throughput, allreduce_nolat, allreduce_lat)
    ("xxlarge", "SWARM"): (2.358, 45.36, 51.27),
    ("xxlarge", "GPipe"): (2.541, 44.17, 64.83),
    ("xxlarge", "1F1B"): (2.550, 44.17, 64.83),
    ("xxlarge", "Offload"): (3.08, 168.71, 252.26),
    ("GPT-3", "SWARM"): (0.619, 441.7, 455.4),
    ("GPT-3", "GPipe"): (0.633, 403.0, 469.6),
    ("GPT-3", "1F1B"): (0.638, 403.0, 469.6),
    ("GPT-3", "Offload"): (0.382, 1527.9, 1635.4),
}


def _sample_flops(cfg):
    ctx = F._ctx_for(cfg, 512, causal_avg=True)
    return 3 * sum(F.per_token_layer_flops(cfg, k, ctx)
                   for k in cfg.block_kinds) * 512


def _layer_params(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return cfg.n_layers * (4 * d * d + 2 * d * f)


def _allreduce(cfg, k, n_stages, latency):
    grad_bytes = 4.0 * _layer_params(cfg) / n_stages      # fp32
    return 2 * (k - 1) / k * grad_bytes / AR_BW + 2 * k * latency


def _swarm(cfg, micro, latency):
    prof = DeviceProfile("V100c", _eff(cfg.d_model), 500 * MBPS,
                         500 * MBPS, 0.003 + latency)
    scfg = SwarmConfig(n_stages=4, microbatch_size=micro, seq_len=512,
                       global_batch=10 ** 9, n_trainers=128,
                       rebalance_period=0.0, codec="none")
    r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=0,
                    profile_fn=lambda i: prof)
    r.build(peers_per_stage=4)
    r.run(until=400.0)
    return r.throughput()


def _gpipe(cfg, micro, latency, n_mb=32):
    """Synchronous pipeline with exposed (blocking) transfers + bubble."""
    t_c = _sample_flops(cfg) / 4 * micro / _eff(cfg.d_model)
    nbytes = micro * 512 * cfg.d_model * 2
    t_n = 2 * (nbytes / (500 * MBPS) + 0.003 + latency)
    t_batch = (n_mb + 3) * (t_c + t_n)
    return 4 * n_mb * micro / t_batch


def _offload(cfg, micro, latency):
    t = _sample_flops(cfg) * micro / _eff(cfg.d_model) * OFFLOAD_SLOWDOWN
    param_bytes = 2.0 * F.total_params(cfg)
    if param_bytes > 12e9:                               # exceeds V100 HBM
        t += 2 * param_bytes / PCIE_BW
    return 16 * micro / t


def run(csv=True):
    print("# SWARM vs baselines (paper Table 2)")
    print("name,us_per_call,derived")
    for cfg, tag, micro in ((XXLARGE, "xxlarge", 4), (GPT3, "GPT-3", 1)):
        for latency, ltag, pidx in ((0.0, "nolat", 1), (0.075, "lat", 2)):
            rows = []
            t0 = time.perf_counter()
            thr = _swarm(cfg, micro, latency)
            rows.append(("SWARM", thr, _allreduce(cfg, 4, 4, latency)))
            g = _gpipe(cfg, micro, latency)
            rows.append(("GPipe", g, _allreduce(cfg, 4, 4, latency)))
            rows.append(("1F1B", g, _allreduce(cfg, 4, 4, latency)))
            rows.append(("Offload", _offload(cfg, micro, latency),
                         _allreduce(cfg, 16, 1, latency)))
            dt = (time.perf_counter() - t0) * 1e6 / 4
            swarm_thr = rows[0][1]
            for name, thr, ar in rows:
                p = PAPER[(tag, name)]
                rel = thr / swarm_thr
                prel = p[0] / PAPER[(tag, "SWARM")][0]
                print(f"throughput/{tag}/{ltag}/{name},{dt:.0f},"
                      f"rel_to_swarm={rel:.2f} paper_rel={prel:.2f} "
                      f"sim_samples_s={thr:.3f} allreduce_s={ar:.1f} "
                      f"paper_allreduce={p[pidx]}")


if __name__ == "__main__":
    run()
