"""Paper Fig. 3 + Table 1: the square-cube law — GPU utilization vs model
size, bandwidth and latency, for the four §4.1 layer configurations."""
from __future__ import annotations

import time

from repro.core import square_cube as sc

# Paper Table 1 reference values (relative GPU utilization, 500 Mb/s)
PAPER_TABLE1 = {
    0.0: {"base": 0.180, "xxlarge": 0.321, "GPT-3": 0.821, "Ours": 0.895},
    0.010: {"base": 0.118, "xxlarge": 0.289, "GPT-3": 0.793, "Ours": 0.872},
    0.050: {"base": 0.0488, "xxlarge": 0.201, "GPT-3": 0.703, "Ours": 0.795},
    0.100: {"base": 0.0278, "xxlarge": 0.149, "GPT-3": 0.602, "Ours": 0.715},
    0.200: {"base": 0.0153, "xxlarge": 0.101, "GPT-3": 0.485, "Ours": 0.592},
}


def run(csv=True):
    rows = []
    t0 = time.perf_counter()
    for rtt, paper in PAPER_TABLE1.items():
        for spec in sc.ALL_SPECS:
            u = sc.utilization(spec, bandwidth_mbps=500.0, rtt_s=rtt)
            rows.append((spec.name, rtt, u, paper[spec.name]))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    ok_order = True
    for rtt in PAPER_TABLE1:
        us_ = [r[2] for r in rows if r[1] == rtt]
        ok_order &= us_ == sorted(us_)
    if csv:
        print("# square-cube law (paper Fig.3/Table 1)")
        print("name,us_per_call,derived")
        for name, rtt, u, pu in rows:
            print(f"square_cube/{name}/rtt{int(rtt*1000)}ms,{us:.2f},"
                  f"util={u:.3f} paper={pu:.3f}")
        print(f"square_cube/ordering_preserved,{us:.2f},{ok_order}")
        fe, ce = sc.scaling_exponents(sc.XXLARGE)
        print(f"square_cube/exponents,{us:.2f},"
              f"compute_exp={fe:.2f} comm_exp={ce:.2f}")
    return rows


if __name__ == "__main__":
    run()
