"""Per-kernel microbenchmarks: the Pallas hot path vs its jnp oracle.

For each kernel on the training hot path (flash attention forward,
rmsnorm, the blockwise-int8 wire round trip, and the fused
boundary-codec crossing) this measures samples/s for both backends,
records the analytic FLOPs / bytes moved, and derives roofline times
from ``benchmarks.roofline``'s cost-model constants — so the per-kernel
numbers and the whole-model roofline tables share one source of truth.

``bytes_moved`` counts HBM traffic for the FUSED launch; for the fused
boundary crossing ``bytes_twopass`` adds the intermediate wire tensor
the unfused encode->quantize sequence writes and re-reads — the traffic
the fusion removes.

On CPU the Pallas numbers run under the interpreter (orders of
magnitude slower — see ``repro.kernels.backend``); they are recorded
for trend tracking, never asserted faster.  Emits machine-readable
``artifacts/BENCH_kernels.json`` (CI uploads it with ``if: always()``).

    PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import roofline

DTYPE_BYTES = 4                                 # benchmarks run f32


def kernel_roofline(flops: float, bytes_moved: float) -> dict:
    """Roofline terms from the shared cost-model constants."""
    t_compute = flops / roofline.PEAK_FLOPS
    t_memory = bytes_moved / roofline.HBM_BW
    return {
        "flops": flops,
        "bytes": bytes_moved,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "intensity_flops_per_byte": flops / max(bytes_moved, 1.0),
        "bound": "memory" if t_memory >= t_compute else "compute",
    }


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))            # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _maxdiff(a, b) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32))))


def _record(name, tokens, t_jnp, t_pallas, diff, flops, bytes_moved,
            extra=None):
    rec = {
        "tokens": tokens,
        "jnp_s_per_call": t_jnp,
        "pallas_s_per_call": t_pallas,
        "jnp_samples_per_s": tokens / t_jnp,
        "pallas_samples_per_s": tokens / t_pallas,
        "max_abs_diff": diff,
        "roofline": kernel_roofline(flops, bytes_moved),
    }
    if extra:
        rec.update(extra)
    return rec


# ------------------------------------------------------------- kernels
def bench_flash(B, S, H, KV, hd, iters) -> dict:
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.models.flash import _flash_fwd_impl
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    scale = hd ** -0.5
    jfn = jax.jit(lambda q, k, v: _flash_fwd_impl(
        q, k, v, True, 0, 0, S, S, scale)[0])
    pfn = lambda q, k, v: flash_attention_fwd(q, k, v, True, 0, scale)
    t_j, t_p = _time(jfn, q, k, v, iters=iters), \
        _time(pfn, q, k, v, iters=iters)
    flops = 0.5 * 4.0 * B * H * S * S * hd        # causal: half the tiles
    bts = DTYPE_BYTES * (q.size + k.size + v.size + B * S * H * hd)
    return _record("flash_fwd", B * S, t_j, t_p,
                   _maxdiff(jfn(q, k, v), pfn(q, k, v)), flops, bts,
                   {"shape": [B, S, H, hd]})


def bench_rmsnorm(B, S, d, iters) -> dict:
    from repro.kernels.rmsnorm.kernel import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jax.random.normal(jax.random.PRNGKey(0), (B * S, d), jnp.float32)
    scale = jnp.ones((d,), jnp.float32)
    jfn = jax.jit(rmsnorm_ref)
    pfn = rmsnorm
    t_j, t_p = _time(jfn, x, scale, iters=iters), \
        _time(pfn, x, scale, iters=iters)
    flops = 4.0 * x.size
    bts = DTYPE_BYTES * (2 * x.size + d)
    return _record("rmsnorm", B * S, t_j, t_p,
                   _maxdiff(jfn(x, scale), pfn(x, scale)), flops, bts,
                   {"shape": [B * S, d]})


def bench_int8_roundtrip(B, S, d, iters) -> dict:
    from repro.compression.quant8 import _roundtrip, BLOCK
    from repro.kernels.boundary.kernel import qdq_flat
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, d), jnp.float32)
    jfn = jax.jit(lambda x: _roundtrip(x, BLOCK))
    pfn = jax.jit(lambda x: qdq_flat(x, BLOCK))
    t_j, t_p = _time(jfn, x, iters=iters), _time(pfn, x, iters=iters)
    flops = 6.0 * x.size
    bts = DTYPE_BYTES * 2 * x.size
    return _record("int8_roundtrip", B * S, t_j, t_p,
                   _maxdiff(jfn(x), pfn(x)), flops, bts,
                   {"shape": [B, S, d], "block": BLOCK})


def bench_boundary(mode, B, S, d, c, iters) -> dict:
    """The fused crossing: encode(+QDQ) on the sender, dequantize+decode
    on the receiver, vs the two-pass jnp sequence."""
    from repro.kernels.boundary import kernel as K
    from repro.kernels.boundary import ref as R
    k = d // c if mode == "maxout" else 1
    qb = R.wire_qblock(c)
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, d), jnp.float32)
    w_c = (jax.random.normal(jax.random.PRNGKey(1), (d, c)) * 0.2
           if mode == "bottleneck" else None)
    w_d = jax.random.normal(jax.random.PRNGKey(2), (c, d)) * 0.2
    T = B * S

    jenc = jax.jit(lambda x: R.encode_quantize_ref(x, w_c, mode, k, qb))
    penc = jax.jit(lambda x: K.encode_quantize(x, w_c, mode, k, qb))
    q, s = jenc(x)
    jdec = jax.jit(lambda q, s: R.dequantize_decode_ref(
        q, s, w_d, mode, qb))
    pdec = jax.jit(lambda q, s: K.dequantize_decode(q, s, w_d, mode, qb))

    t_je, t_pe = _time(jenc, x, iters=iters), _time(penc, x, iters=iters)
    t_jd, t_pd = _time(jdec, q, s, iters=iters), \
        _time(pdec, q, s, iters=iters)
    qp, sp = penc(x)
    diff = max(_maxdiff(q, qp), _maxdiff(s, sp),
               _maxdiff(jdec(q, s), pdec(q, s)))

    mm = 2.0 * T * d * c if mode == "bottleneck" else 0.0
    flops = mm + 10.0 * T * d                       # matmul + norms + QDQ
    wire = T * c + DTYPE_BYTES * T * (c // qb)      # codes + scales
    w_bytes = DTYPE_BYTES * (d * c if mode == "bottleneck" else 0)
    bytes_fused = DTYPE_BYTES * T * d + w_bytes + wire
    # unfused: the float wire tensor is written then re-read by quantize
    bytes_twopass = bytes_fused + 2 * DTYPE_BYTES * T * c
    enc = _record(f"encode_quantize[{mode}]", T, t_je, t_pe, diff, flops,
                  bytes_fused, {"shape": [B, S, d], "wire_dim": c,
                                "qblock": qb,
                                "bytes_twopass": bytes_twopass})
    dec = _record(f"dequantize_decode[{mode}]", T, t_jd, t_pd, diff,
                  2.0 * T * c * d + 6.0 * T * d,
                  wire + DTYPE_BYTES * (c * d + T * d),
                  {"shape": [B, S, d], "wire_dim": c})
    return {"encode_quantize": enc, "dequantize_decode": dec}


def run(csv=True, out_path: str = "artifacts/BENCH_kernels.json",
        smoke: bool = False):
    print("# kernel microbench: jnp oracle vs pallas "
          f"(backend={jax.default_backend()})")
    print("name,us_per_call,derived")
    if smoke:
        B, S, d, iters = 1, 32, 64, 1
    else:
        B, S, d, iters = 2, 128, 128, 3
    report = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() not in ("tpu", "gpu"),
        "smoke": smoke,
        "kernels": {
            "flash_fwd": bench_flash(B, S, 4, 2, 32, iters),
            "rmsnorm": bench_rmsnorm(B, S, d, iters),
            "int8_roundtrip": bench_int8_roundtrip(B, S, d, iters),
        },
    }
    for mode in ("bottleneck", "maxout"):
        pair = bench_boundary(mode, B, S, d, d // 4, iters)
        for kname, rec in pair.items():
            report["kernels"][f"{kname}[{mode}]"] = rec
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    for name, rec in report["kernels"].items():
        rl = rec["roofline"]
        print(f"kernels/{name},{rec['pallas_s_per_call'] * 1e6:.0f},"
              f"jnp={rec['jnp_samples_per_s']:.0f}/s "
              f"pallas={rec['pallas_samples_per_s']:.0f}/s "
              f"diff={rec['max_abs_diff']:.1e} bound={rl['bound']}")
        assert rec["max_abs_diff"] < 1e-4, (
            f"{name}: pallas diverged from jnp oracle by "
            f"{rec['max_abs_diff']}")
        # cross-check against the roofline cost model's constants
        assert abs(rl["t_compute_s"] - rl["flops"] / roofline.PEAK_FLOPS) \
            < 1e-18 and abs(rl["t_memory_s"]
                            - rl["bytes"] / roofline.HBM_BW) < 1e-18
    print(f"kernels/json,0,{out_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iter (CI fast lane)")
    ap.add_argument("--out", default="artifacts/BENCH_kernels.json")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke)
