"""Paper Table 7/8 + App. J: compression-aware architectures — convergence
cost of int8 / bottleneck / maxout boundary compression on a real (tiny)
LM, and the wire-byte savings each buys.

All four modes now run END-TO-END through the elastic SWARM path (the
learned codecs train their ``w_c``/``w_d`` jointly with the model), and the
measured wire bytes of each mode's actual boundary tensor are asserted
equal to the analytic ``flops.boundary_bytes`` — the cost model cannot
drift from what crosses the wire.
"""
from __future__ import annotations

import time

import jax

from repro.core import SwarmRunner, SwarmConfig
from repro.models.config import ArchConfig
from repro.models import flops as F
from repro.optim import adamw
from repro.compression import bottleneck as bn, maxout as mx, codecs
from repro.compression.quant8 import compressed_bytes
from repro.models import params as P

# 2x feature compression for both learned codecs (paper Table 7's setting)
CFG = ArchConfig(name="bench-lm", family="dense", n_layers=4, d_model=128,
                 n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512,
                 head_dim=32, compute_dtype="float32",
                 param_dtype="float32", bottleneck_dim=64, maxout_k=2)

MODES = ("none", "int8", "bottleneck", "maxout")

PAPER_TABLE7 = {
    "none": (21.02, 1.00, 1.0),
    "int8": (21.13, 0.97, 0.5),
    "bottleneck": (21.76, 1.26, 0.5),
    "maxout": (21.83, 1.28, 0.5),
}


def _train(mode: str, steps: int = 20):
    scfg = SwarmConfig(n_stages=2, microbatch_size=4, seq_len=64,
                       global_batch=16, n_trainers=4, rebalance_period=0.0,
                       codec=mode, max_steps=steps)
    r = SwarmRunner(CFG, scfg, adamw(lr=3e-3, grad_clip=0.0), numeric=True,
                    seed=0)
    r.build(peers_per_stage=1)
    r.run(until=1e9)
    return r.metrics["loss"]


def measured_wire_bytes(mode: str, x: jax.Array) -> float:
    """Bytes of the ACTUAL tensor each codec puts on the wire (2-byte
    elements for the float modes, matching the cost model's bf16 wire)."""
    if mode == "int8":
        return float(compressed_bytes(x))
    if mode == "bottleneck":
        p = P.init(jax.random.PRNGKey(0),
                   bn.bottleneck_specs(CFG.d_model, codecs.wire_dim(
                       CFG, "bottleneck")))
        return bn.compress(p, x).size * 2.0
    if mode == "maxout":
        return mx.compress(x, codecs.maxout_k(CFG)).size * 2.0
    return x.size * 2.0


def run(csv=True):
    print("# compression-aware boundaries (paper Table 7/8, App. J)")
    print("name,us_per_call,derived")

    # ---- wire honesty: measured bytes == flops.boundary_bytes, all modes
    b, s = 4, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, CFG.d_model))
    for mode in MODES:
        measured = measured_wire_bytes(mode, x)
        model = F.boundary_bytes(CFG, b, s, mode)
        assert measured == model, (mode, measured, model)
        print(f"compression/wire_bytes_{mode},0,measured={measured:.0f} "
              f"model={model:.0f} ratio={measured / (x.size * 2.0):.3f} "
              f"match=True")

    # ---- convergence: all four modes end-to-end on the elastic path
    t0 = time.perf_counter()
    losses = {mode: _train(mode) for mode in MODES}
    dt = (time.perf_counter() - t0) * 1e6 / len(MODES)

    def steps_to(ls, target):
        for i, l in enumerate(ls):
            if l <= target:
                return i + 1
        return len(ls) + 1

    base = losses["none"]
    target = base[-1] + 0.02
    s_base = steps_to(base, target)
    for mode in MODES:
        ls = losses[mode]
        ratio = steps_to(ls, target) / s_base
        ppl, psteps, pwire = PAPER_TABLE7[mode]
        print(f"compression/{mode},{dt:.0f},final={ls[-1]:.4f} "
              f"steps={ratio:.2f}x paper_steps={psteps}x paper_ppl={ppl}")


if __name__ == "__main__":
    run()
