"""Paper Table 7/8 + App. K: compression-aware architectures — convergence
cost of int8 / bottleneck / maxout boundary compression on a real (tiny)
LM, and the wire-byte savings each buys."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SwarmRunner, SwarmConfig
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.compression.quant8 import compressed_bytes

CFG = ArchConfig(name="bench-lm", family="dense", n_layers=4, d_model=128,
                 n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512,
                 head_dim=32, compute_dtype="float32",
                 param_dtype="float32")

PAPER_TABLE7 = {
    "none": (21.02, 1.00, 1.0),
    "int8": (21.13, 0.97, 0.5),
    "bottleneck": (21.76, 1.26, 0.5),
    "maxout": (21.83, 1.28, 0.5),
}


def _train(compress: bool, steps: int = 20):
    scfg = SwarmConfig(n_stages=2, microbatch_size=4, seq_len=64,
                       global_batch=16, n_trainers=4, rebalance_period=0.0,
                       compress=compress, max_steps=steps)
    r = SwarmRunner(CFG, scfg, adamw(lr=3e-3, grad_clip=0.0), numeric=True,
                    seed=0)
    r.build(peers_per_stage=1)
    r.run(until=1e9)
    return r.metrics["loss"]


def run(csv=True):
    print("# compression-aware boundaries (paper Table 7/8, App. J)")
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    base = _train(compress=False)
    int8 = _train(compress=True)
    dt = (time.perf_counter() - t0) * 1e6 / 2

    def steps_to(losses, target):
        for i, l in enumerate(losses):
            if l <= target:
                return i + 1
        return len(losses) + 1

    target = base[-1] + 0.02
    s_base, s_int8 = steps_to(base, target), steps_to(int8, target)
    print(f"compression/none,{dt:.0f},final={base[-1]:.4f} steps=1.00x "
          f"wire=1.0x paper_ppl={PAPER_TABLE7['none'][0]}")
    print(f"compression/int8,{dt:.0f},final={int8[-1]:.4f} "
          f"steps={s_int8/s_base:.2f}x wire=0.53x "
          f"paper_steps={PAPER_TABLE7['int8'][1]}x")

    # wire bytes per boundary tensor (b=4, s=64, d=128)
    x = jnp.zeros((4, 64, 128))
    fp16 = x.size * 2
    q8 = compressed_bytes(x)
    print(f"compression/wire_bytes,0,fp16={fp16} int8={q8} "
          f"ratio={q8/fp16:.3f}")

    # bottleneck / maxout: measured as activation-reconstruction quality +
    # compression factor (full pretraining sweep is out of CPU budget;
    # paper Table 7 numbers quoted for reference)
    from repro.compression import bottleneck as bn, maxout as mx
    from repro.models import params as P
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (32, 64, 128))
    for name, factor in (("bottleneck", 2), ("maxout", 2)):
        if name == "bottleneck":
            p = P.init(key, bn.bottleneck_specs(128, 128 // factor))
            z = bn.compress(p, h)
        else:
            p = P.init(key, mx.maxout_specs(128, factor))
            z = mx.compress(h, factor)
        print(f"compression/{name},0,wire={z.size / h.size:.2f}x"
              f" paper_steps={PAPER_TABLE7[name][1]}x "
              f"paper_ppl={PAPER_TABLE7[name][0]}")


if __name__ == "__main__":
    run()
