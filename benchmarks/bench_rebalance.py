"""Paper Table 5 / Fig. 5 / Fig. 7: adaptive rebalancing vs no rebalancing
vs the always-optimal assignment, replaying a preemption trace; plus the
Fig. 7 scaling-in-stages study.

``recomputed=`` in the output is the microbatch ledger's count of
re-issued (recomputed) microbatches — the weekly sweep tracks it as the
recompute overhead of exactly-once accounting under churn."""
from __future__ import annotations

import time

import numpy as np

from repro.core import SwarmRunner, SwarmConfig, T4
from repro.core.faults import synth_preemptible_trace, active_counts
from repro.core.rebalance import optimal_assignment, pipeline_throughput
from repro.models.config import ArchConfig
from repro.optim import adamw

# the paper's §4.3 model: 3 stages of shared layers, d=4096 (layer sharing
# makes stages uniform; we model the 4-stage variant of App. I)
MODEL = ArchConfig(name="swarm1b-sim", family="dense", n_layers=4,
                   d_model=4096, n_heads=32, n_kv_heads=32, d_ff=16384,
                   vocab_size=50257, tie_embeddings=True)

PAPER_TABLE5 = {"None": (82.7, 99.0, 45.4), "T=300": (95.8, 99.4, 88.9),
                "T=60": (97.6, 99.8, 91.7)}

HORIZON = 4 * 3600.0          # 4h replay (32h-statistics trace, scaled)


def _run(T: float, trace, n0: int, n_stages: int = 4, horizon=HORIZON):
    # trainers must outnumber peers ~3x so the GPUs (not the dispatch
    # loop) are the bottleneck — the regime where rebalancing matters
    scfg = SwarmConfig(n_stages=n_stages, microbatch_size=1, seq_len=512,
                       global_batch=2048, n_trainers=3 * n0,
                       rebalance_period=T, codec="int8")
    r = SwarmRunner(MODEL, scfg, adamw(), numeric=False, seed=0,
                    profile_fn=lambda i: T4)
    r.build(peers_per_stage=n0 // n_stages)
    r.apply_trace(trace)
    r.run(until=horizon)
    return r


def _optimal_throughput(trace, n0: int, n_stages: int, horizon=HORIZON):
    """Integrate the weakest-link-optimal throughput over the trace."""
    counts = active_counts(trace, n0, horizon, dt=60.0)
    # per-peer stage rate from the same cost model as the runner
    from repro.models import flops as F
    ctx = F._ctx_for(MODEL, 512, causal_avg=True)
    per = MODEL.n_layers // n_stages
    fpt = sum(F.per_token_layer_flops(MODEL, k, ctx)
              for k in MODEL.block_kinds[:per])
    fpt_last = fpt + 2 * MODEL.d_model * MODEL.vocab_size
    t_mb = T4.compute_time((fpt * 3) * 512)     # fwd+bwd per sample
    rates = []
    for n in counts:
        if n < n_stages:
            # counts form raises below one peer per stage: a pool this
            # depleted has zero weakest-link throughput
            rates.append(0.0)
            continue
        alloc = optimal_assignment(int(n), n_stages)
        rates.append(pipeline_throughput(alloc, 1.0 / t_mb / 4.0))
    return float(np.mean(rates)) * 4.0          # fwd+bwd both on peers


def run(csv=True):
    print("# adaptive rebalancing (paper Table 5 / Fig. 5)")
    print("name,us_per_call,derived")
    trace = synth_preemptible_trace(horizon_s=HORIZON, target_peers=48,
                                    mean_lifetime_s=2.5 * 3600.0, seed=7)
    results = {}
    for T, tag in ((0.0, "None"), (300.0, "T=300"), (60.0, "T=60")):
        t0 = time.perf_counter()
        r = _run(T, trace, 48)
        dt = (time.perf_counter() - t0) * 1e6
        results[tag] = r
    # normalize against the best observed overall throughput as 'optimal'
    opt = max(r.throughput() for r in results.values()) * 1.02
    import bisect
    for tag, r in results.items():
        ts, vs = r.metrics["throughput_t"], r.metrics["throughput_v"]
        overall = 100 * r.throughput() / opt
        last = 100 * r.throughput(window=3600.0) / opt
        p = PAPER_TABLE5[tag]
        print(f"rebalance/{tag},0,overall={overall:.1f}% "
              f"last1h={last:.1f}%"
              f" migrations={r.metrics['migrations']}"
              f" recomputed={r.metrics['recomputed_microbatches']}"
              f" paper_overall={p[0]}% paper_last={p[2]}%")

    # Fig. 7: scaling with number of stages (heavier churn so the
    # imbalance actually drifts within the shortened horizon)
    for n_stages in (4, 8, 16):
        trace_s = synth_preemptible_trace(
            horizon_s=HORIZON, target_peers=8 * n_stages,
            mean_lifetime_s=1.0 * 3600.0, mass_fraction=0.2, seed=11)
        r_rb = _run(300.0, trace_s, 8 * n_stages, n_stages, HORIZON)
        r_no = _run(0.0, trace_s, 8 * n_stages, n_stages, HORIZON)
        rel = (r_rb.throughput(window=3600.0)
               / max(r_no.throughput(window=3600.0), 1e-9) - 1) * 100
        print(f"rebalance/stages{n_stages},0,"
              f"rebalanced_vs_none_last1h={rel:+.1f}%")


if __name__ == "__main__":
    run()
