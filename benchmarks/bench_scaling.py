"""Paper Fig. 6: SWARM throughput scales ~linearly in the number of
(homogeneous T4) peers; plus Tables 3-4: actual-vs-best-case throughput and
optimal bandwidth per device class."""
from __future__ import annotations

import time

from repro.core import SwarmRunner, SwarmConfig, T4, A100
from repro.models.config import ArchConfig
from repro.models import flops as F
from repro.optim import adamw

MODEL = ArchConfig(name="swarm1b-sim", family="dense", n_layers=3,
                   d_model=4096, n_heads=32, n_kv_heads=32, d_ff=16384,
                   vocab_size=50257, tie_embeddings=True)


def _throughput(n_peers, profile_fn, codec="int8", horizon=900.0):
    scfg = SwarmConfig(n_stages=3, microbatch_size=1, seq_len=2048,
                       global_batch=512, n_trainers=3 * n_peers,
                       rebalance_period=300.0, codec=codec)
    r = SwarmRunner(MODEL, scfg, adamw(), numeric=False, seed=0,
                    profile_fn=profile_fn)
    r.build(peers_per_stage=n_peers // 3)
    r.run(until=horizon)
    return r.throughput()


def _best_case(n_peers, profile):
    """Paper's 'ideal case ignoring all network operations'."""
    ctx = F._ctx_for(MODEL, 2048, causal_avg=True)
    fpt = sum(F.per_token_layer_flops(MODEL, k, ctx)
              for k in MODEL.block_kinds) \
        + 2 * MODEL.d_model * MODEL.vocab_size
    t_per_sample = profile.compute_time(3 * fpt * 2048)
    return n_peers / t_per_sample / 3.0        # 3 stages share the peers


def run(csv=True):
    print("# scaling with number of nodes (paper Fig. 6, Tables 3-4)")
    print("name,us_per_call,derived")
    base = None
    for n in (6, 12, 24, 48):
        t0 = time.perf_counter()
        thr = _throughput(n, lambda i: T4)
        dt = (time.perf_counter() - t0) * 1e6
        if base is None:
            base = thr / n
        lin = thr / (base * n)
        print(f"scaling/T4x{n},{dt:.0f},samples_s={thr:.2f} "
              f"linearity={lin:.2f}")

    # Tables 3-4: actual vs best-case, T4 vs A100 vs mixed
    for tag, prof_fn, prof in (
            ("T4", lambda i: T4, T4), ("A100", lambda i: A100, A100),
            ("mixed", lambda i: T4 if i % 2 else A100, None)):
        t0 = time.perf_counter()
        thr = _throughput(24, prof_fn)
        dt = (time.perf_counter() - t0) * 1e6
        if prof is not None:
            best = _best_case(24, prof)
            print(f"bandwidth/{tag}x24,{dt:.0f},actual={thr:.2f} "
                  f"best_case={best:.2f} ratio={thr/best:.2f}")
        else:
            print(f"bandwidth/{tag}x24,{dt:.0f},actual={thr:.2f} "
                  f"(heterogeneous: balanced by IWRR)")

    # optimal bandwidth to saturate a T4 (paper Table 3 right columns)
    ctx = F._ctx_for(MODEL, 2048, causal_avg=True)
    fpt = sum(F.per_token_layer_flops(MODEL, k, ctx)
              for k in MODEL.block_kinds[:1])
    t_c = T4.compute_time(3 * fpt * 2048)
    nbytes = F.boundary_bytes(MODEL, 1, 2048, "int8")
    bw_mbps = 2 * nbytes / t_c / 125_000.0
    print(f"bandwidth/T4_optimal_mbps,0,required={bw_mbps:.0f}Mb/s "
          f"paper=318-398Mb/s")


if __name__ == "__main__":
    run()
