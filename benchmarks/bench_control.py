"""Control-plane scale benchmark (ISSUE 10): replay a preemptible-fleet
trace through the planner + wiring at 400-1000 simulated peers.

Three sections, one machine-readable record
(``artifacts/BENCH_control.json``, uploaded by CI with ``if:
always()``):

* **planner ms/decision** — ``optimal_assignment(spans=True)``,
  ``plan_span_change`` and ``plan_migration`` timed at 1000 peers x 48
  stages on 13B-class stage-plan pricing, against the RECORDED pre-fix
  baselines (measured at commit 08e5cfa on this workload, before the
  ControlSnapshot/heap restructure).  The acceptance bar: <= 50 ms per
  decision, with the recorded baseline >= 10x slower than the matching
  unit (one full rebalance round — a single snapshot capture plus both
  Alg.-2 decisions — for the two DHT-reading planners, since the
  pre-fix implementations each re-read the DHT internally).
* **throughput retention** — a timing-mode ``SwarmRunner`` fleet
  replaying a zone-correlated mass-preemption trace
  (``synth_preemptible_trace(regions=...)``) vs the same fleet steady:
  retention >= 0.7x, with the snapshot-driven rebalance round and the
  region-aware (LinkTable) boundary pricing live.
* **stale-peer leaks** — after the churny replay plus one wiring
  refresh, ZERO wiring entries (``_stages_of`` / ``ema`` / queue heaps)
  may reference expired peers, and the per-stage heaps must be
  compacted to O(live servers), not O(#requests).

    PYTHONPATH=src python -m benchmarks.bench_control [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import SwarmConfig, SwarmRunner, T4, V100, A100
from repro.core import rebalance as rb
from repro.core.dht import DHT
from repro.core.faults import synth_preemptible_trace
from repro.core.square_cube import default_wan_table
from repro.models.stage_plan import get_stage_plan
from repro.optim import adamw
from repro.configs import swarm1b

# 13B-class dry-run pricing: the paper's swarm-1b stack (48 layers,
# d=4096 — "compute-equivalent to a 13B model" with its 3x sharing)
# densified so any stage count dividing 48 plans cleanly.
MODEL = swarm1b.CONFIG.with_overrides(name="swarm13b-ctl", share_groups=0)
SEQ = 512
REGIONS = ("us-east", "us-west", "eu", "ap")

# Pre-fix planner times on THIS workload (1000 peers x 48 stages,
# swarm-13B stage plan, heterogeneous T4/V100/A100 speeds), measured at
# commit 08e5cfa — the planners before the per-round ControlSnapshot,
# the O(1)-coverage/span-multiset candidate scan, and the chunk-rate
# heap.  Kept as constants so every CI run re-proves the >= 10x bar
# without re-running a 99-second baseline.  The pre-fix Alg.-2 planners
# each re-read the DHT internally, so the honest unit of comparison for
# them is one full rebalance round: a single snapshot capture plus both
# decisions, against plan_span_change (226.3 ms) + plan_migration
# (26.0 ms) run back to back.
BASELINE_MS = {
    "optimal_assignment": 99095.5,
    "rebalance_round": 252.3,
}
DECISION_BUDGET_MS = 50.0


def _fleet_speeds(n: int) -> list[float]:
    """Heterogeneous preemptible fleet: mostly T4s with V100/A100
    stragglers-in-reverse (paper §4.3 runs on preemptible T4s; the
    planner must still place a mixed pool)."""
    profs = [T4, T4, T4, V100, A100]
    return [profs[i % len(profs)].flops_per_s / T4.flops_per_s
            for i in range(n)]


def _plan_pricing(n_stages: int):
    """(stage costs, per-edge bytes) in seconds-per-microbatch units
    from the 13B stage plan: fwd+bwd compute on a T4 reference, wire
    priced per boundary."""
    plan = get_stage_plan(MODEL, n_stages)
    costs = [3.0 * f * SEQ / T4.flops_per_s
             for f in plan.stage_costs(SEQ)]
    bbytes = [plan.boundary_bytes(b, 1, SEQ, "int8")
              for b in range(n_stages - 1)]
    return costs, bbytes


def _stage_regions(n_stages: int) -> list[str]:
    """A deliberately bad static placement — contiguous region blocks,
    so interior boundaries include slow WAN pairs the planner should
    fuse across."""
    per = max(1, n_stages // len(REGIONS))
    return [REGIONS[min(s // per, len(REGIONS) - 1)]
            for s in range(n_stages)]


def bench_planner(n_peers: int, n_stages: int, smoke: bool) -> dict:
    speeds = _fleet_speeds(n_peers)
    costs, bbytes = _plan_pricing(n_stages)
    links = default_wan_table()
    regions = _stage_regions(n_stages)
    bcosts = links.edge_costs(bbytes, regions)

    t0 = time.perf_counter()
    assign = rb.optimal_assignment(n_peers, n_stages, costs,
                                   speeds=speeds, spans=True,
                                   boundary_cost=bcosts)
    ms_assign = (time.perf_counter() - t0) * 1e3
    assert rb.spans_route(n_stages, assign)

    # region-aware vs region-blind placement, both priced by the REAL
    # (region-priced) edge costs: optimizing the true objective must
    # not lose to the uniform-scalar legacy pricing
    naive = rb.optimal_assignment(
        n_peers, n_stages, costs, speeds=speeds, spans=True,
        boundary_cost=float(np.mean(bcosts)))
    thr_aware = rb.pipeline_throughput(assign, speeds, stage_costs=costs,
                                       boundary_cost=bcosts)
    thr_naive = rb.pipeline_throughput(naive, speeds, stage_costs=costs,
                                       boundary_cost=bcosts)

    # a populated control plane: every peer announces a queue size under
    # its span's stages, then one snapshot drives both Alg.-2 decisions
    dht = DHT(lambda: 0.0)
    rng = np.random.default_rng(0)
    spans = {f"p{i}": tuple(assign[i]) for i in range(n_peers)}
    pps: dict[int, list] = {s: [] for s in range(n_stages)}
    for i, (lo, hi) in enumerate(assign):
        for s in range(lo, hi):
            dht.store(dht.load_key(s), f"p{i}",
                      float(rng.uniform(0.0, 10.0)), ttl=1e9)
        if hi - lo == 1:
            pps[lo].append(f"p{i}")

    t0 = time.perf_counter()
    snap = rb.ControlSnapshot.capture(dht, n_stages)
    ms_capture = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    rb.plan_migration(snap, n_stages, pps)
    ms_mig = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    rb.plan_span_change(snap, n_stages, spans, boundary_costs=bcosts)
    ms_span = (time.perf_counter() - t0) * 1e3

    # one round = one capture shared by both Alg.-2 decisions; the
    # per-decision figures below charge the shared capture to each, the
    # round figure charges it once (the baseline planners re-read the
    # DHT themselves, so the round is the apples-to-apples unit)
    ms = {"optimal_assignment": ms_assign,
          "plan_span_change": ms_span + ms_capture,
          "plan_migration": ms_mig + ms_capture,
          "rebalance_round": ms_capture + ms_mig + ms_span}
    out = {
        "n_peers": n_peers, "n_stages": n_stages, "model": MODEL.name,
        "ms_per_decision": ms,
        "snapshot_capture_ms": ms_capture,
        "baseline_ms": BASELINE_MS,
        "speedup_vs_baseline": {k: BASELINE_MS[k] / max(ms[k], 1e-9)
                                for k in BASELINE_MS},
        "region_aware": {"thr_aware": thr_aware, "thr_naive": thr_naive,
                         "ratio": thr_aware / max(thr_naive, 1e-12)},
    }
    for name, v in ms.items():
        print(f"planner_{name},ms,{v:.2f}")
        assert v <= DECISION_BUDGET_MS, (
            f"{name} took {v:.1f} ms at {n_peers} peers x {n_stages} "
            f"stages (budget {DECISION_BUDGET_MS} ms)")
        if not smoke and name in BASELINE_MS:
            # the recorded baselines were measured at exactly this scale
            assert BASELINE_MS[name] >= 10.0 * v, (
                f"{name}: recorded pre-fix baseline "
                f"{BASELINE_MS[name]:.1f} ms is not >= 10x the measured "
                f"{v:.1f} ms")
    assert thr_aware >= 0.999 * thr_naive, (
        f"region-aware placement lost to region-blind under the true "
        f"edge prices: {thr_aware:.4f} < {thr_naive:.4f}")
    return out


def _replay_runner(n0: int, n_stages: int, horizon: float,
                   seed: int = 0) -> SwarmRunner:
    links = default_wan_table()
    scfg = SwarmConfig(n_stages=n_stages, microbatch_size=1, seq_len=SEQ,
                       global_batch=max(2 * n0, 64), n_trainers=n0,
                       rebalance_period=300.0, codec="int8", spans=True,
                       link_table=links)
    profs = [T4, T4, T4, V100, A100]
    r = SwarmRunner(MODEL, scfg, adamw(), numeric=False, seed=seed,
                    profile_fn=lambda i: profs[i % len(profs)],
                    region_fn=lambda i: REGIONS[i % len(REGIONS)])
    r.build(peers_per_stage=n0 // n_stages)
    return r


def bench_replay(n0: int, n_stages: int, horizon: float) -> tuple[dict,
                                                                  dict]:
    steady = _replay_runner(n0, n_stages, horizon)
    steady.run(until=horizon)
    thr_steady = steady.throughput()

    churn = _replay_runner(n0, n_stages, horizon)
    # zone-correlated spot reclaims: elevated mass-preemption pressure,
    # every mass event emptying capacity from ONE region
    trace = synth_preemptible_trace(
        horizon_s=horizon, target_peers=n0,
        mean_lifetime_s=2.0 * 3600.0, mass_preemption_rate_per_h=1.0,
        mass_fraction=0.2, seed=7, regions=REGIONS)
    churn.apply_trace(trace)
    churn.run(until=horizon)
    thr_churn = churn.throughput()
    retention = thr_churn / max(thr_steady, 1e-12)

    replay = {
        "n0": n0, "n_stages": n_stages, "horizon_s": horizon,
        "trace_events": len(trace),
        "thr_steady_samples_per_s": thr_steady,
        "thr_churn_samples_per_s": thr_churn,
        "retention": retention,
        "failures": churn.metrics["failures"],
        "joins": churn.metrics["joins"],
        "migrations": churn.metrics["migrations"],
        "span_changes": churn.metrics["span_changes"],
    }
    print(f"replay_retention,ratio,{retention:.3f}")

    # ---- stale-peer leak audit on the churned fleet -----------------
    live = {pid for pid, p in churn.peers.items()
            if p.alive and p.serving}
    expired_entries = 0
    max_heap = 0
    for w in churn.wirings:
        w.refresh_from_dht(churn.dht, churn.announced_stages())
        expired_entries += sum(1 for pid in w._stages_of
                               if pid not in live)
        expired_entries += sum(1 for pid in w.ema if pid not in live)
        for q in w.queues:
            expired_entries += sum(1 for pid in q._entries
                                   if pid not in live)
            max_heap = max(max_heap, q.heap_size())
    covered_slots = sum(len(p.stages) for pid, p in churn.peers.items()
                        if pid in live)
    leaks = {
        "live_peers": len(live),
        "dead_peers": len(churn.peers) - len(live),
        "wiring_entries_expired": expired_entries,
        "max_queue_heap_size": max_heap,
        "dht_stage_records": churn.dht.n_records("stage/"),
        "dht_load_records": churn.dht.n_records("load/"),
        "covered_stage_slots": covered_slots,
    }
    assert expired_entries == 0, (
        f"{expired_entries} wiring entries still reference expired "
        f"peers after refresh — the eviction fix regressed")
    assert max_heap <= 2 * len(live) + 16, (
        f"a stage queue heap holds {max_heap} entries for {len(live)} "
        f"live peers — compaction regressed")
    assert leaks["dht_stage_records"] <= covered_slots, (
        f"{leaks['dht_stage_records']} live stage records exceed the "
        f"{covered_slots} covered slots of live peers — dead peers "
        f"leaked announcements")
    return replay, leaks


def run(csv=True, out_path: str = "artifacts/BENCH_control.json",
        smoke: bool = False) -> dict:
    print("# control plane at preemptible-fleet scale (ISSUE 10)")
    print("name,unit,value")
    if smoke:
        n_planner, s_planner = 200, 12
        n0, s_replay, horizon = 24, 8, 1800.0
    else:
        n_planner, s_planner = 1000, 48
        n0, s_replay, horizon = 400, 16, 3600.0

    planner = bench_planner(n_planner, s_planner, smoke)
    replay, leaks = bench_replay(n0, s_replay, horizon)

    report = {"smoke": smoke, "planner": planner, "replay": replay,
              "leaks": leaks}
    # write the record FIRST: a regressed run must still leave the
    # artifact behind for diagnosis (CI uploads it with `if: always()`)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    assert replay["retention"] >= 0.7, (
        f"throughput retention {replay['retention']:.3f} under the "
        f"mass-preemption replay fell below 0.7x steady state")
    print(f"# BENCH_control written to {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet, short trace (CI fast lane)")
    ap.add_argument("--out", default="artifacts/BENCH_control.json")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
