"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark plus the roofline
tables derived from the dry-run artifacts (if present).

    PYTHONPATH=src python -m benchmarks.run [--only <name>]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def smoke() -> None:
    """CI import-rot guard: one real train step, then import every module
    under ``src/repro`` and every benchmark suite.

    The train step runs FIRST so the jax backend initializes with the
    default device view — ``repro.launch.dryrun`` mutates XLA_FLAGS (the
    512-device override) at import, which must not leak into the step.
    """
    import importlib
    import pkgutil

    import jax

    from repro.data import make_batch
    from repro.models.config import ArchConfig
    from repro.optim import adamw
    from repro.train.steps import make_state, make_train_step

    cfg = ArchConfig(name="smoke", family="dense", n_layers=2, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                     head_dim=16, compute_dtype="float32",
                     param_dtype="float32")
    opt = adamw(lr=1e-3)
    state = make_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    _, m = step(state, make_batch(cfg.vocab_size, 16, 2))
    print(f"smoke_train_step,loss,{float(m['loss']):.4f}")

    import benchmarks
    import repro
    failed = []
    for pkg in (repro, benchmarks):
        for info in pkgutil.walk_packages(pkg.__path__, pkg.__name__ + "."):
            try:
                importlib.import_module(info.name)
            except Exception as e:
                failed.append((info.name, f"{type(e).__name__}: {e}"))
    for name, err in failed:
        print(f"# IMPORT FAILED {name}: {err}", file=sys.stderr)
    print(f"smoke_imports,modules_ok,{'FAIL' if failed else 'OK'}")
    sys.exit(1 if failed else 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="1-step import-rot guard (CI): no full suites")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return

    from benchmarks import (bench_square_cube, bench_throughput,
                            bench_rebalance, bench_scaling,
                            bench_compression, bench_cost, bench_swarm,
                            bench_serve, bench_control, bench_kernels,
                            roofline)
    suites = {
        "kernels": bench_kernels.run,             # pallas vs jnp per-kernel
        "square_cube": bench_square_cube.run,     # Fig.3 / Table 1
        "throughput": bench_throughput.run,       # Table 2
        "rebalance": bench_rebalance.run,         # Table 5 / Fig.5 / Fig.7
        "scaling": bench_scaling.run,             # Fig.6 / Tables 3-4
        "compression": bench_compression.run,     # Table 7/8
        "cost": bench_cost.run,                   # Table 9
        "swarm": bench_swarm.run,                 # runtime layer: compile
                                                  # cache + BENCH_swarm.json
        "serve": bench_serve.run,                 # serving layer: tokens/s,
                                                  # p99, churn recovery
        "control": bench_control.run,             # control plane at 1000-peer
                                                  # scale + leak audit
    }
    failed = []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} took {time.time() - t0:.1f}s\n")

    if not args.only or args.only == "roofline":
        try:
            print("# roofline (single-pod baseline, from dry-run artifacts)")
            roofline.main("single")
        except Exception:
            failed.append("roofline")
            traceback.print_exc()

    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
