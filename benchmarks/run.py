"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark plus the roofline
tables derived from the dry-run artifacts (if present).

    PYTHONPATH=src python -m benchmarks.run [--only <name>]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_square_cube, bench_throughput,
                            bench_rebalance, bench_scaling,
                            bench_compression, bench_cost, roofline)
    suites = {
        "square_cube": bench_square_cube.run,     # Fig.3 / Table 1
        "throughput": bench_throughput.run,       # Table 2
        "rebalance": bench_rebalance.run,         # Table 5 / Fig.5 / Fig.7
        "scaling": bench_scaling.run,             # Fig.6 / Tables 3-4
        "compression": bench_compression.run,     # Table 7/8
        "cost": bench_cost.run,                   # Table 9
    }
    failed = []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} took {time.time() - t0:.1f}s\n")

    if not args.only or args.only == "roofline":
        try:
            print("# roofline (single-pod baseline, from dry-run artifacts)")
            roofline.main("single")
        except Exception:
            failed.append("roofline")
            traceback.print_exc()

    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
