"""Roofline analysis (§Roofline): per (arch x shape x mesh), derive the
three terms from the compiled dry-run artifacts:

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory     = HLO_bytes / (chips x 819 GB/s)
    collective = collective_bytes / (chips x 50 GB/s/link)

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, scan
bodies counted once — reconstructed via the layer probe; see
repro/launch/hlo_analysis.py).  Collective bytes are parsed from the
partitioned HLO (per-device) and trip-count scaled.  MODEL_FLOPS uses the
6·N·D convention with N = activated params.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")


def corrected_flops_per_device(rec: dict) -> float:
    """graph x accum + (n_r - 1) x probe_layer / chips per scanned run
    (grad-accumulation microbatch loops are while loops too)."""
    accum = rec.get("accum", 1) or 1
    total = float(rec["hlo_flops_per_device_raw"]) * accum
    probe = rec.get("probe")
    if probe:
        chips = rec["n_devices"]
        for kind, n in probe["runs"]:
            if n > 1 and kind in probe["kinds"]:
                total += (n - 1) * probe["kinds"][kind] / chips
    return total


def corrected_bytes_per_device(rec: dict) -> float:
    """HBM traffic: scale the raw per-device bytes by the same ratio as the
    FLOP correction (layer bodies dominate both)."""
    raw_b = float(rec["hlo_bytes_per_device_raw"])
    raw_f = float(rec["hlo_flops_per_device_raw"])
    corr_f = corrected_flops_per_device(rec)
    if raw_f <= 0:
        return raw_b
    return raw_b * (corr_f / raw_f)


def model_flops(rec: dict) -> float:
    """6·N_active·D for the cell's token count (per device)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs import get_config, SHAPES
    from repro.models import flops as F
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_active = F.active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 1.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 1.0 / 3.0           # forward only: 2·N·D
    else:
        tokens = shape.global_batch  # one token per sequence
        mult = 1.0 / 3.0
    return 6.0 * n_active * tokens * mult / rec["n_devices"]


def analyze(rec: dict) -> dict:
    flops = corrected_flops_per_device(rec)
    bytes_hbm = corrected_bytes_per_device(rec)
    bytes_coll = float(rec["collectives"]["total_bytes"])
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = bytes_coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    # ideal step time: compute at peak, but never below one pass over the
    # resident state (params+caches) — the binding floor for decode
    min_bytes = rec["memory"]["argument_bytes"]
    ideal = max(mf / PEAK_FLOPS, min_bytes / HBM_BW)
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "hlo_flops": flops, "hlo_bytes": bytes_hbm,
        "coll_bytes": bytes_coll,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        # roofline fraction: ideal compute time / achievable step time
        # (step time >= max of the three terms)
        "roofline_frac": ideal / bound if bound else 0.0,
        "peak_mem_gib": rec["memory"]["peak_per_device"] / 2 ** 30,
        "fits_16g": rec["memory"]["peak_per_device"] <= 16 * 2 ** 30,
    }


def load_records(mesh: str = "single") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"{mesh}__*.json"))):
        rec = json.load(open(path))
        out.append(rec)
    return out


def what_would_help(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.4:
            return ("cut non-useful FLOPs (remat recompute, causal-block "
                    "skip, MoE capacity)")
        return "compute-bound near peak: increase arithmetic efficiency"
    if d == "memory":
        return ("reduce HBM traffic: fuse norms/quant (Pallas), bf16 "
                "master/grad, larger fusion blocks")
    return ("cut collective bytes: int8 collectives, 2D-sharded layouts, "
            "overlap via pipelined scan")


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {r['peak_mem_gib']:.1f}"
            f"{'' if r['fits_16g'] else ' (!)'} |")
    return hdr + "\n".join(lines)


def main(mesh: str = "single"):
    rows = []
    for rec in load_records(mesh):
        if rec.get("status") == "skipped":
            print(f"skipped,{rec['arch']},{rec['shape']},{rec['reason']}")
            continue
        if rec.get("status") != "ok":
            print(f"ERROR,{rec['arch']},{rec['shape']},"
                  f"{rec.get('error', '?')}")
            continue
        rows.append(analyze(rec))
    print(markdown_table(rows))
    for r in rows:
        print(f"hint,{r['arch']},{r['shape']},{what_would_help(r)}")
    return rows


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "single")
