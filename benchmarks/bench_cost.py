"""Paper Table 9 / App. K: time- and cost-to-solution on reliable vs
preemptible fleets (public on-demand/spot price sheet, mid-2021 as in the
paper)."""
from __future__ import annotations

import dataclasses

from repro.core import SwarmRunner, SwarmConfig, T4, V100
from repro.models.config import ArchConfig
from repro.optim import adamw

PRICES = {  # $/h, on-demand vs preemptible (paper-era public cloud)
    ("V100", False): 7.834 / 8, ("V100", True): 5.383 / 8,
    ("T4", True): 3.536 / 32,
}
PAPER_TABLE9 = {"8xV100 reliable": (175.4, 1374),
                "8xV100 preemptible": (192.6, 1037),
                "32xT4 preemptible": (140.8, 497.8)}

MODEL = ArchConfig(name="albert-sim", family="dense", n_layers=4,
                   d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
                   vocab_size=30000, tie_embeddings=True)
TARGET_SAMPLES = 4096 * 4000     # samples to reach the target loss


def _fleet_throughput(n, profile, preemptible):
    scfg = SwarmConfig(n_stages=4, microbatch_size=8, seq_len=512,
                       global_batch=4096, n_trainers=8,
                       rebalance_period=300.0, codec="int8")
    r = SwarmRunner(MODEL, scfg, adamw(), numeric=False, seed=0,
                    profile_fn=lambda i: profile)
    r.build(peers_per_stage=n // 4)
    if preemptible:
        from repro.core.faults import synth_preemptible_trace
        r.apply_trace(synth_preemptible_trace(
            horizon_s=1800.0, target_peers=n,
            mean_lifetime_s=6 * 3600.0, seed=5))
    r.run(until=1800.0)
    return r.throughput()


def run(csv=True):
    print("# time/cost to solution (paper Table 9)")
    print("name,us_per_call,derived")
    for tag, n, prof, pre, paper in (
            ("8xV100_reliable", 8, V100, False,
             PAPER_TABLE9["8xV100 reliable"]),
            ("8xV100_preempt", 8, V100, True,
             PAPER_TABLE9["8xV100 preemptible"]),
            ("32xT4_preempt", 32, T4, True,
             PAPER_TABLE9["32xT4 preemptible"])):
        thr = _fleet_throughput(n, prof, pre)
        hours = TARGET_SAMPLES / max(thr, 1e-9) / 3600.0
        price = PRICES[(prof.name, pre)] * n
        cost = hours * price
        print(f"cost/{tag},0,hours={hours:.1f} hourly=${price:.2f} "
              f"total=${cost:.0f} paper={paper[0]}h/${paper[1]}")


if __name__ == "__main__":
    run()
