"""Paper Table 9 / App. K: time- and cost-to-solution on reliable vs
preemptible fleets (public on-demand/spot price sheet, mid-2021 as in the
paper) — plus the StagePlan pricing audit: per-kind stage FLOPs must sum
to the whole-model figure, and the expert-sharded MoE boundary price must
equal the actual routed dispatch-buffer bytes."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import SwarmRunner, SwarmConfig, T4, V100
from repro.models import flops as F
from repro.models.config import ArchConfig, MoEConfig, SSMConfig
from repro.models.stage_plan import get_stage_plan
from repro.optim import adamw

PRICES = {  # $/h, on-demand vs preemptible (paper-era public cloud)
    ("V100", False): 7.834 / 8, ("V100", True): 5.383 / 8,
    ("T4", True): 3.536 / 32,
}
PAPER_TABLE9 = {"8xV100 reliable": (175.4, 1374),
                "8xV100 preemptible": (192.6, 1037),
                "32xT4 preemptible": (140.8, 497.8)}

MODEL = ArchConfig(name="albert-sim", family="dense", n_layers=4,
                   d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
                   vocab_size=30000, tie_embeddings=True)
TARGET_SAMPLES = 4096 * 4000     # samples to reach the target loss


def _fleet_throughput(n, profile, preemptible):
    scfg = SwarmConfig(n_stages=4, microbatch_size=8, seq_len=512,
                       global_batch=4096, n_trainers=8,
                       rebalance_period=300.0, codec="int8")
    r = SwarmRunner(MODEL, scfg, adamw(), numeric=False, seed=0,
                    profile_fn=lambda i: profile)
    r.build(peers_per_stage=n // 4)
    if preemptible:
        from repro.core.faults import synth_preemptible_trace
        r.apply_trace(synth_preemptible_trace(
            horizon_s=1800.0, target_peers=n,
            mean_lifetime_s=6 * 3600.0, seed=5))
    r.run(until=1800.0)
    return r.throughput()


HETERO = ArchConfig(name="cost-hetero", family="dense", n_layers=4,
                    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
                    vocab_size=30000,
                    block_pattern=("attn", "moe", "mamba", "mlstm"),
                    moe=MoEConfig(num_experts=8, top_k=2,
                                  d_ff_expert=2048, expert_sharded=True),
                    ssm=SSMConfig())
COST_SEQ, COST_MB = 512, 8


def _stage_flops_audit():
    """Per-kind stage rates off the plan — one stage per kind here, so
    each row IS one kind's price; their sum must reproduce the
    whole-model forward FLOPs/token exactly."""
    plan = get_stage_plan(HETERO, 4)
    per_stage = [plan.stage_flops(s, COST_SEQ) for s in range(4)]
    total = F.forward_flops_per_token(HETERO, COST_SEQ)
    assert abs(sum(per_stage) - total) <= 1e-6 * total, (
        f"per-kind stage flops drifted from the whole model: "
        f"{sum(per_stage)} vs {total}")
    for s, fpt in enumerate(per_stage):
        kinds = "+".join(k for k, _ in plan.stages[s].runs)
        print(f"cost/stage_flops/{s}_{kinds},0,"
              f"fwd_gflops_per_token={fpt / 1e9:.3f}")
    print(f"cost/stage_flops/total,0,sum={sum(per_stage) / 1e9:.3f}G "
          f"whole_model={total / 1e9:.3f}G")


def _moe_wire_audit():
    """The boundary entering the expert-sharded MoE stage must price
    exactly the routed dispatch buffer a real all-to-all ships: top_k
    bf16 copies of every token's hidden state."""
    plan = get_stage_plan(HETERO, 4)
    T = COST_MB * COST_SEQ
    dispatch = jnp.zeros((T * HETERO.moe.top_k, HETERO.d_model),
                         dtype=jnp.bfloat16)
    measured = float(dispatch.nbytes)
    priced = plan.boundary_bytes(0, COST_MB, COST_SEQ)   # attn -> moe
    assert priced == measured, (
        f"expert-sharded MoE boundary price {priced} != routed "
        f"dispatch-buffer bytes {measured}")
    uniform = plan.boundary_bytes(1, COST_MB, COST_SEQ)  # moe -> mamba
    assert uniform == measured / HETERO.moe.top_k
    print(f"cost/moe_wire,0,routed={measured / 1e6:.2f}MB "
          f"(top_k={HETERO.moe.top_k}) uniform={uniform / 1e6:.2f}MB "
          f"priced==measured")


def run(csv=True):
    print("# time/cost to solution (paper Table 9)")
    print("name,us_per_call,derived")
    _stage_flops_audit()
    _moe_wire_audit()
    for tag, n, prof, pre, paper in (
            ("8xV100_reliable", 8, V100, False,
             PAPER_TABLE9["8xV100 reliable"]),
            ("8xV100_preempt", 8, V100, True,
             PAPER_TABLE9["8xV100 preemptible"]),
            ("32xT4_preempt", 32, T4, True,
             PAPER_TABLE9["32xT4 preemptible"])):
        thr = _fleet_throughput(n, prof, pre)
        hours = TARGET_SAMPLES / max(thr, 1e-9) / 3600.0
        price = PRICES[(prof.name, pre)] * n
        cost = hours * price
        print(f"cost/{tag},0,hours={hours:.1f} hourly=${price:.2f} "
              f"total=${cost:.0f} paper={paper[0]}h/${paper[1]}")


if __name__ == "__main__":
    run()
