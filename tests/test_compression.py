"""8-bit blockwise quantization + compression-aware layers (paper App. J)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.compression import (blockwise_quantize, blockwise_dequantize,
                               compress_boundary, quantization_error,
                               bottleneck_specs, maxout_specs)
from repro.compression import bottleneck as bn
from repro.compression import maxout as mx
from repro.models import params as P


def test_roundtrip_small_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3
    assert float(quantization_error(x)) < 0.01


def test_exact_for_blockwise_constant():
    x = jnp.repeat(jnp.array([1.0, -2.0, 0.5]), 64)
    q, s, meta = blockwise_quantize(x, 64)
    xr = blockwise_dequantize(q, s, meta)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x), rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                               max_side=65),
                  elements=st.floats(-1e4, 1e4, width=32)))
def test_property_error_bound(x):
    """Absmax int8: per-element error <= absmax/127 per block."""
    xj = jnp.asarray(x)
    q, s, meta = blockwise_quantize(xj, 64)
    xr = blockwise_dequantize(q, s, meta)
    per_block_bound = np.asarray(s).ravel() / 127.0 * 1.0001 + 1e-6
    diff = np.abs(np.asarray(xr) - x).ravel()
    pad = (-diff.size) % 64
    diff = np.pad(diff, (0, pad))
    worst = diff.reshape(-1, 64).max(1)
    assert np.all(worst <= per_block_bound[:worst.size])


def test_compressed_dtype_is_int8():
    q, s, meta = blockwise_quantize(jnp.ones(256), 64)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32


def test_boundary_ste_gradient():
    """compress_boundary: fwd quantizes, bwd quantizes the cotangent."""
    x = jax.random.normal(jax.random.PRNGKey(1), (128,))
    g = jax.grad(lambda x: jnp.sum(jnp.sin(compress_boundary(x))))(x)
    # cos(q(x)) quantized: close to cos(x), and exactly a quantized vector
    ref = jnp.cos(x)
    assert float(jnp.max(jnp.abs(g - ref))) < 0.05
    q, s, meta = blockwise_quantize(g, 64)
    gr = blockwise_dequantize(q, s, meta)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(g), atol=1e-6)


def test_bottleneck_wire_ratio_and_shapes():
    specs = bottleneck_specs(64, 16)
    p = P.init(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 64))
    z = bn.compress(p, x)
    assert z.shape == (4, 10, 16)          # 4x fewer wire bytes
    y = bn.decompress(p, z)
    assert y.shape == x.shape


def test_maxout_compress():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 64))
    z = mx.compress(x, 4)
    assert z.shape == (2, 8, 16)
    specs = maxout_specs(64, 4)
    p = P.init(jax.random.PRNGKey(3), specs)
    y = mx.decompress(p, z)
    assert y.shape == x.shape


def test_compressed_bytes_accounting():
    from repro.compression.quant8 import compressed_bytes
    x = jnp.zeros(6400)
    assert compressed_bytes(x, 64) == 6400 + 4 * 100
