"""Serving layer: keyed executor slots, the session KV ledger,
prefill/decode disaggregated assignment, codec config normalization,
and ServeRunner end-to-end (token-for-token vs the single-process
reference, with and without span-peer churn)."""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense_config

from repro.core.ledger import SessionKVLedger
from repro.core.rebalance import serve_assignment, spans_route
from repro.core.swarm import SwarmConfig
from repro.runtime import StageState, build_numeric_executors
from repro.serve import ServeConfig, ServeRunner
from repro.serve.programs import KV_SLOT
from repro.serve.runner import reference_generate


# ---------------------------------------------------------------- slots
class TestKeyedSlotSnapshots:
    """snapshot/restore with ``slots=``: serving state rides along only
    when asked for, and a restore is a full install (unrequested slots
    are shed)."""

    def _peer_state(self):
        cfg = tiny_dense_config(n_layers=2)
        ex = build_numeric_executors(cfg, 1, seq_len=8)[0]
        state = StageState(params={"w": jnp.ones((2, 2))})
        ex.install_slot(state, KV_SLOT, "sess-0",
                        {"k": np.arange(4.0).reshape(2, 2)})
        return ex, state

    def test_default_snapshot_keeps_historical_format(self):
        ex, state = self._peer_state()
        snap = ex.snapshot(state)
        assert set(snap) == {"params", "opt", "version"}  # no slots key

    def test_snapshot_carries_requested_slot(self):
        ex, state = self._peer_state()
        snap = ex.snapshot(state, slots=(KV_SLOT,))
        assert "sess-0" in snap["slots"][KV_SLOT]
        np.testing.assert_array_equal(
            snap["slots"][KV_SLOT]["sess-0"]["k"],
            np.arange(4.0).reshape(2, 2))

    def test_restore_with_slots_installs_kv(self):
        ex, state = self._peer_state()
        snap = ex.snapshot(state, slots=(KV_SLOT,))
        other = StageState()
        ex.restore(other, snap, slots=(KV_SLOT,))
        got = ex.export_slot(other, KV_SLOT, "sess-0")
        np.testing.assert_array_equal(got["k"],
                                      np.arange(4.0).reshape(2, 2))

    def test_training_only_restore_sheds_kv(self):
        """Restoring a training snapshot into a serving peer evicts its
        sessions; restoring a kv snapshot without asking for the slot
        drops it on the floor."""
        ex, state = self._peer_state()
        snap = ex.snapshot(state, slots=(KV_SLOT,))
        ex.restore(state, ex.snapshot(state))      # training-only restore
        assert KV_SLOT not in state.slots
        ex.restore(state, snap)                    # kv present, not asked
        assert KV_SLOT not in state.slots
        ex.restore(state, snap, slots=(KV_SLOT,))
        assert "sess-0" in state.slot(KV_SLOT)

    def test_grads_never_ride_slot_snapshots(self):
        ex, state = self._peer_state()
        snap = ex.snapshot(state, slots=("grads", KV_SLOT))
        assert set(snap["slots"]) == {KV_SLOT}     # core slots excluded


# --------------------------------------------------------------- ledger
class TestSessionKVLedger:
    def test_exactly_once_is_a_hard_error(self):
        led = SessionKVLedger(3)
        led.record(1, "s0", "peerA")
        with pytest.raises(RuntimeError, match="double prefill"):
            led.record(1, "s0", "peerB")
        assert led.holder(1, "s0") == "peerA"      # first admit wins

    def test_transfer_moves_without_reprefill(self):
        led = SessionKVLedger(2)
        led.record(0, "s0", "prefiller")
        led.transfer(0, "s0", "decoder")
        assert led.holder(0, "s0") == "decoder"
        with pytest.raises(RuntimeError):          # still exactly-once
            led.record(0, "s0", "decoder")

    def test_peer_death_releases_only_its_rows(self):
        led = SessionKVLedger(4)
        for s in (0, 1):
            led.record(s, "s0", "p-lo")
        for s in (2, 3):
            led.record(s, "s0", "p-hi")
        lost = led.release_all("p-hi")
        assert sorted(lost) == [(2, "s0"), (3, "s0")]
        assert led.missing_stages("s0") == [2, 3]
        assert led.sessions_of("p-lo") == {"s0"}
        assert led.sessions_of("p-hi") == set()


# ----------------------------------------------------------- assignment
class TestServeAssignment:
    def test_both_pools_route(self):
        out = serve_assignment(n_prefill=3, n_decode=2, n_stages=6)
        assert spans_route(6, out["prefill"])
        assert spans_route(6, out["decode"])

    def test_prefill_refines_decode(self):
        """Every decode-span entry boundary is a prefill hop boundary —
        the invariant that guarantees wire history exists wherever a
        replacement decode peer needs to re-prefill."""
        out = serve_assignment(n_prefill=4, n_decode=3, n_stages=8,
                               stage_costs=[3, 1, 1, 1, 2, 1, 1, 2])
        cuts = {lo for lo, _ in out["prefill"]} | {8}
        for lo, hi in out["decode"]:
            assert lo in cuts and hi in cuts

    def test_decode_spans_fuse_wide(self):
        out = serve_assignment(n_prefill=4, n_decode=2, n_stages=4)
        d_width = np.mean([hi - lo for lo, hi in out["decode"]])
        p_width = np.mean([hi - lo for lo, hi in out["prefill"]])
        assert d_width >= p_width

    def test_empty_prefill_pool_serves_direct(self):
        out = serve_assignment(n_prefill=0, n_decode=2, n_stages=4)
        assert out["prefill"] == [] and spans_route(4, out["decode"])

    def test_decode_pool_prices_hops_at_whole_pipe(self):
        """Per-hop latency dominates decode, so the decode layout fuses
        each peer onto the full pipeline regardless of speed skew."""
        out = serve_assignment(n_prefill=2, n_decode=3, n_stages=6,
                               stage_costs=[5, 1, 1, 1, 1, 5],
                               decode_speeds=[1.0, 4.0, 0.5])
        assert out["decode"] == [(0, 6)] * 3


# ------------------------------------------------------- codec config
class TestCodecNormalization:
    def test_compress_bool_resolves_with_warning(self):
        with pytest.warns(DeprecationWarning, match="codec='int8'"):
            assert SwarmConfig(compress=True).codec == "int8"
        with pytest.warns(DeprecationWarning, match="codec='none'"):
            assert SwarmConfig(compress=False).codec == "none"

    def test_compress_str_passthrough(self):
        with pytest.warns(DeprecationWarning):
            assert SwarmConfig(compress="bottleneck").codec == "bottleneck"

    def test_conflicting_spellings_raise(self):
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="conflicting"):
                SwarmConfig(codec="none", compress=True)

    def test_replace_does_not_rewarn(self):
        with pytest.warns(DeprecationWarning):
            scfg = SwarmConfig(compress=True)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            scfg2 = dataclasses.replace(scfg, max_steps=3)
        assert scfg2.codec == "int8" and scfg2.max_steps == 3

    def test_default_and_validation(self):
        assert SwarmConfig().codec == "int8"       # historical default
        assert SwarmConfig(codec="auto").codec == "auto"
        with pytest.raises(ValueError):
            SwarmConfig(codec="zstd")


# ----------------------------------------------------------- end-to-end
S, NEW = 8, 6


def _prompts(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n, S))


class TestServeRunner:
    def test_disaggregated_matches_reference(self):
        """2 prefill + 2 decode peers over 4 stages: prefill KV hands
        off to the decode pool (ledger ``transfer``, never re-prefill)
        and greedy outputs equal the single-process program."""
        cfg = tiny_dense_config()
        r = ServeRunner(cfg, ServeConfig(n_stages=4, max_batch=2,
                                         max_sessions=2), seed=0)
        r.build_pools(n_prefill=2, n_decode=2)
        prompts = _prompts(cfg)
        reqs = [r.submit(p, NEW) for p in prompts]
        summary = r.run()
        ref = reference_generate(cfg, r.params, prompts, NEW)
        np.testing.assert_array_equal(np.stack([q.tokens for q in reqs]),
                                      ref)
        assert summary["failed"] == 0
        assert summary["reprefills"] == 0
        # every (stage, session) moved pools exactly once: 4 stages x
        # 2 session batches
        assert summary["kv_transfers"] == 4 * 2
        assert all(c == 0 for c in r.kv.stage_counts())  # all released

    def test_span_kill_reprefills_only_lost_stages(self):
        """Kill a decode span peer mid-generation: its replacement
        re-prefills EXACTLY the dead span's stages from the recorded
        boundary history; the surviving span's KV is reused.  The strict
        ledger raises on any double-prefill, so completion is proof of
        exactly-once."""
        cfg = tiny_dense_config()
        r = ServeRunner(cfg, ServeConfig(n_stages=4, max_batch=2,
                                         max_sessions=1), seed=0)
        for name, span in (("d0a", (0, 2)), ("d1a", (2, 4)),
                           ("d0b", (0, 2)), ("d1b", (2, 4))):
            r.add_peer(span, pool="decode", name=name)
        prompts = _prompts(cfg)
        reqs = [r.submit(p, NEW) for p in prompts]
        r.schedule_fail(0.045, "d1a")               # lands mid-decode
        summary = r.run()
        ref = reference_generate(cfg, r.params, prompts, NEW)
        np.testing.assert_array_equal(np.stack([q.tokens for q in reqs]),
                                      ref)
        assert summary["failed"] == 0
        assert summary["reprefills"] >= 1
        # recovery touched the dead (2, 4) span only — 2 stages per
        # re-prefill, never the surviving (0, 2) span's stages
        assert summary["reprefilled_stages"] == 2 * summary["reprefills"]
        assert all(c == 0 for c in r.kv.stage_counts())
