# NOTE: no XLA_FLAGS here — smoke tests must see exactly 1 device
# (the 512-device override belongs to launch/dryrun.py ONLY).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

try:
    import hypothesis  # noqa: F401  (real install: property tests run)
except ImportError:
    # CI installs hypothesis from requirements.txt; a container without it
    # still runs every plain test — only @given property tests skip.  The
    # stub satisfies import-time strategy construction (st.integers(...)
    # etc. are built while the module loads) and turns @given into a skip.
    import types

    class _AnyStrategy:
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*a, **k):
        del a, k
        return pytest.mark.skip(reason="hypothesis not installed")

    def _settings(*a, **k):
        del a, k
        return lambda f: f

    _h = types.ModuleType("hypothesis")
    _h.given, _h.settings = _given, _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _AnyStrategy()
    _extra = types.ModuleType("hypothesis.extra")
    _hnp = types.ModuleType("hypothesis.extra.numpy")
    _hnp.__getattr__ = lambda name: _AnyStrategy()
    _h.strategies, _h.extra, _extra.numpy = _st, _extra, _hnp
    for _name, _mod in [("hypothesis", _h), ("hypothesis.strategies", _st),
                        ("hypothesis.extra", _extra),
                        ("hypothesis.extra.numpy", _hnp)]:
        sys.modules[_name] = _mod


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_dense_config(**kw):
    from repro.models.config import ArchConfig
    base = dict(name="tiny", family="dense", n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                head_dim=16, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def reference_losses(cfg, programs, opt, seed, steps, seq, mb, gb,
                     data_seed=17):
    """Fault-free sequential single-stage-per-peer reference trajectory
    (same data order, same params init) — the oracle every churn-/
    runtime-/span-equivalence test compares a SwarmRunner against.  One
    copy: the accumulation and token-weighted averaging conventions here
    must stay in lockstep with ``SwarmRunner._all_reduce_and_step``."""
    import jax
    import jax.numpy as jnp
    from repro.data.synthetic import SyntheticLM
    from repro.runtime import init_stage_params

    S = len(programs)
    assert S >= 2
    params = init_stage_params(programs, jax.random.PRNGKey(seed))
    opt_states = [opt.init(p) for p in params]
    ds = SyntheticLM(cfg.vocab_size, seq, mb, seed=data_seed)
    idx, losses = 0, []
    for _ in range(steps):
        grads = [jax.tree.map(jnp.zeros_like, p) for p in params]
        loss_sum, tok = 0.0, 0
        for _ in range(gb // mb):
            b = ds.batch(idx)
            idx += 1
            xs = [b["tokens"]]              # per-stage boundary inputs
            for s in range(S - 1):
                xs.append(programs[s].fwd(params[s], xs[-1]))
            loss, gx, gp = programs[S - 1].bwd(params[S - 1], xs[-1],
                                               b["labels"])
            grads[S - 1] = jax.tree.map(jnp.add, grads[S - 1], gp)
            for s in range(S - 2, 0, -1):
                gx, gp = programs[s].bwd(params[s], xs[s], gx)
                grads[s] = jax.tree.map(jnp.add, grads[s], gp)
            _, gp = programs[0].bwd(params[0], xs[0], gx)
            grads[0] = jax.tree.map(jnp.add, grads[0], gp)
            loss_sum += float(loss)
            tok += mb * seq
        losses.append(loss_sum / tok)
        for s in range(S):
            gm = jax.tree.map(lambda g: g / tok, grads[s])
            upd, opt_states[s] = opt.update(gm, opt_states[s], params[s])
            params[s] = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                     params[s], upd)
    return losses
