# NOTE: no XLA_FLAGS here — smoke tests must see exactly 1 device
# (the 512-device override belongs to launch/dryrun.py ONLY).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

try:
    import hypothesis  # noqa: F401  (real install: property tests run)
except ImportError:
    # CI installs hypothesis from requirements.txt; a container without it
    # still runs every plain test — only @given property tests skip.  The
    # stub satisfies import-time strategy construction (st.integers(...)
    # etc. are built while the module loads) and turns @given into a skip.
    import types

    class _AnyStrategy:
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*a, **k):
        del a, k
        return pytest.mark.skip(reason="hypothesis not installed")

    def _settings(*a, **k):
        del a, k
        return lambda f: f

    _h = types.ModuleType("hypothesis")
    _h.given, _h.settings = _given, _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _AnyStrategy()
    _extra = types.ModuleType("hypothesis.extra")
    _hnp = types.ModuleType("hypothesis.extra.numpy")
    _hnp.__getattr__ = lambda name: _AnyStrategy()
    _h.strategies, _h.extra, _extra.numpy = _st, _extra, _hnp
    for _name, _mod in [("hypothesis", _h), ("hypothesis.strategies", _st),
                        ("hypothesis.extra", _extra),
                        ("hypothesis.extra.numpy", _hnp)]:
        sys.modules[_name] = _mod


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_dense_config(**kw):
    from repro.models.config import ArchConfig
    base = dict(name="tiny", family="dense", n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                head_dim=16, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)
