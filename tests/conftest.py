# NOTE: no XLA_FLAGS here — smoke tests must see exactly 1 device
# (the 512-device override belongs to launch/dryrun.py ONLY).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_dense_config(**kw):
    from repro.models.config import ArchConfig
    base = dict(name="tiny", family="dense", n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                head_dim=16, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)
