"""Baseline-system cost models (paper Table 2 comparisons)."""
from repro.core.baselines import gpipe, one_f1b, zero_offload
from repro.core.peer import V100
from repro.models.config import ArchConfig

XXLARGE = ArchConfig(name="xx4", family="dense", n_layers=4, d_model=4096,
                     n_heads=32, n_kv_heads=32, d_ff=16384, vocab_size=2,
                     act="gelu", tie_embeddings=True)
GPT3 = ArchConfig(name="g3", family="dense", n_layers=4, d_model=12288,
                  n_heads=96, n_kv_heads=96, d_ff=49152, vocab_size=2,
                  act="gelu", tie_embeddings=True)


def test_gpipe_bubble_hurts_few_microbatches():
    few = gpipe(XXLARGE, V100, n_microbatches=4)
    many = gpipe(XXLARGE, V100, n_microbatches=64)
    assert many.throughput > few.throughput
    # bubble fraction: (S-1)/(M+S-1)
    assert many.throughput / few.throughput > 1.3


def test_1f1b_matches_gpipe_steady_state():
    a = gpipe(XXLARGE, V100)
    b = one_f1b(XXLARGE, V100)
    assert abs(a.throughput - b.throughput) < 1e-9
    assert b.name == "1F1B"


def test_offload_allreduce_full_model_vs_stage():
    """Paper §4.2: ZeRO-Offload aggregates the ENTIRE model per peer,
    pipelines only one stage -> offload All-Reduce is several x larger."""
    g = gpipe(GPT3, V100)
    z = zero_offload(GPT3, V100)
    assert z.allreduce_time > 2.5 * g.allreduce_time


def test_square_cube_shifts_the_winner():
    """Offload's relative position degrades with model size (Table 2)."""
    rel_small = (zero_offload(XXLARGE, V100).throughput
                 / gpipe(XXLARGE, V100).throughput)
    rel_big = (zero_offload(GPT3, V100).throughput
               / gpipe(GPT3, V100).throughput)
    assert rel_big < rel_small
