"""Distribution layer: sharding rules + the GSPMD SWARM pipeline.

Multi-device cases run in a subprocess so the main test process keeps the
single-device view (the 512-device override is dryrun-only by design).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.sharding import DEFAULT_RULES
from repro.dist.pipeline import stage_periodic


def test_rules_divisibility_fallback():
    """kv_heads=4 on a 16-way model axis must fall back to replication."""
    class M:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    spec = DEFAULT_RULES.spec_for(("embed", "kv_heads", "head_dim"),
                                  (4096, 4, 128), M())
    assert tuple(spec) == ("data",)          # kv_heads dim dropped


def test_rules_no_double_axis_use():
    class M:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    spec = DEFAULT_RULES.spec_for(("mlp", "embed2"), (4096, 4096), M())
    # both map to 'model'; only the first may take it
    assert list(spec).count("model") <= 1


def test_stage_periodicity():
    assert stage_periodic(get_config("yi-6b"), 2)
    assert stage_periodic(get_config("xlstm-125m"), 2)       # (5m,1s)x2
    assert not stage_periodic(get_config("whisper-large-v3"), 2)
    assert not stage_periodic(get_config("swarm-1b"), 2)     # share_groups
    assert not stage_periodic(get_config("yi-6b"), 7)        # 32 % 7


_PIPELINE_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ArchConfig
    from repro.optim import adamw
    from repro.train.steps import make_train_step, make_state
    from repro.dist.pipeline import make_pipeline_train_step
    from repro.data import make_batch

    from repro.optim.adamw import Optimizer
    from repro.train.steps import make_loss_fn
    cfg = ArchConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                     head_dim=16, compute_dtype="float32",
                     param_dtype="float32", boundary_compression="none")
    # gradient-extractor optimizer: updated params = params + grads, so we
    # compare raw gradients (an adam step sign-normalizes tiny grads and
    # amplifies f32 reduction noise to O(lr))
    grad_opt = Optimizer(init=lambda p: {"z": jnp.zeros(())},
                         update=lambda g, s, p: (g, s))
    state = make_state(cfg, grad_opt, jax.random.PRNGKey(0))
    batch = make_batch(cfg.vocab_size, 32, 8)

    loss_fn = make_loss_fn(cfg, remat=False)
    (ref_loss, _), ref_g = jax.value_and_grad(loss_fn, has_aux=True)(
        state["params"], batch)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    pipe_step = make_pipeline_train_step(cfg, grad_opt, n_stages=2,
                                         n_microbatches=4, remat=False,
                                         compress="none")
    with mesh:
        out_state, m = jax.jit(pipe_step)(state, batch)
    print("ref", float(ref_loss), "pipe", float(m["loss"]))
    assert abs(float(ref_loss) - float(m["loss"])) < 1e-4
    pipe_g = jax.tree.map(lambda pn, p0: pn - p0, out_state["params"],
                          state["params"])
    for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(pipe_g)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=1e-3)
    print("PIPELINE_EQUIV_OK")
""")


@pytest.mark.slow
def test_pipeline_train_step_equals_reference():
    """The GSPMD shifting-buffer pipeline computes the SAME step as the
    plain train step (loss and updated params) on a 2x2x2 mesh."""
    r = subprocess.run([sys.executable, "-c", _PIPELINE_EQUIV],
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=600)
    assert "PIPELINE_EQUIV_OK" in r.stdout, r.stdout + r.stderr


_MIXED_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ArchConfig, SSMConfig
    from repro.optim.adamw import Optimizer
    from repro.train.steps import make_state, make_loss_fn
    from repro.dist.pipeline import make_pipeline_train_step
    from repro.data import make_batch

    # xlstm-style mixed-kind periodic stack: the per-stage params take the
    # slice-and-restack path, which the homogeneous tests never touch
    cfg = ArchConfig(name="tiny-x", family="ssm", n_layers=6, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                     head_dim=16, rope="none", act="gelu", norm="layernorm",
                     block_pattern=("mlstm", "mlstm", "slstm") * 2,
                     ssm=SSMConfig(state_dim=8, chunk=16),
                     compute_dtype="float32", param_dtype="float32",
                     boundary_compression="none")
    grad_opt = Optimizer(init=lambda p: {"z": jnp.zeros(())},
                         update=lambda g, s, p: (g, s))
    state = make_state(cfg, grad_opt, jax.random.PRNGKey(0))
    batch = make_batch(cfg.vocab_size, 32, 8)
    (ref_loss, _), ref_g = jax.value_and_grad(
        make_loss_fn(cfg, remat=False), has_aux=True)(state["params"], batch)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    pipe_step = make_pipeline_train_step(cfg, grad_opt, n_stages=2,
                                         n_microbatches=4, remat=False,
                                         compress="none")
    with mesh:
        out_state, m = jax.jit(pipe_step)(state, batch)
    print("ref", float(ref_loss), "pipe", float(m["loss"]))
    assert abs(float(ref_loss) - float(m["loss"])) < 1e-4
    pipe_g = jax.tree.map(lambda pn, p0: pn - p0, out_state["params"],
                          state["params"])
    for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(pipe_g)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=1e-3)
    print("MIXED_EQUIV_OK")
""")


@pytest.mark.slow
def test_pipeline_mixed_kind_equals_reference():
    """Mixed-kind periodic stacks (xlstm-style) must pipeline exactly too:
    guards the per-stage slice-and-restack path against the XLA SPMD
    sharded-concatenate miscompile (see dist/pipeline.py::_restack)."""
    r = subprocess.run([sys.executable, "-c", _MIXED_EQUIV],
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=600)
    assert "MIXED_EQUIV_OK" in r.stdout, r.stdout + r.stderr


_SPAN_MIXED_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ArchConfig, SSMConfig
    from repro.runtime.stage_model import (build_span_program,
                                           build_stage_programs,
                                           init_stage_params)
    from repro.data import make_batch

    # mixed-kind periodic stack, 4 stages of (mlstm, slstm): the span
    # [1, 3) covers TWO structurally identical interior stages, so the
    # span builder stacks their param trees with restack and scans over
    # the stage dim — the exact sharded-concat pattern the XLA 0.4.x
    # workaround guards (stacked leaves constrained over "pod")
    cfg = ArchConfig(name="tiny-x", family="ssm", n_layers=8, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                     head_dim=16, rope="none", act="gelu", norm="layernorm",
                     block_pattern=("mlstm", "slstm") * 4,
                     ssm=SSMConfig(state_dim=8, chunk=16),
                     compute_dtype="float32", param_dtype="float32",
                     boundary_compression="none")
    SEQ = 32
    progs = build_stage_programs(cfg, 4, SEQ)
    params = init_stage_params(progs, jax.random.PRNGKey(0))
    span = build_span_program(cfg, 4, SEQ, (1, 3))
    batch = make_batch(cfg.vocab_size, SEQ, 4)

    x1 = progs[0].fwd(params[0], batch["tokens"])
    # single-device reference: the chained per-stage programs
    x2 = progs[1].fwd(params[1], x1)
    x3_ref = progs[2].fwd(params[2], x2)
    loss_ref, gx3, gp3 = progs[3].bwd(params[3], x3_ref, batch["labels"])
    gx2_ref, gp2 = progs[2].bwd(params[2], x2, gx3)
    gx1_ref, gp1 = progs[1].bwd(params[1], x1, gx2_ref)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    with mesh:
        x3 = span.fwd(tuple(params[1:3]), x1)
        gx1, gp = span.bwd(tuple(params[1:3]), x1, gx3)
    # the 0.4.x miscompile corrupts stage s > 0 of the stack at ~3e-2;
    # legitimate whole-graph fusion noise sits at f32-ulp scale
    np.testing.assert_allclose(np.asarray(x3), np.asarray(x3_ref),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx1_ref),
                               atol=1e-4)
    for a, b in zip(jax.tree.leaves((gp1, gp2)), jax.tree.leaves(gp)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=1e-3)
    print("SPAN_MIXED_EQUIV_OK")
""")


@pytest.mark.slow
def test_span_program_mixed_kind_equals_reference():
    """The span builder's restack-and-scan path (structurally identical
    interior stages stacked over the leading dim, constrained to "pod")
    must match the chained single-stage programs on a mesh with a real
    pod axis: guards the XLA SPMD sharded-concatenate miscompile on the
    span path, the second call site of dist/pipeline.py::restack (see
    tests/test_pins.py)."""
    r = subprocess.run([sys.executable, "-c", _SPAN_MIXED_EQUIV],
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=600)
    assert "SPAN_MIXED_EQUIV_OK" in r.stdout, r.stdout + r.stderr


_INT8_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ArchConfig
    from repro.optim import adamw
    from repro.train.steps import make_train_step, make_state
    from repro.dist.pipeline import make_pipeline_train_step
    from repro.data import make_batch

    cfg = ArchConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                     head_dim=16, compute_dtype="float32",
                     param_dtype="float32")
    opt = adamw(lr=1e-2, grad_clip=0.0)
    state = make_state(cfg, opt, jax.random.PRNGKey(0))
    batch = make_batch(cfg.vocab_size, 32, 8)
    ref_step = jax.jit(make_train_step(cfg, opt, remat=False))
    _, ref_m = ref_step(state, batch)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    step = make_pipeline_train_step(cfg, opt, 2, 4, remat=False,
                                    compress="int8")
    with mesh:
        _, m = jax.jit(step)(state, batch)
    d = abs(float(ref_m["loss"]) - float(m["loss"]))
    print("loss delta under int8 boundaries:", d)
    assert d < 0.05          # paper App. J: 8-bit barely perturbs
    assert d > 0.0           # but it IS quantized
    print("INT8_PIPE_OK")
""")


@pytest.mark.slow
def test_pipeline_int8_boundary_compression():
    r = subprocess.run([sys.executable, "-c", _INT8_PIPELINE],
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=600)
    assert "INT8_PIPE_OK" in r.stdout, r.stdout + r.stderr
