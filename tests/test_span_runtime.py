"""Span-aware swarms: the PipelineExecutor backend (ISSUE 5 tentpole).

A peer may serve a contiguous span of stages [lo, hi) fused in one jit
(square-cube, paper §3.1; Varuna's stage fusion).  The load-bearing
properties:

* **churn equivalence** — a swarm mixing span peers with single-stage
  peers, learned bottleneck codec on, reproduces the all-single-stage
  fault-free reference trajectory at 2e-4 (the acceptance criterion),
  including a mid-run span SPLIT into single-stage peers and a MERGE
  back (Varuna-style re-partitioning);
* **exactly-once over spans** — a span peer holds one ledger row per
  covered stage; a re-issued attempt after a span-peer kill folds only
  the stages whose gradients died, skipping survivors;
* **state interop** — span ↔ single hand-offs move ordinary
  single-stage snapshots, so checkpoint cuts and peer downloads are
  span-agnostic;
* **compile accounting** — one fwd + one bwd jit per (span, codec)
  process-wide; wire codecs (int8) apply at span edges only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reference_losses, tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.core.sim import Sleep
from repro.optim import adamw
from repro.runtime import (PipelineExecutor, StageExecutor,
                           build_numeric_executors, compile_stats,
                           get_span_program, reset_compile_stats)
from test_churn import _assert_exactly_once

SEQ, MB, GB, STEPS = 32, 2, 8, 3


def _codec_cfg():
    return tiny_dense_config(boundary_compression="bottleneck",
                             bottleneck_dim=16)


def _scfg(n_stages, max_steps=STEPS, **kw):
    return SwarmConfig(n_stages=n_stages, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="bottleneck", max_steps=max_steps, **kw)


def _span_peer(runner, lo, hi):
    cfg, n = runner.cfg, runner.n_stages
    return runner.add_peer(range(lo, hi), executor=PipelineExecutor(
        cfg, n, SEQ, (lo, hi), compress="bottleneck"))


# --------------------------------------------------- mixed-swarm churn
def test_span_peer_in_mixed_swarm_equals_reference():
    """ISSUE 5 acceptance: a peer serving stages [0, 2) via
    PipelineExecutor in a mixed swarm (single-stage peers at both
    stages), learned codec on, under churn, matches the all-single-stage
    reference trajectory at 2e-4 — and is exactly-once accounted."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    runner = SwarmRunner(cfg, _scfg(2), opt, numeric=True, seed=0,
                         record_accumulation=True)
    runner.build(peers_per_stage=2)
    span_peer = _span_peer(runner, 0, 2)
    runner.apply_trace([TraceEvent(0.02, -1), TraceEvent(0.25, +1)])
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    assert m["failures"] == 1 and m["joins"] == 1
    # the span peer genuinely served (accumulated under BOTH stages)
    span_accs = {s for (k, _t, s, _i, _a, pid) in runner.ledger_log
                 if k == "acc" and pid == span_peer.id}
    assert span_accs == {0, 1}, span_accs
    ref = reference_losses(cfg, runner.programs, opt, 0, STEPS, SEQ, MB, GB)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 2, GB // MB)


def test_span_split_and_merge_equals_reference():
    """Satellite 1: a 2-peer swarm — spans [0, 2) and [2, 4) over a
    4-stage pipeline — reproduces the 4x-single-stage fault-free
    reference at 2e-4 with the bottleneck codec on, through a mid-run
    migration that SPLITS the first span into two single-stage peers
    and a MERGE back."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    runner = SwarmRunner(cfg, _scfg(4), opt, numeric=True, seed=0,
                         record_accumulation=True)
    A = _span_peer(runner, 0, 2)
    B = _span_peer(runner, 2, 4)
    runner.build(peers_per_stage=0)          # trainers only

    def script(r):
        yield Sleep(0.10)
        # split: a fresh peer warm-joins on [1, 2) (downloading stage 1
        # FROM the span peer), then A shrinks to [0, 1)
        yield from r.split_span(A, at=1)
        assert A.stages == range(0, 1), A.stages
        yield Sleep(0.10)
        C = next(p for p in r.peers.values()
                 if p.alive and p.serving and p.stages == range(1, 2))
        # merge back: A re-absorbs stage 1 (downloading it from C)
        yield from r.merge_spans(A, range(0, 2))
        assert A.stages == range(0, 2), A.stages
        # C leaving afterwards is safe — A covers stage 1 again
        r._fail_peer(C)

    runner.sim.spawn(script(runner))
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    assert m["span_changes"] == 2 and m["joins"] == 1
    assert m["failures"] == 1
    ref = reference_losses(cfg, runner.programs, opt, 0, STEPS, SEQ, MB, GB)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 4, GB // MB)


def test_span_peer_killed_midrun_recovers():
    """A dying span peer releases one ledger row per covered stage; its
    stages' state survives on the other peers (or re-joins via the span
    hand-off path) and the trajectory still matches the reference."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    runner = SwarmRunner(cfg, _scfg(2), opt, numeric=True, seed=0,
                         record_accumulation=True)
    runner.build(peers_per_stage=1)          # singles keep coverage
    span_peer = _span_peer(runner, 0, 2)

    def script(r):
        yield Sleep(0.06)
        r._fail_peer(span_peer)

    runner.sim.spawn(script(runner))
    m = runner.run(until=1e6)
    assert runner.step == STEPS and m["failures"] == 1
    rel = {(s, i) for (k, _t, s, i, _a, pid) in runner.ledger_log
           if k == "rel" and pid == span_peer.id}
    if rel:                                 # it held grads when it died
        assert {s for s, _ in rel} <= {0, 1}
    ref = reference_losses(cfg, runner.programs, opt, 0, STEPS, SEQ, MB, GB)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 2, GB // MB)


# --------------------------------------------------- wire accounting
def test_span_swarm_moves_fewer_host_bytes():
    """All-span peers vs all-single peers on the same seed: identical
    loss trajectory, strictly fewer (here: zero) boundary bytes through
    the host — the saved bytes the square-cube rebalancing buys."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)

    def run(span: bool):
        r = SwarmRunner(cfg, _scfg(2), opt, numeric=True, seed=0)
        if span:
            _span_peer(r, 0, 2)
            _span_peer(r, 0, 2)
            r.build(peers_per_stage=0)
        else:
            r.build(peers_per_stage=2)
        m = r.run(until=1e6)
        assert r.step == STEPS
        return m

    single = run(span=False)
    span = run(span=True)
    np.testing.assert_allclose(span["loss"], single["loss"], atol=2e-4)
    assert span["wire_bytes"] == 0.0
    assert single["wire_bytes"] > 0.0


# --------------------------------------------------- protocol / interop
def test_span_executor_protocol_and_for_span():
    cfg = _codec_cfg()
    pex = PipelineExecutor(cfg, 4, SEQ, (1, 3), compress="bottleneck")
    assert isinstance(pex, StageExecutor)
    assert pex.stages == range(1, 3) and pex.stage == 1
    assert pex.for_span(range(1, 3)) is pex
    assert pex.for_span(range(2, 3)).stages == range(2, 3)
    assert pex.for_stage(0).stages == range(0, 1)
    wide = pex.for_span(range(0, 4))
    assert isinstance(wide, PipelineExecutor)
    num = build_numeric_executors(cfg, 4, SEQ, compress="bottleneck")[0]
    assert num.for_span(range(0, 1)) is num
    grown = num.for_span(range(0, 2))
    assert isinstance(grown, PipelineExecutor)
    assert grown.stages == range(0, 2)


def test_span_snapshot_restore_interop_with_singles():
    """Per-stage snapshots cross span <-> single executors bitwise, and a
    span's whole-state snapshot round-trips."""
    cfg = _codec_cfg()
    num = build_numeric_executors(cfg, 2, SEQ, compress="bottleneck")
    pex = PipelineExecutor(cfg, 2, SEQ, (0, 2), compress="bottleneck")
    sts = [e.init_state(jax.random.PRNGKey(3)) for e in num]
    for st in sts:
        st.opt = adamw().init(st.params)
        st.version = 5
    pst = pex.init_state(jax.random.PRNGKey(4))
    for s in range(2):
        pex.restore(pst, num[s].snapshot(sts[s]), stage=s)
    assert pst.stage_view(0).version == 5
    for s in range(2):
        back = pex.snapshot(pst, stage=s)
        st2 = num[s].init_state(jax.random.PRNGKey(9))
        num[s].restore(st2, back)
        for a, b in zip(jax.tree.leaves(st2.params),
                        jax.tree.leaves(sts[s].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # a download never imports grads
        assert all(float(jnp.max(jnp.abs(x))) == 0.0
                   for x in jax.tree.leaves(st2.grad_acc))
    whole = pex.snapshot(pst)
    pst2 = pex.init_state(jax.random.PRNGKey(11))
    pex.restore(pst2, whole)
    for s in range(2):
        for a, b in zip(jax.tree.leaves(pst2.stage_view(s).params),
                        jax.tree.leaves(sts[s].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_span_matches_single_stage_chain():
    """One span fwd/bwd == the chained single-stage programs (identical
    math — the codec round-trip fuses on-device; only XLA's whole-graph
    fusion may reassociate at f32-ulp scale, hence the tight rtol, far
    below anything a wrong boundary/codec wiring would produce)."""
    cfg = _codec_cfg()
    from repro.data.synthetic import SyntheticLM
    num = build_numeric_executors(cfg, 2, SEQ, compress="bottleneck")
    pex = PipelineExecutor(cfg, 2, SEQ, (0, 2), compress="bottleneck")
    sts = [e.init_state(jax.random.PRNGKey(0)) for e in num]
    pst = pex.init_state(jax.random.PRNGKey(1))
    for s in range(2):
        pex.restore(pst, num[s].snapshot(sts[s]), stage=s)
    b = SyntheticLM(cfg.vocab_size, SEQ, MB, seed=17).batch(0)
    w = num[0].wire_fwd(num[0].run_fwd(sts[0], b["tokens"]))
    loss_ref = float(num[1].run_fwd(sts[1], w, b["labels"]))
    loss_span = float(pex.run_fwd(pst, b["tokens"], b["labels"]))
    np.testing.assert_allclose(loss_span, loss_ref, rtol=1e-6)
    loss, gx, gp = pex.run_bwd(pst, b["tokens"], labels=b["labels"])
    np.testing.assert_allclose(float(loss), loss_ref, rtol=1e-6)
    assert gx is None
    assert set(gp) == {0, 1}
    _, gx1, gp1 = num[1].run_bwd(sts[1], w, labels=b["labels"])
    _, gp0 = num[0].prog.bwd(sts[0].params, b["tokens"], gx1)
    for ref_t, got_t in ((gp0, gp[0]), (gp1, gp[1])):
        for a, c in zip(jax.tree.leaves(ref_t), jax.tree.leaves(got_t)):
            scale = float(jnp.max(jnp.abs(a))) + 1e-9
            np.testing.assert_allclose(np.asarray(a) / scale,
                                       np.asarray(c) / scale, atol=1e-5)


def test_int8_wire_codec_applies_at_span_edges_only():
    """A [0, 2) span of a 4-stage int8 pipeline quantizes its outbound
    edge (stage 1 -> 2) but NOT the fused 0 -> 1 boundary: its fwd equals
    the un-quantized two-stage chain, and its wire output the edge
    round-trip."""
    cfg = tiny_dense_config()            # int8 is cfg-default
    from repro.compression.quant8 import _roundtrip
    from repro.data.synthetic import SyntheticLM
    num = build_numeric_executors(cfg, 4, SEQ, compress="int8")
    pex = PipelineExecutor(cfg, 4, SEQ, (0, 2), compress="int8")
    sts = [e.init_state(jax.random.PRNGKey(0)) for e in num]
    pst = pex.init_state(jax.random.PRNGKey(1))
    for s in range(2):
        pex.restore(pst, num[s].snapshot(sts[s]), stage=s)
    b = SyntheticLM(cfg.vocab_size, SEQ, MB, seed=17).batch(0)
    y = pex.run_fwd(pst, b["tokens"])
    # fused boundary un-quantized: equals chaining raw stage fwds
    raw = num[1].run_fwd(sts[1], num[0].run_fwd(sts[0], b["tokens"]))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(raw))
    # ...and differs from the single-stage path, which quantizes 0 -> 1
    quant = num[1].run_fwd(
        sts[1], num[0].wire_fwd(num[0].run_fwd(sts[0], b["tokens"])))
    assert float(jnp.max(jnp.abs(raw - quant))) > 0.0
    # the span's outbound EDGE is quantized like any wire crossing
    np.testing.assert_array_equal(
        np.asarray(pex.wire_fwd(y)),
        np.asarray(_roundtrip(y, pex.quant_block)))


# --------------------------------------------------- span rebalancing
def test_rebalance_loop_shrinks_span_peer_onto_bottleneck():
    """SwarmConfig(spans=True): Alg. 2 proposes a span change and the
    runner executes it — with stage 1 genuinely hot (slow single-stage
    peers backing up behind it), the span peer covering it concentrates
    onto the bottleneck stage (its dropped stage keeps cover), the
    remaining layout still routes, and exactly-once accounting holds."""
    from repro.core import rebalance as rb
    from repro.core.peer import DeviceProfile, MBPS
    slow = DeviceProfile("slow", 5e8, 800 * MBPS, 800 * MBPS, 1e-4)
    fast = DeviceProfile("fast", 40e9, 800 * MBPS, 800 * MBPS, 1e-4)
    cfg = tiny_dense_config()
    scfg = SwarmConfig(n_stages=2, microbatch_size=1, seq_len=512,
                       global_batch=16, n_trainers=6,
                       rebalance_period=0.5, codec="none",
                       max_steps=30, spans=True)
    r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=0,
                    record_accumulation=True)
    r.build(peers_per_stage=2)
    for p in r.peers.values():               # stage-1 singles: the
        p.profile = slow if p.stage == 1 else fast   # bottleneck
    wide = r.add_peer(range(0, 2), profile=fast)
    r.run(until=60.0)
    assert r.metrics["span_changes"] >= 1
    assert wide.alive and len(wide.stages) == 1   # shrunk onto one stage
    # whatever sequence of moves ran, the serving layout still tiles
    layout = [(p.stages.start, p.stages.stop) for p in r.peers.values()
              if p.alive and p.serving]
    assert rb.spans_route(2, layout)
    _assert_exactly_once(r, 2, 16)


# --------------------------------------------------- compile accounting
def test_one_jit_per_span_and_codec():
    """N span peers of one (span, codec) share ONE fwd + ONE bwd jit;
    a second same-shape runner re-traces nothing."""
    reset_compile_stats()
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)

    def run(seed):
        r = SwarmRunner(cfg, _scfg(2, max_steps=1), opt, numeric=True,
                        seed=seed)
        _span_peer(r, 0, 2)
        _span_peer(r, 0, 2)
        r.build(peers_per_stage=0)
        r.run(until=1e6)

    run(seed=0)
    st = compile_stats()
    span_keys = {k: v for k, v in st["per_key"].items()
                 if (0, 2) in k}
    assert {k[-2] for k in span_keys} == {"fwd", "bwd"}
    assert all(v == 1 for v in span_keys.values()), span_keys
    run(seed=1)
    st2 = compile_stats()
    span_keys2 = {k: v for k, v in st2["per_key"].items()
                  if (0, 2) in k}
    assert span_keys2 == span_keys            # zero new traces
    # ...and the program object itself is cache-shared
    assert get_span_program(cfg, 2, SEQ, (0, 2), "bottleneck") is \
        get_span_program(cfg, 2, SEQ, (0, 2), "bottleneck")
