"""Stochastic wiring (Algorithm 1) — unit + property tests."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.wiring import StagePriorityQueue, StochasticWiring, INF


def test_single_server_always_chosen():
    w = StochasticWiring(1)
    w.add_server("a", [0])
    for _ in range(10):
        assert w.choose_server(0) == "a"


def test_ban_and_reannounce():
    w = StochasticWiring(1)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.ban_server("a")
    assert all(w.choose_server(0) == "b" for _ in range(20))
    w.add_server("a", [0])          # re-announced in the DHT
    chosen = {w.choose_server(0) for _ in range(20)}
    assert "a" in chosen


def test_refresh_unbans_reannounced_peer_same_stage():
    """A banned peer whose stage is UNCHANGED must be re-admitted when it
    re-announces in the DHT — pre-fix, refresh_from_dht only handled the
    stage-changed case, so transient bans (e.g. a routing race during a
    migration window) became permanent per-trainer blacklists."""
    w = StochasticWiring(1)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.ban_server("a")
    assert w.is_banned("a")
    assert all(w.choose_server(0) == "b" for _ in range(10))
    w.refresh_from_dht(None, {"a": 0, "b": 0})   # same stage, re-announced
    assert not w.is_banned("a")
    chosen = {w.choose_server(0) for _ in range(30)}
    assert "a" in chosen


def test_refresh_leaves_unbanned_peers_alone():
    """Re-announce of a healthy peer must not reset its priority (which
    would flood it with requests)."""
    w = StochasticWiring(1, gamma=1.0)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.observe("a", 1.0)
    w.observe("b", 1.0)
    for _ in range(10):
        w.choose_server(0)
    before = {s: w.queues[0].priority_of(s) for s in ("a", "b")}
    w.refresh_from_dht(None, {"a": 0, "b": 0})
    after = {s: w.queues[0].priority_of(s) for s in ("a", "b")}
    assert before == after


def test_empty_stage_returns_none():
    w = StochasticWiring(2)
    w.add_server("a", [0])
    assert w.choose_server(1) is None


def test_iwrr_proportional_allocation():
    """Paper §3.2: a device 2x faster gets 2x the requests."""
    w = StochasticWiring(1, gamma=1.0)
    w.add_server("fast", [0])
    w.add_server("slow", [0])
    w.observe("fast", 1.0)
    w.observe("slow", 2.0)
    counts = {"fast": 0, "slow": 0}
    for _ in range(3000):
        s = w.choose_server(0)
        counts[s] += 1
        w.observe(s, 1.0 if s == "fast" else 2.0)
    ratio = counts["fast"] / counts["slow"]
    assert 1.8 < ratio < 2.2, counts


@settings(max_examples=30, deadline=None)
@given(speeds=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6))
def test_iwrr_inverse_time_share_property(speeds):
    """Request share of peer i converges to (1/t_i) / sum(1/t_j)."""
    w = StochasticWiring(1, gamma=1.0)
    names = [f"p{i}" for i in range(len(speeds))]
    for n in names:
        w.add_server(n, [0])
    for n, t in zip(names, speeds):
        w.observe(n, t)
    counts = dict.fromkeys(names, 0)
    for _ in range(4000):
        s = w.choose_server(0)
        counts[s] += 1
    total_inv = sum(1.0 / t for t in speeds)
    for n, t in zip(names, speeds):
        expect = (1.0 / t) / total_inv
        share = counts[n] / 4000
        assert abs(share - expect) < 0.06, (n, share, expect)


def test_ema_update_rule():
    w = StochasticWiring(1, gamma=0.1, epsilon=0.5)
    w.add_server("a", [0])
    w.ema["a"] = 0.5                # pin the (jittered) prior
    w.observe("a", 1.5)
    assert math.isclose(w.ema["a"], 0.1 * 1.5 + 0.9 * 0.5)


def test_refresh_evicts_absent_peer():
    """Kill-without-ban: a reclaimed spot instance never says goodbye —
    its DHT records simply lapse.  ONE refresh against a snapshot that
    no longer lists the peer must drop it from routing, ``_stages_of``
    and ``ema`` (pre-fix it lingered forever: the ISSUE-10 leak)."""
    w = StochasticWiring(1)
    w.add_server("a", [0])
    w.add_server("dead", [0])
    for _ in range(10):
        s = w.choose_server(0)
        w.observe(s, 1.0)
    w.refresh_from_dht(None, {"a": 0})    # 'dead' absent: TTL expired
    assert "dead" not in w._stages_of
    assert "dead" not in w.ema
    assert all("dead" not in q._entries for q in w.queues)
    assert all(w.choose_server(0) == "a" for _ in range(20))


def test_refresh_evicted_peer_rejoins_like_new():
    """An evicted peer that re-announces later is re-discovered with a
    fresh prior, exactly like a first join."""
    w = StochasticWiring(1)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.refresh_from_dht(None, {"a": 0})
    assert "b" not in w._stages_of
    w.refresh_from_dht(None, {"a": 0, "b": 0})
    chosen = {w.choose_server(0) for _ in range(30)}
    assert "b" in chosen


def test_heap_compaction_bounded_under_bumps():
    """10k priority bumps over 4 servers must keep the physical heap
    O(#servers) — lazy deletion without compaction grows it
    O(#requests) for the life of the trainer (the ISSUE-10 leak)."""
    q = StagePriorityQueue()
    for i in range(4):
        q.update(f"p{i}", float(i))
    for _ in range(10_000):
        server, priority = q.top()
        q.update(server, priority + 1.0)
    # compaction triggers once invalidated entries outnumber live ones
    # past _COMPACT_MIN, so the heap never exceeds live + _COMPACT_MIN
    # + the handful pushed since the last rebuild
    bound = 2 * (4 + StagePriorityQueue._COMPACT_MIN)
    assert q.heap_size() <= bound, q.heap_size()
    # and the queue still routes: all four servers stay reachable
    assert sorted(q.servers()) == [f"p{i}" for i in range(4)]


def test_heap_compaction_with_bans_and_removes():
    """Interleaved bans (INF updates, never pushed) and removes must not
    corrupt the invalid-entry accounting that drives compaction."""
    q = StagePriorityQueue()
    for i in range(8):
        q.update(f"p{i}", float(i))
    for k in range(2_000):
        server, priority = q.top()
        q.update(server, priority + 1.0)
        if k % 97 == 0:
            q.update(f"p{k % 8}", INF)          # ban
            q.update(f"p{k % 8}", float(k))     # re-admit
        if k % 401 == 0:
            q.remove(f"p{(k + 3) % 8}")
            q.update(f"p{(k + 3) % 8}", float(k))
    assert q.heap_size() <= 2 * (8 + StagePriorityQueue._COMPACT_MIN)
    assert q.top() is not None


def test_move_server_between_stages():
    w = StochasticWiring(2)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.move_server("a", [1])
    assert w.choose_server(1) == "a"
    assert all(w.choose_server(0) == "b" for _ in range(5))
