"""Stochastic wiring (Algorithm 1) — unit + property tests."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.wiring import StochasticWiring, INF


def test_single_server_always_chosen():
    w = StochasticWiring(1)
    w.add_server("a", [0])
    for _ in range(10):
        assert w.choose_server(0) == "a"


def test_ban_and_reannounce():
    w = StochasticWiring(1)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.ban_server("a")
    assert all(w.choose_server(0) == "b" for _ in range(20))
    w.add_server("a", [0])          # re-announced in the DHT
    chosen = {w.choose_server(0) for _ in range(20)}
    assert "a" in chosen


def test_refresh_unbans_reannounced_peer_same_stage():
    """A banned peer whose stage is UNCHANGED must be re-admitted when it
    re-announces in the DHT — pre-fix, refresh_from_dht only handled the
    stage-changed case, so transient bans (e.g. a routing race during a
    migration window) became permanent per-trainer blacklists."""
    w = StochasticWiring(1)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.ban_server("a")
    assert w.is_banned("a")
    assert all(w.choose_server(0) == "b" for _ in range(10))
    w.refresh_from_dht(None, {"a": 0, "b": 0})   # same stage, re-announced
    assert not w.is_banned("a")
    chosen = {w.choose_server(0) for _ in range(30)}
    assert "a" in chosen


def test_refresh_leaves_unbanned_peers_alone():
    """Re-announce of a healthy peer must not reset its priority (which
    would flood it with requests)."""
    w = StochasticWiring(1, gamma=1.0)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.observe("a", 1.0)
    w.observe("b", 1.0)
    for _ in range(10):
        w.choose_server(0)
    before = {s: w.queues[0].priority_of(s) for s in ("a", "b")}
    w.refresh_from_dht(None, {"a": 0, "b": 0})
    after = {s: w.queues[0].priority_of(s) for s in ("a", "b")}
    assert before == after


def test_empty_stage_returns_none():
    w = StochasticWiring(2)
    w.add_server("a", [0])
    assert w.choose_server(1) is None


def test_iwrr_proportional_allocation():
    """Paper §3.2: a device 2x faster gets 2x the requests."""
    w = StochasticWiring(1, gamma=1.0)
    w.add_server("fast", [0])
    w.add_server("slow", [0])
    w.observe("fast", 1.0)
    w.observe("slow", 2.0)
    counts = {"fast": 0, "slow": 0}
    for _ in range(3000):
        s = w.choose_server(0)
        counts[s] += 1
        w.observe(s, 1.0 if s == "fast" else 2.0)
    ratio = counts["fast"] / counts["slow"]
    assert 1.8 < ratio < 2.2, counts


@settings(max_examples=30, deadline=None)
@given(speeds=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6))
def test_iwrr_inverse_time_share_property(speeds):
    """Request share of peer i converges to (1/t_i) / sum(1/t_j)."""
    w = StochasticWiring(1, gamma=1.0)
    names = [f"p{i}" for i in range(len(speeds))]
    for n in names:
        w.add_server(n, [0])
    for n, t in zip(names, speeds):
        w.observe(n, t)
    counts = dict.fromkeys(names, 0)
    for _ in range(4000):
        s = w.choose_server(0)
        counts[s] += 1
    total_inv = sum(1.0 / t for t in speeds)
    for n, t in zip(names, speeds):
        expect = (1.0 / t) / total_inv
        share = counts[n] / 4000
        assert abs(share - expect) < 0.06, (n, share, expect)


def test_ema_update_rule():
    w = StochasticWiring(1, gamma=0.1, epsilon=0.5)
    w.add_server("a", [0])
    w.ema["a"] = 0.5                # pin the (jittered) prior
    w.observe("a", 1.5)
    assert math.isclose(w.ema["a"], 0.1 * 1.5 + 0.9 * 0.5)


def test_move_server_between_stages():
    w = StochasticWiring(2)
    w.add_server("a", [0])
    w.add_server("b", [0])
    w.move_server("a", [1])
    assert w.choose_server(1) == "a"
    assert all(w.choose_server(0) == "b" for _ in range(5))
