"""The Pallas hot path (``cfg.kernels="pallas"``) is a pure backend
switch: kernel-vs-oracle equivalence for every fused op, gradient
equality across backends (the fused ops share one jnp backward), full
train-step equivalence on both shipping pipeline paths (GSPMD +
elastic), grad-flow through the fused boundary codec, and exactly-once
accounting under churn with the fused wire-quantized crossing on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reference_losses, tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.optim import adamw
from repro.runtime import build_stage_programs

SEQ, MB, GB, STEPS = 32, 2, 8, 2

CODEC_KW = dict(boundary_compression="bottleneck", bottleneck_dim=16,
                pipeline_stages=2)


def _cfg_pair(**kw):
    """(jnp, pallas) configs differing ONLY in the kernels flag."""
    return (tiny_dense_config(**kw),
            tiny_dense_config(kernels="pallas", **kw))


# ----------------------------------------------------- backend detection
def test_default_interpret_auto_detects_cpu():
    from repro.kernels.backend import default_interpret, resolve_interpret
    assert jax.default_backend() == "cpu"
    assert default_interpret() is True       # no TPU/GPU -> interpret
    assert resolve_interpret(None) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(True) is True


def test_quant8_ops_interpret_default_is_backend_aware():
    """quant8 wrappers no longer hard-code interpret=True: the default
    resolves from the backend (interpret on CPU), and an explicit policy
    threads through to the same numbers."""
    from repro.kernels.quant8.ops import roundtrip
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 37))
    auto = roundtrip(x, 64)                      # interpret=None -> auto
    forced = roundtrip(x, 64, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))


# ------------------------------------------------- kernel vs jnp oracle
@pytest.mark.parametrize("shape,qb", [((6, 64), 16), ((2, 5, 48), 16),
                                      ((128, 128), 64)])
def test_fused_qdq_matches_ref(shape, qb):
    from repro.kernels.boundary import kernel as K, ref as R
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 3.0
    np.testing.assert_allclose(np.asarray(K.qdq(x, qb)),
                               np.asarray(R.qdq_ref(x, qb)), atol=1e-6)


@pytest.mark.parametrize("n", [64, 100, 4096, 37])
def test_fused_flat_qdq_matches_quant8(n):
    """The single-launch flat round trip reproduces quant8's two-pass
    quantize/dequantize bit-for-bit geometry (incl. the padded tail
    block, whose zeros never raise an absmax)."""
    from repro.compression.quant8 import _roundtrip
    from repro.kernels.boundary.kernel import qdq_flat
    x = jax.random.normal(jax.random.PRNGKey(2), (n,)) * 2.0
    np.testing.assert_allclose(np.asarray(qdq_flat(x, 64)),
                               np.asarray(_roundtrip(x, 64)), atol=1e-6)


@pytest.mark.parametrize("mode,k", [("bottleneck", 1), ("maxout", 4)])
@pytest.mark.parametrize("quantize", [False, True])
def test_fused_codec_kernels_match_ref(mode, k, quantize):
    from repro.kernels.boundary import kernel as K, ref as R
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 64)) * 3.0
    w_c = (jax.random.normal(jax.random.PRNGKey(4), (64, 16)) * 0.2
           if mode == "bottleneck" else None)
    c = 16
    w_d = jax.random.normal(jax.random.PRNGKey(5), (c, 64)) * 0.2
    qb = R.wire_qblock(c)
    ze = R.encode_ref(x, w_c, mode, k)
    ref = R.qdq_ref(ze, qb) if quantize else ze
    np.testing.assert_allclose(
        np.asarray(K.encode(x, w_c, mode, k, qb, quantize)),
        np.asarray(ref), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(K.decode(ze, w_d, mode)),
        np.asarray(R.decode_ref(ze, w_d, mode)), atol=1e-5)
    # the true wire payload: int8 codes identical, scales/decode close
    q_r, s_r = R.encode_quantize_ref(x, w_c, mode, k, qb)
    q_k, s_k = K.encode_quantize(x, w_c, mode, k, qb)
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_k))
    np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_k),
                               atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(K.dequantize_decode(q_k, s_k, w_d, mode, qb)),
        np.asarray(R.dequantize_decode_ref(q_r, s_r, w_d, mode, qb)),
        atol=1e-5)


@pytest.mark.parametrize("mode,k", [("bottleneck", 1), ("maxout", 4)])
@pytest.mark.parametrize("quantized", [False, True])
def test_fused_codec_grads_match_backends_and_flow(mode, k, quantized):
    """Backends share one jnp backward: (dx, dw_c, dw_d) agree to f32
    rounding, the STE rides the wire QDQ, and both codec matrices keep
    training (nonzero grads)."""
    from repro.kernels.boundary import ops as O
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 24, 64)) * 2.0
    w_c = jax.random.normal(jax.random.PRNGKey(7), (64, 16)) * 0.2
    w_d = jax.random.normal(jax.random.PRNGKey(8), (16, 64)) * 0.2

    def loss(x, wc, wd, use_kernel):
        w = wc if mode == "bottleneck" else None
        z = O.encode_wire(x, w, mode, k, 16, quantized, use_kernel)
        return jnp.sum(O.decode_wire(z, wd, mode, use_kernel) ** 2)

    gp = jax.grad(loss, argnums=(0, 1, 2))(x, w_c, w_d, True)
    gj = jax.grad(loss, argnums=(0, 1, 2))(x, w_c, w_d, False)
    for a, b in zip(gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4)
    if mode == "bottleneck":
        assert float(jnp.max(jnp.abs(gp[1]))) > 0      # w_c trains
    assert float(jnp.max(jnp.abs(gp[2]))) > 0          # w_d trains


def test_flash_pallas_impl_matches_jnp_vjp():
    """flash_attention(impl="pallas"): fused forward kernel + the
    chunked jnp backward — out and (dq, dk, dv) equal the jnp path
    (GQA, causal)."""
    from repro.models.flash import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (2, 24, 4, 16))
    k = jax.random.normal(ks[1], (2, 24, 2, 16))
    v = jax.random.normal(ks[2], (2, 24, 2, 16))

    def loss(q, k, v, impl):
        return jnp.sum(flash_attention(q, k, v, causal=True,
                                       impl=impl) ** 2)

    np.testing.assert_allclose(
        float(loss(q, k, v, "pallas")), float(loss(q, k, v, "jnp")),
        rtol=1e-6)
    gp = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, "pallas")
    gj = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, "jnp")
    for a, b in zip(gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)


def test_rmsnorm_train_matches_autodiff():
    from repro.kernels.rmsnorm.ops import rmsnorm_train
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jax.random.normal(jax.random.PRNGKey(10), (6, 33, 64)) * 2.0
    s = jax.random.normal(jax.random.PRNGKey(11), (64,)) * 0.5 + 1.0
    f_k = lambda x, s: jnp.sum(jnp.sin(rmsnorm_train(x, s)))
    f_r = lambda x, s: jnp.sum(jnp.sin(rmsnorm_ref(x, s)))
    np.testing.assert_allclose(float(f_k(x, s)), float(f_r(x, s)),
                               rtol=1e-6)
    gk, gr = jax.grad(f_k, (0, 1))(x, s), jax.grad(f_r, (0, 1))(x, s)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)


# ------------------------------------------- full train-step equivalence
@pytest.mark.parametrize("wire_quant", [False, True])
def test_pipeline_train_step_pallas_matches_jnp(wire_quant):
    """One GSPMD pipelined train step, kernels="pallas" vs "jnp" at
    identical config/init/batch: loss within 1e-5, every gradient leaf
    within 1e-5 of the jnp path's (scale-normalized; the grad-identity
    optimizer makes the param delta the accumulated gradient, avoiding
    adam's amplification of f32 ULPs), boundary codec grads nonzero
    (the fused crossing ships on this path)."""
    from repro.data import make_batch
    from repro.dist.pipeline import make_pipeline_train_step
    from repro.optim.adamw import Optimizer
    from repro.train.steps import make_state
    cfg_j, cfg_p = _cfg_pair(wire_quant=wire_quant, **CODEC_KW)
    grad_opt = Optimizer(init=lambda p: {"z": jnp.zeros(())},
                         update=lambda g, s, p: (g, s))
    batch = make_batch(cfg_j.vocab_size, SEQ, GB)
    outs = {}
    for name, cfg in (("jnp", cfg_j), ("pallas", cfg_p)):
        state = make_state(cfg, grad_opt, jax.random.PRNGKey(0))
        assert "boundary" in state["params"]
        step = jax.jit(make_pipeline_train_step(cfg, grad_opt,
                                                n_stages=2,
                                                n_microbatches=4,
                                                remat=False))
        new_state, m = step(state, batch)
        delta = jax.tree.map(lambda a, b: a - b, new_state["params"],
                             state["params"])
        outs[name] = (float(m["loss"]), delta)
        for kk, g in delta["boundary"].items():
            assert float(jnp.max(jnp.abs(g))) > 0, kk
    assert abs(outs["pallas"][0] - outs["jnp"][0]) < 1e-5
    # wire_quant: a 1-ULP pre-rounding diff can flip an int8 code at an
    # exact tie, moving that element by scale/127 — so the quantized
    # variant gets a slightly looser (still tight) gradient bound
    tol = 1e-4 if wire_quant else 1e-5
    for a, b in zip(jax.tree.leaves(outs["pallas"][1]),
                    jax.tree.leaves(outs["jnp"][1])):
        scale = float(jnp.max(jnp.abs(b))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=tol)


@pytest.mark.parametrize("wire_quant", [False, True])
def test_elastic_run_pallas_matches_jnp(wire_quant):
    """The elastic path (numeric SwarmRunner, learned codec): the
    pallas-backed swarm reproduces the jnp swarm's loss trajectory at
    identical seed and sample order."""
    losses = {}
    for name, cfg in zip(("jnp", "pallas"),
                         _cfg_pair(wire_quant=wire_quant,
                                   boundary_compression="bottleneck",
                                   bottleneck_dim=16)):
        scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                           global_batch=GB, n_trainers=2,
                           rebalance_period=0.0, codec="bottleneck",
                           max_steps=STEPS)
        r = SwarmRunner(cfg, scfg, adamw(lr=1e-2), numeric=True, seed=0)
        r.build(peers_per_stage=2)
        m = r.run(until=1e6)
        assert r.step == STEPS
        losses[name] = m["loss"]
    # step 1 runs at identical params (matches to f32 rounding); step 2
    # adds an adamw update that amplifies ULP-level grad diffs, so the
    # bound is relative (tie-flipped int8 codes widen it under
    # wire_quant — see the pipeline test)
    np.testing.assert_allclose(losses["pallas"], losses["jnp"],
                               rtol=1e-4 if wire_quant else 1e-5)


def test_churn_exactly_once_pallas_wire_quant():
    """Exactly-once accounting survives churn with the fused
    wire-quantized pallas crossing on: failures + a warm join reproduce
    the fault-free reference trajectory (same fused codec in the
    sequential oracle), and no (stage, microbatch) pair is ever
    double-counted."""
    from test_churn import _assert_exactly_once
    cfg = tiny_dense_config(kernels="pallas", wire_quant=True,
                            boundary_compression="bottleneck",
                            bottleneck_dim=16)
    programs = build_stage_programs(cfg, 2, SEQ)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3,
                       rebalance_period=0.0, codec="bottleneck",
                       max_steps=STEPS)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                         programs=programs, record_accumulation=True)
    runner.build(peers_per_stage=3)
    runner.apply_trace([TraceEvent(0.05, -1), TraceEvent(0.22, +1)])
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    assert m["failures"] == 1 and m["joins"] == 1
    ref = reference_losses(cfg, programs, opt, 0, STEPS, SEQ, MB, GB)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 2, GB // MB)
