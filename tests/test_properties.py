"""System-invariant property tests (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.compression.quant8 import blockwise_quantize, blockwise_dequantize
from repro.models import rope as rope_lib
from repro.models import layers as L
from repro.core.faults import synth_preemptible_trace, active_counts
from repro.core.rebalance import optimal_assignment, pipeline_throughput, \
    spans_route


# ------------------------------------------------------------------ quant
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quantization_idempotent(seed):
    """quant(dequant(quant(x))) == quant(x): re-sending a quantized tensor
    over a second SWARM boundary is lossless."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 7
    q1, s1, meta = blockwise_quantize(x, 64)
    x1 = blockwise_dequantize(q1, s1, meta)
    q2, s2, _ = blockwise_quantize(x1, 64)
    x2 = blockwise_dequantize(q2, s2, meta)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=2e-6, atol=2e-6)


# ------------------------------------------------------------------ rope
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_rope_preserves_norms(seed):
    """Rotations are orthogonal: per-head vector norms are unchanged."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, 16, 4, 32))
    y = rope_lib.apply_rope(x, jnp.arange(16), 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_position_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j (the RoPE property that
    makes ring-buffer SWA caches valid: absolute slots don't matter)."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))

    def score(i, j):
        qr = rope_lib.apply_rope(q, jnp.array([i]), 10_000.0)
        kr = rope_lib.apply_rope(k, jnp.array([j]), 10_000.0)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(105, 103)) < 1e-4
    assert abs(score(7, 0) - score(1007, 1000)) < 1e-4


# ------------------------------------------------------- ring cache
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(2, 12))
def test_ring_place_keeps_last_window(S, W):
    """ring_place preserves exactly the last min(S, W) entries, each in
    slot t % W."""
    x = jnp.arange(S, dtype=jnp.float32).reshape(1, S, 1) + 1.0
    out = np.asarray(L.ring_place(x, W))[0, :, 0]
    kept = min(S, W)
    for t in range(S - kept, S):
        assert out[t % W] == t + 1
    # nothing else is non-zero
    assert (out != 0).sum() == kept


# ------------------------------------------------------------- traces
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_trace_never_kills_last_peer(seed):
    trace = synth_preemptible_trace(horizon_s=3600.0, target_peers=8,
                                    mean_lifetime_s=600.0, seed=seed)
    counts = active_counts(trace, 8, 3600.0, dt=10.0)
    assert counts.min() >= 1


def test_trace_deterministic():
    a = synth_preemptible_trace(seed=5, horizon_s=1800.0)
    b = synth_preemptible_trace(seed=5, horizon_s=1800.0)
    assert [(e.time, e.delta) for e in a] == [(e.time, e.delta) for e in b]


# ------------------------------------------------- span assignment
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 8), st.integers(1, 6),
       st.lists(st.floats(0.1, 8.0), min_size=8, max_size=8),
       st.lists(st.floats(0.2, 4.0), min_size=6, max_size=6),
       st.sampled_from([0.0, 0.25, 1.0]))
def test_span_assignment_covers_and_never_loses_throughput(
        n_peers, n_stages, speeds8, costs6, boundary_cost):
    """For random (n_peers, n_stages, speeds, costs), span-enabled
    optimal_assignment always yields (1) full stage coverage, (2) one
    valid non-overlapping contiguous span per peer, and (3)
    pipeline_throughput >= the span-free (width-1 greedy) assignment's
    — the square-cube guarantee: fusing stages may only help."""
    speeds = speeds8[:n_peers]
    costs = costs6[:n_stages]
    spans = optimal_assignment(n_peers, n_stages, costs, speeds=speeds,
                               spans=True, boundary_cost=boundary_cost)
    assert len(spans) == n_peers
    covered = set()
    for lo, hi in spans:
        # a peer's assignment is ONE contiguous [lo, hi): trivially free
        # of overlapping spans on that peer, and must be well-formed
        assert 0 <= lo < hi <= n_stages
        covered |= set(range(lo, hi))
    assert covered == set(range(n_stages))
    thr = pipeline_throughput(spans, speeds, stage_costs=costs,
                              boundary_cost=boundary_cost)
    assert thr > 0.0
    if n_peers >= n_stages:          # span-free placement exists at all
        free = optimal_assignment(n_peers, n_stages, costs, speeds=speeds,
                                  spans=True, boundary_cost=boundary_cost,
                                  max_span=1)
        assert all(hi - lo == 1 for lo, hi in free)
        thr_free = pipeline_throughput(free, speeds, stage_costs=costs,
                                       boundary_cost=boundary_cost)
        assert thr >= thr_free - 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.sampled_from([0.5, 1.0, 2.0]))
def test_single_peer_span_serves_whole_pipeline(n_stages, boundary_cost):
    """One peer can only cover the pipeline as the full span [0, S) —
    and with a boundary price, fusing beats the (impossible) alternative
    of paying 2 host edges per stage."""
    [span] = optimal_assignment(1, n_stages, spans=True,
                                boundary_cost=boundary_cost)
    assert tuple(span) == (0, n_stages)
    # count-form throughput with boundary pricing: width-1 stages pay
    # their host edges, so the fused span's rate is strictly higher
    fused = pipeline_throughput([(0, n_stages)], 1.0,
                                stage_costs=[1.0] * n_stages,
                                boundary_cost=boundary_cost)
    assert fused == 1.0 / n_stages   # interior boundaries cost nothing


@settings(max_examples=20, deadline=None)
@given(st.integers(65, 1000), st.integers(2, 48), st.integers(0, 10_000),
       st.sampled_from([0.0, 0.25, 1.0]))
def test_span_assignment_scales_to_preemptible_fleets(
        n_peers, n_stages, seed, boundary_cost):
    """ISSUE-10 fleet scale (above the exact-search peer limit): random
    heterogeneous fleets up to 1000 peers still get a routable,
    fully-covering span layout that never loses to the width-1 greedy
    placement."""
    rng = np.random.default_rng(seed)
    speeds = rng.uniform(0.1, 8.0, n_peers).tolist()
    costs = rng.uniform(0.2, 4.0, n_stages).tolist()
    spans = optimal_assignment(n_peers, n_stages, costs, speeds=speeds,
                               spans=True, boundary_cost=boundary_cost)
    assert len(spans) == n_peers
    assert spans_route(n_stages, [tuple(sp) for sp in spans])
    assert {s for lo, hi in spans
            for s in range(lo, hi)} == set(range(n_stages))
    thr = pipeline_throughput(spans, speeds, stage_costs=costs,
                              boundary_cost=boundary_cost)
    free = optimal_assignment(n_peers, n_stages, costs, speeds=speeds,
                              spans=True, boundary_cost=boundary_cost,
                              max_span=1)
    thr_free = pipeline_throughput(free, speeds, stage_costs=costs,
                                   boundary_cost=boundary_cost)
    assert thr >= thr_free - 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 8), st.integers(1, 6), st.integers(0, 10_000),
       st.sampled_from([0.0, 0.25, 1.0]))
def test_fast_span_path_matches_exact_search_on_small_fleets(
        n_peers, n_stages, seed, boundary_cost):
    """The heap-based candidate scan used above ``_EXACT_PEER_LIMIT``
    must reproduce the exhaustive search's decisions VERBATIM on the
    4-8 peer fixture sizes — forcing the fast path via the limit must
    not change a single span (the refactor's no-behavior-change bar)."""
    from repro.core import rebalance as rb
    rng = np.random.default_rng(seed)
    speeds = rng.uniform(0.1, 8.0, n_peers).tolist()
    costs = rng.uniform(0.2, 4.0, n_stages).tolist()
    exact = optimal_assignment(n_peers, n_stages, costs, speeds=speeds,
                               spans=True, boundary_cost=boundary_cost)
    old = rb._EXACT_PEER_LIMIT
    rb._EXACT_PEER_LIMIT = 0
    try:
        fast = optimal_assignment(n_peers, n_stages, costs,
                                  speeds=speeds, spans=True,
                                  boundary_cost=boundary_cost)
    finally:
        rb._EXACT_PEER_LIMIT = old
    assert [tuple(sp) for sp in fast] == [tuple(sp) for sp in exact]


# ------------------------------------------------------- stage plan
_PLAN_KINDS = ["attn", "moe", "mla", "mla_moe", "mlstm", "slstm",
               "mamba", "hymba"]


def _plan_cfg(block_kinds):
    from repro.models.config import (ArchConfig, MLAConfig, MoEConfig,
                                     SSMConfig)
    return ArchConfig(
        name="plan-prop", family="dense", n_layers=len(block_kinds),
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        block_pattern=tuple(block_kinds),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32),
        mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16),
        ssm=SSMConfig(state_dim=8, chunk=16))


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.data())
def test_stage_plan_segmentation_roundtrips(n_stages, per, data):
    """Random block_kinds: the plan's per-stage runs are exactly the
    stage slice's maximal same-kind segments, and concatenating the
    expanded runs over all stages reproduces the layer pattern — no
    layer lost, duplicated, or re-kinded by planning.  Summed per-stage
    flops reproduce the whole-model figure exactly (head included)."""
    from repro.models.model import segments
    from repro.models.stage_plan import make_stage_plan
    from repro.models import flops as F
    kinds = data.draw(st.lists(st.sampled_from(_PLAN_KINDS),
                               min_size=n_stages * per,
                               max_size=n_stages * per))
    cfg = _plan_cfg(kinds)
    plan = make_stage_plan(cfg, n_stages)
    assert plan.n_stages == n_stages
    flat = []
    for s, spec in enumerate(plan.stages):
        lo = s * per
        assert list(spec.runs) == segments(tuple(kinds[lo:lo + per]))
        for k, c in spec.runs:
            flat += [k] * c
        assert spec.owns_embed == (s == 0)
        assert spec.owns_head == (s == n_stages - 1)
    assert flat == list(kinds)
    total = sum(plan.stage_flops(s, 64) for s in range(n_stages))
    ref = F.forward_flops_per_token(cfg, 64)
    assert abs(total - ref) <= 1e-9 * max(ref, 1.0)


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 5), st.integers(1, 2), st.data())
def test_stage_plan_fusion_never_crosses_kind_boundary(n_stages, per,
                                                       data):
    """fusion_groups tiles any span with contiguous groups of
    structurally identical stages: a multi-stage scan group never mixes
    two different stage structures (the span falls back to sequential
    hand-off at kind boundaries)."""
    from repro.models.stage_plan import make_stage_plan
    kinds = data.draw(st.lists(st.sampled_from(_PLAN_KINDS),
                               min_size=n_stages * per,
                               max_size=n_stages * per))
    lo = data.draw(st.integers(0, n_stages - 1))
    hi = data.draw(st.integers(lo + 1, n_stages))
    plan = make_stage_plan(_plan_cfg(kinds), n_stages)
    groups = plan.fusion_groups((lo, hi))
    # groups tile [lo, hi) in order
    tiled = []
    for start, count in groups:
        assert count >= 1
        tiled += list(range(start, start + count))
    assert tiled == list(range(lo, hi))
    for start, count in groups:
        keys = {plan.stages[s].structural_key
                for s in range(start, start + count)}
        assert len(keys) == 1        # one structure per scan group
    # maximality: adjacent groups really differ (no gratuitous splits)
    for (s0, c0), (s1, _) in zip(groups, groups[1:]):
        assert plan.stages[s0].structural_key != \
            plan.stages[s1].structural_key


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(2, 5), st.integers(1, 2),
       st.data())
def test_stage_plan_priced_assignments_route(n_peers, n_stages, per,
                                             data):
    """optimal_assignment driven by plan stage rates + per-boundary wire
    prices still yields a routable span layout (spans_route), whatever
    the kind mix — per-kind pricing must never break coverage."""
    from repro.models.stage_plan import make_stage_plan
    kinds = data.draw(st.lists(st.sampled_from(_PLAN_KINDS),
                               min_size=n_stages * per,
                               max_size=n_stages * per))
    plan = make_stage_plan(_plan_cfg(kinds), n_stages)
    costs = list(plan.stage_costs(64))
    bcosts = list(plan.boundary_costs(1, 64, "int8"))
    spans = optimal_assignment(n_peers, n_stages, costs,
                               speeds=[1.0] * n_peers, spans=True,
                               boundary_cost=bcosts)
    assert spans_route(n_stages, [tuple(sp) for sp in spans])
    assert pipeline_throughput(spans, [1.0] * n_peers, stage_costs=costs,
                               boundary_cost=bcosts) > 0.0


# ----------------------------------------------------- attention masks
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 48), st.integers(1, 16))
def test_sliding_window_never_attends_outside(S, W):
    """flash(window=W) output at position t is independent of tokens
    older than t-W+1."""
    from repro.models.flash import flash_attention
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, S, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 8))
    out = flash_attention(q, k, v, causal=True, window=W,
                          chunk_q=16, chunk_k=16)
    # perturb the OLDEST token's k/v: last position unchanged iff S > W
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out2 = flash_attention(q, k2, v2, causal=True, window=W,
                           chunk_q=16, chunk_k=16)
    changed = float(jnp.max(jnp.abs(out[:, -1] - out2[:, -1])))
    if S > W:
        assert changed < 1e-5          # token 0 fell out of the window
    else:
        assert changed > 1e-4          # still visible
