"""The model's flash attention (custom VJP) vs the O(S^2) oracle:
forward AND gradients, across GQA/window/cross-length cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.attention import naive_attention, decode_attention


CASES = [
    (2, 128, 128, 8, 2, 32, 32, True, 0),
    (1, 100, 100, 4, 4, 16, 16, True, 24),
    (2, 64, 192, 6, 3, 24, 48, False, 0),
    (1, 96, 96, 2, 1, 64, 64, True, 0),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_fwd_and_grad_vs_naive(case):
    B, Sq, Sk, H, KV, Dq, Dv, causal, win = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dq))
    k = jax.random.normal(ks[1], (B, Sk, KV, Dq))
    v = jax.random.normal(ks[2], (B, Sk, KV, Dv))
    qo = Sk - Sq

    def f(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=win,
                               q_offset=qo, chunk_q=32, chunk_k=48)

    def n(q, k, v):
        return naive_attention(q, k, v, causal=causal, window=win,
                               q_offset=qo)

    np.testing.assert_allclose(np.asarray(f(q, k, v)),
                               np.asarray(n(q, k, v)), atol=2e-5)
    gf = jax.grad(lambda *a: jnp.sum(jnp.sin(f(*a))), argnums=(0, 1, 2))(
        q, k, v)
    gn = jax.grad(lambda *a: jnp.sum(jnp.sin(n(*a))), argnums=(0, 1, 2))(
        q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_decode_matches_full_attention_last_row():
    """Single-token decode over a cache == last row of full attention."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, D = 2, 33, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    full = naive_attention(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5)


def test_chunk_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 4, 32))
    v = jax.random.normal(ks[2], (1, 128, 4, 32))
    outs = [flash_attention(q, k, v, chunk_q=cq, chunk_k=ck)
            for cq, ck in [(32, 32), (64, 128), (128, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5)
