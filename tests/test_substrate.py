"""Substrate units: optimizers, data determinism, checkpointing,
square-cube law, simulation kernel."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sim import Sim, Sleep
from repro.core import square_cube as sc
from repro.data.synthetic import SyntheticLM
from repro.optim import adamw, lamb
from repro.ckpt import save_checkpoint, restore_checkpoint, latest_step


# ------------------------------------------------------------------ sim
def test_sim_ordering_and_time():
    sim = Sim()
    log = []

    def proc(name, dt):
        yield Sleep(dt)
        log.append((name, sim.now))

    sim.spawn(proc("b", 2.0))
    sim.spawn(proc("a", 1.0))
    sim.run()
    assert log == [("a", 1.0), ("b", 2.0)]


def test_sim_event_failure_propagates():
    sim = Sim()
    seen = []

    def waiter(ev):
        try:
            yield ev.wait()
        except RuntimeError:
            seen.append("failed")

    ev = sim.event()
    sim.spawn(waiter(ev))

    def failer():
        yield Sleep(1.0)
        ev.fail(RuntimeError("x"))

    sim.spawn(failer())
    sim.run()
    assert seen == ["failed"]


def test_sim_run_until():
    sim = Sim()

    def forever():
        while True:
            yield Sleep(10.0)

    sim.spawn(forever())
    assert sim.run(until=25.0) == 25.0


# ----------------------------------------------------------- square-cube
def test_square_cube_exponents():
    """Compute exponent ~> 1.7, comm exponent == 1 in d_model."""
    fe, ce = sc.scaling_exponents(sc.XXLARGE)
    assert fe > 1.6
    assert abs(ce - 1.0) < 1e-9


def test_utilization_monotone_in_model_size():
    """Fig. 3/Table 1 trend: bigger models -> higher GPU utilization."""
    utils = [sc.utilization(s, bandwidth_mbps=500.0)
             for s in (sc.BASE, sc.XXLARGE, sc.GPT3)]
    assert utils[0] < utils[1] < utils[2]


def test_quantized_boundary_improves_utilization():
    assert sc.utilization(sc.OURS, bandwidth_mbps=500.0) > \
        sc.utilization(sc.XXLARGE, bandwidth_mbps=500.0)


def test_latency_degrades_small_models_more():
    """Table 1: 100ms RTT hurts 'base' proportionally more than GPT-3."""
    def degradation(spec):
        u0 = sc.utilization(spec, bandwidth_mbps=500.0, rtt_s=0.0)
        u1 = sc.utilization(spec, bandwidth_mbps=500.0, rtt_s=0.1)
        return u1 / u0
    assert degradation(sc.BASE) < degradation(sc.GPT3)


# ------------------------------------------------------------------ data
def test_data_deterministic_and_host_shardable():
    ds = SyntheticLM(vocab_size=256, seq_len=16, global_batch=8, seed=3)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    h0 = ds.batch(5, host_index=0, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


def test_data_learnable_structure():
    """Order-2 markov stream: the next token is a function of history."""
    ds = SyntheticLM(vocab_size=256, seq_len=64, global_batch=4, seed=0)
    toks = np.asarray(ds.batch(0)["tokens"])
    assert toks.max() < 64 + 3 * 8          # confined to the state space


# ----------------------------------------------------------------- optim
def test_adamw_reduces_quadratic():
    opt = adamw(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_lamb_trust_ratio_scales_update():
    opt = lamb(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.full((4,), 100.0)}
    state = opt.init(params)
    upd, _ = opt.update({"w": jnp.full((4,), 1e-3)}, state, params)
    # layerwise trust ratio makes the step proportional to ||w|| (clipped
    # at trust_clip=10 -> |step| = lr*10 = 1.0 here)
    assert 0.99 <= float(jnp.max(jnp.abs(upd["w"]))) <= 100.0


@settings(max_examples=20, deadline=None)
@given(st.floats(1e-5, 1e-1))
def test_adamw_first_step_is_lr_sized(lr):
    opt = adamw(lr=lr, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.ones(2)}
    upd, _ = opt.update({"w": jnp.ones(2)}, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(upd["w"]), -lr, rtol=1e-3)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones(4, jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(d) == 12
    restored, step = restore_checkpoint(d, tree)
    assert step == 12
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) + 1)


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 0, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"zzz": jnp.ones(2)})


def test_checkpoint_restores_elsewhere_shape_checked(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 0, {"a": jnp.ones((2, 3))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.ones((3, 2))})
