"""DHT semantics: multi-writer keys, TTL expiration, staleness."""
from repro.core.dht import DHT


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_store_get_multiwriter():
    clk = FakeClock()
    dht = DHT(clk)
    dht.store("k", "a", 1, ttl=10)
    dht.store("k", "b", 2, ttl=10)
    recs = dht.get("k")
    assert {sk: r.value for sk, r in recs.items()} == {"a": 1, "b": 2}


def test_ttl_expiration():
    clk = FakeClock()
    dht = DHT(clk)
    dht.store("k", "a", 1, ttl=5)
    dht.store("k", "b", 2, ttl=50)
    clk.t = 10.0
    recs = dht.get("k")
    assert list(recs) == ["b"]


def test_reannounce_refreshes_ttl():
    clk = FakeClock()
    dht = DHT(clk)
    dht.store("k", "a", 1, ttl=5)
    clk.t = 4.0
    dht.store("k", "a", 1, ttl=5)     # re-announce (paper: every few min)
    clk.t = 8.0
    assert "a" in dht.get("k")


def test_overwrite_takes_latest_value():
    clk = FakeClock()
    dht = DHT(clk)
    dht.store("load/0", "p", 3.0, ttl=10)
    dht.store("load/0", "p", 7.0, ttl=10)
    assert dht.get_value("load/0", "p") == 7.0


def test_delete():
    clk = FakeClock()
    dht = DHT(clk)
    dht.store("k", "a", 1, ttl=10)
    dht.delete("k", "a")
    assert dht.get("k") == {}
