"""Dependency-pin guards.

The repo carries two shims that are only valid on the jax 0.4.x line
pinned in requirements.txt (``jax>=0.4.35,<0.5``):

* ``repro.dist.pipeline._restack`` — works around the XLA 0.4.x SPMD
  partitioner miscompiling a concatenate whose concat dim is sharded;
* ``repro._compat.AxisType`` — backports ``jax.sharding.AxisType`` /
  ``make_mesh(axis_types=...)``.

These tests FAIL the moment the pin (or the installed jax) crosses 0.5,
so whoever moves the pin is forced to re-evaluate both: re-test whether
plain ``jnp.stack`` partitions correctly (see
``test_distribution.py::test_pipeline_mixed_kind_equals_reference``) and
drop the shims if so.
"""
import os
import re

import jax

from repro.dist.pipeline import JAX_PIN_CEILING

_MSG = ("jax pin crossed {ceiling}: re-evaluate (1) the "
        "dist/pipeline.py::_restack XLA-SPMD concatenate workaround "
        "(plain jnp.stack may be safe now — run the mixed-kind pipeline "
        "equivalence test) and (2) the repro._compat AxisType/make_mesh "
        "shim (native in jax >= 0.5); drop them and this guard if they "
        "are no longer needed.")


def _requirements_jax_spec() -> str:
    path = os.path.join(os.path.dirname(__file__), "..", "requirements.txt")
    with open(path) as f:
        for line in f:
            line = line.split("#")[0].strip()
            if re.match(r"^jax([<>=!~\[]|$)", line):
                return line
    raise AssertionError("no jax pin found in requirements.txt")


def test_requirements_pin_below_ceiling():
    """The requirement must not admit any jax version at/past the
    ceiling — specifier-aware, so `jax==0.4.38` or `jax~=0.4.35` (both
    legal below-ceiling pins) pass while `jax>=0.4` fails."""
    from packaging.specifiers import SpecifierSet  # pytest dependency
    line = _requirements_jax_spec().replace(" ", "")
    spec = SpecifierSet(re.sub(r"^jax(\[[^\]]*\])?", "", line))
    ceiling = ".".join(map(str, JAX_PIN_CEILING))
    probes = [f"{ceiling}.0", "0.9.99", "1.0.0"]
    admitted = [v for v in probes if v in spec]
    assert not admitted, _MSG.format(ceiling=ceiling) + \
        f" (requirements.txt {line!r} admits {admitted})"


def test_installed_jax_below_ceiling():
    installed = tuple(int(x) for x in jax.__version__.split(".")[:2])
    assert installed < JAX_PIN_CEILING, \
        _MSG.format(ceiling=JAX_PIN_CEILING) + \
        f" (installed jax {jax.__version__})"
