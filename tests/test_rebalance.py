"""Adaptive rebalancing (Algorithm 2) — decision function + invariants."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dht import DHT
from repro.core.rebalance import (ControlSnapshot, plan_migration,
                                  plan_span_change, optimal_assignment,
                                  pipeline_throughput, spans_route,
                                  stage_loads)


class FakeClock:
    t = 0.0

    def __call__(self):
        return self.t


def _dht_with_loads(loads_per_stage):
    dht = DHT(FakeClock())
    pps = {}
    for s, loads in enumerate(loads_per_stage):
        pps[s] = []
        for i, q in enumerate(loads):
            pid = f"s{s}p{i}"
            dht.store(dht.load_key(s), pid, q, ttl=100)
            pps[s].append(pid)
    return dht, pps


def test_migrates_from_min_to_max_stage():
    dht, pps = _dht_with_loads([[0.1, 0.2, 0.3], [9.0]])
    mig = plan_migration(dht, 2, pps)
    assert mig is not None
    assert mig.src_stage == 0 and mig.dst_stage == 1
    assert mig.peer == "s0p0"       # smallest queue in the donor stage


def test_never_empties_a_stage():
    dht, pps = _dht_with_loads([[0.1], [9.0, 9.0]])
    assert plan_migration(dht, 2, pps) is None


def test_balanced_swarm_stays_put():
    dht, pps = _dht_with_loads([[1.0, 1.0], [1.0, 1.0]])
    mig = plan_migration(dht, 2, pps)
    assert mig is None


@settings(max_examples=50, deadline=None)
@given(n_peers=st.integers(3, 64), n_stages=st.integers(1, 8))
def test_optimal_assignment_invariants(n_peers, n_stages):
    if n_peers < n_stages:
        return
    alloc = optimal_assignment(n_peers, n_stages)
    assert sum(alloc) == n_peers
    assert all(a >= 1 for a in alloc)
    assert max(alloc) - min(alloc) <= 1      # uniform costs -> near-even


def test_throughput_weakest_link():
    assert pipeline_throughput([4, 1, 4]) == 1.0
    assert pipeline_throughput([2, 2, 2]) == 2.0


# ------------------------------------------------------------- spans
def _span_dht(loads_per_stage, peer_queues):
    """DHT where stage s's load records come from ``peer_queues`` (pid ->
    {stage: queue}); ``loads_per_stage`` only sanity-checks the sums."""
    dht = DHT(FakeClock())
    for pid, per_stage in peer_queues.items():
        for s, q in per_stage.items():
            dht.store(dht.load_key(s), pid, q, ttl=100)
    return dht


def test_span_change_splits_overloaded_span_onto_bottleneck():
    """A backlogged span peer covering the max-load stage shrinks onto
    that stage — provided every stage it drops keeps another cover."""
    spans = {"wide": (0, 2), "s0": (0, 1), "s1": (1, 2)}
    dht = _span_dht(None, {"wide": {0: 5.0, 1: 5.0},
                           "s0": {0: 0.1}, "s1": {1: 9.0}})
    ch = plan_span_change(dht, 2, spans)
    assert ch is not None
    assert ch.peer == "wide" and ch.new_span == (1, 2)


def test_span_change_never_strands_a_dropped_stage():
    """Same bottleneck, but nobody else covers stage 0: the wide peer
    may NOT shrink away from it."""
    spans = {"wide": (0, 2), "s1": (1, 2)}
    dht = _span_dht(None, {"wide": {0: 5.0, 1: 5.0}, "s1": {1: 9.0}})
    assert plan_span_change(dht, 2, spans) is None


def test_span_change_merges_into_well_covered_neighbor_when_balanced():
    """Balanced loads: the least-loaded peer absorbs an adjacent stage
    covered by >= 2 peers, deleting one host boundary for its traffic."""
    spans = {"a": (0, 1), "b": (1, 2), "c": (1, 2)}
    dht = _span_dht(None, {"a": {0: 1.0}, "b": {1: 0.5}, "c": {1: 0.5}})
    ch = plan_span_change(dht, 2, spans)
    assert ch is not None
    assert ch.peer == "a" and ch.new_span == (0, 2)


def test_span_change_no_merge_into_singly_covered_stage():
    spans = {"a": (0, 1), "b": (1, 2)}
    dht = _span_dht(None, {"a": {0: 1.0}, "b": {1: 1.0}})
    assert plan_span_change(dht, 2, spans) is None


def test_span_assignment_max_span_cap_raises_when_uncoverable():
    """An explicit width cap that cannot cover the pipe must raise the
    informative error, not crash on an empty candidate list."""
    with pytest.raises(ValueError, match="max_span"):
        optimal_assignment(2, 5, spans=True, max_span=2)
    with pytest.raises(ValueError, match="max_span"):
        optimal_assignment(2, 3, spans=True, max_span=1)
    # coverable caps still work
    spans = optimal_assignment(3, 5, spans=True, max_span=2)
    assert {s for lo, hi in spans for s in range(lo, hi)} == set(range(5))
    assert all(hi - lo <= 2 for lo, hi in spans)


def test_spans_route_needs_a_start_at_every_hop_boundary():
    """Coverage is weaker than routability: a hop enters a span only at
    its start, so the layout must chain 0 -> S through span edges."""
    assert spans_route(2, [(0, 2), (1, 2)])
    assert spans_route(3, [(0, 1), (1, 3)])
    assert spans_route(3, [(0, 2), (0, 1), (1, 3)])
    # covers every stage of a 3-stage pipe, but nothing starts at 2
    assert not spans_route(3, [(0, 2), (1, 2), (1, 3)])
    assert not spans_route(2, [(1, 2)])          # nothing starts at 0
    assert not spans_route(3, [(0, 2), (1, 3)])  # classic misalignment


def test_span_change_never_breaks_routability():
    """The exact trap sequence: {a:(0,2), b:(1,2), c:(2,3)} is balanced
    and stage 1 is double-covered, but growing c down to (1,3) would
    leave no span starting at boundary 2 — every microbatch would stall.
    The planner must skip that grow (and propose only routable moves)."""
    spans = {"a": (0, 2), "b": (1, 2), "c": (2, 3)}
    dht = _span_dht(None, {"a": {0: 1.0, 1: 1.0}, "b": {1: 1.0},
                           "c": {2: 2.0}})
    ch = plan_span_change(dht, 3, spans)
    if ch is not None:
        layout = [sp for pid, sp in spans.items() if pid != ch.peer]
        layout.append(ch.new_span)
        assert spans_route(3, layout), ch
        assert ch != ("c", (2, 3), (1, 3))


def test_span_change_split_tolerates_queue_jitter():
    """Sub-threshold load differences (announce jitter, uneven peer
    counts) must read as balanced — merges still fire — while a real
    bottleneck still splits."""
    # tiny asymmetry only: stays in the merge branch
    spans = {"a": (0, 1), "b": (1, 2), "c": (1, 2)}
    dht = _span_dht(None, {"a": {0: 0.003}, "b": {1: 0.001},
                           "c": {1: 0.001}})
    ch = plan_span_change(dht, 2, spans)
    assert ch is not None and ch.peer == "a" and ch.new_span == (0, 2)


def test_counts_assignment_raises_below_one_peer_per_stage():
    """``spans=False`` must allocate >= 1 peer per stage; a depleted pool
    gets the informative error (pointing at spans=True), not a crash or
    a silent zero-width stage."""
    with pytest.raises(ValueError, match="spans=True"):
        optimal_assignment(3, 4)
    with pytest.raises(ValueError, match="spans=True"):
        optimal_assignment(0, 2)
    # exactly one per stage is fine
    assert optimal_assignment(4, 4) == [1, 1, 1, 1]


# --------------------------------------------------- control snapshot
def test_snapshot_decisions_match_live_dht():
    """One ControlSnapshot shared across the round must reproduce the
    decisions of planners reading the DHT directly."""
    dht, pps = _dht_with_loads([[0.1, 0.2, 0.3], [9.0, 8.0]])
    snap = ControlSnapshot.capture(dht, 2)
    assert stage_loads(snap, 2) == stage_loads(dht, 2)
    assert plan_migration(snap, 2, pps) == plan_migration(dht, 2, pps)

    spans = {"wide": (0, 2), "s0": (0, 1), "s1": (1, 2)}
    dht2 = _span_dht(None, {"wide": {0: 5.0, 1: 5.0},
                            "s0": {0: 0.1}, "s1": {1: 9.0}})
    snap2 = ControlSnapshot.capture(dht2, 2)
    assert plan_span_change(snap2, 2, spans) == \
        plan_span_change(dht2, 2, spans)


def test_snapshot_is_frozen_against_later_writes():
    """Writes landing after capture must not leak into the round's
    decisions — that is the point of the per-round snapshot."""
    dht, pps = _dht_with_loads([[0.1, 0.2, 0.3], [9.0]])
    snap = ControlSnapshot.capture(dht, 2)
    dht.store(dht.load_key(0), "s0p0", 99.0, ttl=100)   # late announce
    mig = plan_migration(snap, 2, pps)
    assert mig is not None and mig.peer == "s0p0"       # pre-write view


def test_snapshot_stage_count_mismatch_raises():
    dht, pps = _dht_with_loads([[1.0], [1.0]])
    snap = ControlSnapshot.capture(dht, 2)
    with pytest.raises(ValueError, match="snapshot"):
        plan_migration(snap, 3, pps)


def test_repeated_migration_converges_to_balance():
    """Simulated Alg. 2 rounds on a queueing model reach near-balance.

    Per-peer backlog scales like work/alloc^2 (each stage has unit work;
    more peers both split the work and drain faster), so stage load is
    1/alloc — underprovisioned stages read as overloaded."""
    alloc = [6, 1, 1]
    for _ in range(8):
        loads = [[1.0 / alloc[s] ** 2] * alloc[s] for s in range(3)]
        dht, pps = _dht_with_loads(loads)
        mig = plan_migration(dht, 3, pps)
        if mig is None:
            break
        alloc[mig.src_stage] -= 1
        alloc[mig.dst_stage] += 1
    # near-balanced (Alg. 2 may oscillate between [2,3,3] permutations)
    assert max(alloc) - min(alloc) <= 1, alloc
