"""Adaptive rebalancing (Algorithm 2) — decision function + invariants."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dht import DHT
from repro.core.rebalance import (plan_migration, optimal_assignment,
                                  pipeline_throughput)


class FakeClock:
    t = 0.0

    def __call__(self):
        return self.t


def _dht_with_loads(loads_per_stage):
    dht = DHT(FakeClock())
    pps = {}
    for s, loads in enumerate(loads_per_stage):
        pps[s] = []
        for i, q in enumerate(loads):
            pid = f"s{s}p{i}"
            dht.store(dht.load_key(s), pid, q, ttl=100)
            pps[s].append(pid)
    return dht, pps


def test_migrates_from_min_to_max_stage():
    dht, pps = _dht_with_loads([[0.1, 0.2, 0.3], [9.0]])
    mig = plan_migration(dht, 2, pps)
    assert mig is not None
    assert mig.src_stage == 0 and mig.dst_stage == 1
    assert mig.peer == "s0p0"       # smallest queue in the donor stage


def test_never_empties_a_stage():
    dht, pps = _dht_with_loads([[0.1], [9.0, 9.0]])
    assert plan_migration(dht, 2, pps) is None


def test_balanced_swarm_stays_put():
    dht, pps = _dht_with_loads([[1.0, 1.0], [1.0, 1.0]])
    mig = plan_migration(dht, 2, pps)
    assert mig is None


@settings(max_examples=50, deadline=None)
@given(n_peers=st.integers(3, 64), n_stages=st.integers(1, 8))
def test_optimal_assignment_invariants(n_peers, n_stages):
    if n_peers < n_stages:
        return
    alloc = optimal_assignment(n_peers, n_stages)
    assert sum(alloc) == n_peers
    assert all(a >= 1 for a in alloc)
    assert max(alloc) - min(alloc) <= 1      # uniform costs -> near-even


def test_throughput_weakest_link():
    assert pipeline_throughput([4, 1, 4]) == 1.0
    assert pipeline_throughput([2, 2, 2]) == 2.0


def test_repeated_migration_converges_to_balance():
    """Simulated Alg. 2 rounds on a queueing model reach near-balance.

    Per-peer backlog scales like work/alloc^2 (each stage has unit work;
    more peers both split the work and drain faster), so stage load is
    1/alloc — underprovisioned stages read as overloaded."""
    alloc = [6, 1, 1]
    for _ in range(8):
        loads = [[1.0 / alloc[s] ** 2] * alloc[s] for s in range(3)]
        dht, pps = _dht_with_loads(loads)
        mig = plan_migration(dht, 3, pps)
        if mig is None:
            break
        alloc[mig.src_stage] -= 1
        alloc[mig.dst_stage] += 1
    # near-balanced (Alg. 2 may oscillate between [2,3,3] permutations)
    assert max(alloc) - min(alloc) <= 1, alloc
