"""End-to-end SWARM behaviour: synchronous-equivalence (App. E),
fault tolerance (App. A), rebalancing under churn, DPU semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.runtime import build_stage_programs, init_stage_params
from repro.data.synthetic import SyntheticLM
from repro.optim import adamw, delayed_parameter_updates


def _reference_losses(cfg, opt, n_steps, seq, mb, gb, seed=0,
                      data_seed=17):
    programs = build_stage_programs(cfg, 2, seq)
    params = init_stage_params(programs, jax.random.PRNGKey(seed))
    opt_states = [opt.init(p) for p in params]
    ds = SyntheticLM(cfg.vocab_size, seq, mb, seed=data_seed)
    idx, losses = 0, []
    for _ in range(n_steps):
        grads = [jax.tree.map(jnp.zeros_like, p) for p in params]
        loss_sum, tok = 0.0, 0
        for _ in range(gb // mb):
            b = ds.batch(idx)
            idx += 1
            x = programs[0].fwd(params[0], b["tokens"])
            loss, gx, gp1 = programs[1].bwd(params[1], x, b["labels"])
            _, gp0 = programs[0].bwd(params[0], b["tokens"], gx)
            grads[0] = jax.tree.map(jnp.add, grads[0], gp0)
            grads[1] = jax.tree.map(jnp.add, grads[1], gp1)
            loss_sum += float(loss)
            tok += mb * seq
        losses.append(loss_sum / tok)
        for s in range(2):
            gm = jax.tree.map(lambda g: g / tok, grads[s])
            upd, opt_states[s] = opt.update(gm, opt_states[s], params[s])
            params[s] = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                     params[s], upd)
    return losses, params


@pytest.fixture(scope="module")
def swarm_setup():
    cfg = tiny_dense_config()
    scfg = SwarmConfig(n_stages=2, microbatch_size=2, seq_len=32,
                       global_batch=8, n_trainers=3, rebalance_period=0.0,
                       codec="none", max_steps=3)
    return cfg, scfg


@pytest.mark.slow
def test_swarm_equals_synchronous_training(swarm_setup):
    """Paper App. E: SWARM's stepwise updates == conventional training."""
    cfg, scfg = swarm_setup
    opt = adamw(lr=1e-2, grad_clip=0.0)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=2)
    metrics = runner.run(until=1e6)
    ref_losses, ref_params = _reference_losses(cfg, opt, 3, 32, 2, 8)
    assert len(metrics["loss"]) == 3
    np.testing.assert_allclose(metrics["loss"], ref_losses, atol=2e-4)
    p_sw = next(p for p in runner.peers.values()
                if p.alive and p.stage == 0).state.params
    for a, b in zip(jax.tree.leaves(p_sw), jax.tree.leaves(ref_params[0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_swarm_survives_failures_and_joins(swarm_setup):
    cfg, scfg = swarm_setup
    import dataclasses
    scfg = dataclasses.replace(scfg, rebalance_period=2.0, codec="int8",
                               max_steps=4)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                         record_accumulation=True)
    runner.build(peers_per_stage=3)
    runner.apply_trace([TraceEvent(0.02, -2), TraceEvent(0.05, -1),
                        TraceEvent(0.3, +2)])
    m = runner.run(until=1e6)
    assert runner.step == 4
    assert m["failures"] == 3 and m["joins"] == 2
    # gradients lost with dead peers were recomputed by survivors (App. A)
    assert all(np.isfinite(m["loss"]))
    # ... exactly once: replay the ledger audit trail
    from test_churn import _assert_exactly_once
    _assert_exactly_once(runner, 2,
                         scfg.global_batch // scfg.microbatch_size)
    # every stage still servable
    for s in range(2):
        assert any(p.alive and p.serving and p.stage == s
                   for p in runner.peers.values())


@pytest.mark.slow
def test_swarm_loss_decreases():
    cfg = tiny_dense_config(n_layers=2)
    # 12 steps: at 8 the drop sits right on the 0.1 threshold (0.098);
    # 12 gives a deterministic 2x margin at the same lr
    scfg = SwarmConfig(n_stages=2, microbatch_size=4, seq_len=32,
                       global_batch=16, n_trainers=4, rebalance_period=0.0,
                       codec="int8", max_steps=12)
    opt = adamw(lr=3e-3, grad_clip=0.0)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=1)
    runner.build(peers_per_stage=2)
    m = runner.run(until=1e6)
    assert m["loss"][-1] < m["loss"][0] - 0.1, m["loss"]


@pytest.mark.slow
def test_8bit_compression_close_to_uncompressed():
    """App. J: 8-bit boundary compression barely perturbs the step."""
    cfg = tiny_dense_config(n_layers=2)
    losses = {}
    for codec in ("none", "int8"):
        scfg = SwarmConfig(n_stages=2, microbatch_size=2, seq_len=32,
                           global_batch=8, n_trainers=2,
                           rebalance_period=0.0, codec=codec,
                           max_steps=3)
        r = SwarmRunner(cfg, scfg, adamw(lr=1e-2, grad_clip=0.0),
                        numeric=True, seed=0)
        r.build(peers_per_stage=1)
        losses[codec] = r.run(until=1e6)["loss"]
    diff = max(abs(a - b)
               for a, b in zip(losses["int8"], losses["none"]))
    assert diff < 0.05, (losses, diff)


def test_dpu_one_step_delay_semantics():
    """DPU applies step t's gradients at step t+1 (App. E)."""
    opt = adamw(lr=1.0, b1=0.0, b2=0.999, weight_decay=0.0, grad_clip=0.0)
    dpu = delayed_parameter_updates(opt, delay=1)
    params = {"w": jnp.ones(3)}
    state = dpu.init(params)
    g1 = {"w": jnp.array([1.0, 0.0, 0.0])}
    upd, state = dpu.update(g1, state, params)
    assert float(jnp.max(jnp.abs(upd["w"]))) == 0.0     # nothing banked yet
    g2 = {"w": jnp.array([0.0, 1.0, 0.0])}
    upd, state = dpu.update(g2, state, params)
    # the applied update must correspond to g1, not g2
    assert abs(float(upd["w"][0])) > 0.5
    assert abs(float(upd["w"][1])) < 1e-6


def test_rebalancing_improves_throughput_under_churn():
    """Fig. 5 in miniature: rebalanced swarm beats no-rebalance."""
    cfg = tiny_dense_config(n_layers=4, d_model=1024, d_ff=4096,
                            vocab_size=5000)
    from repro.core.faults import synth_preemptible_trace
    trace = synth_preemptible_trace(horizon_s=1200.0, target_peers=16,
                                    mean_lifetime_s=900.0, seed=3)
    thr = {}
    for T in (0.0, 60.0):
        scfg = SwarmConfig(n_stages=2, microbatch_size=1, seq_len=128,
                           global_batch=64, n_trainers=8,
                           rebalance_period=T, codec="int8")
        r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=4)
        r.build(peers_per_stage=8)
        r.apply_trace(trace)
        r.run(until=1200.0)
        thr[T] = r.throughput()
    assert thr[60.0] >= thr[0.0] * 0.95   # at minimum never much worse
