"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracle, per the kernels/ contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quant8 import ops as q8
from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_ref
from repro.kernels.flash_attention.ops import (flash_attention_fwd,
                                               attention_ref)


@pytest.mark.parametrize("shape", [(64,), (1024,), (64, 64), (7, 130),
                                   (3, 5, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant8_kernel_matches_ref(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 5).astype(dtype)
    qa, sa, _ = q8.quantize(x, use_kernel=True)
    qb, sb, _ = q8.quantize(x, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))  # exact
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    a = q8.roundtrip(x, use_kernel=True)
    b = q8.roundtrip(x, use_kernel=False)
    # dequant multiply order may be fused differently: 1-ulp tolerance
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-6, atol=1e-6)
    assert a.dtype == dtype


@pytest.mark.parametrize("block", [32, 64, 128])
def test_quant8_blocks(block):
    x = jax.random.normal(jax.random.PRNGKey(1), (block * 9,))
    a = q8.roundtrip(x, block, use_kernel=True)
    b = q8.roundtrip(x, block, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(4, 128), (16, 256), (2, 3, 512),
                                   (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), shape).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(3), (shape[-1],)) + 1.0
    a = rmsnorm(x, s)
    b = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-2)


CASES = [
    # B, Sq, Sk, H, KV, D, causal, window, bq, bk
    (1, 256, 256, 4, 2, 64, True, 0, 128, 128),
    (2, 200, 200, 4, 4, 32, True, 64, 64, 128),
    (1, 128, 384, 8, 2, 64, False, 0, 128, 128),
    (1, 128, 128, 2, 1, 128, True, 0, 64, 64),     # MQA
    (1, 64, 64, 4, 4, 16, True, 0, 64, 64),        # single block
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_vs_ref(case, dtype):
    B, Sq, Sk, H, KV, D, causal, win, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D)).astype(dtype)
    o = flash_attention_fwd(q, k, v, causal, win, None, bq, bk, True)
    r = attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_matches_model_reference():
    """Pallas kernel vs the model's jnp flash path (custom VJP fwd)."""
    from repro.models.flash import flash_attention as model_flash
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 64))
    k = jax.random.normal(ks[1], (2, 128, 4, 64))
    v = jax.random.normal(ks[2], (2, 128, 4, 64))
    a = flash_attention_fwd(q, k, v, True, 0, None, 64, 64, True)
    b = model_flash(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
