"""Learned boundary codecs (paper App. J) end-to-end on both execution
paths, and the honest compression cost model.

Multi-device pipeline cases run in a subprocess so the main test process
keeps the single-device view (same pattern as tests/test_distribution.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense_config
from repro.compression import codecs
from repro.compression.quant8 import BLOCK, compressed_bytes
from repro.core import SwarmRunner, SwarmConfig
from repro.runtime import build_stage_programs, init_stage_params
from repro.models import flops as F
from repro.optim import adamw


# ------------------------------------------------------------ cost model
def test_boundary_bytes_int8_delegates_to_quant8():
    """One source of truth: the analytic int8 wire size must equal
    quant8.compressed_bytes exactly — including ceil-divided partial
    blocks, which the old ``n + 4 * n/64`` formula got wrong."""
    cfg = tiny_dense_config(d_model=100)      # 100 * 3 * 7 % BLOCK != 0
    x = jnp.zeros((3, 7, 100))
    assert F.boundary_bytes(cfg, 3, 7, "int8") == compressed_bytes(x)
    assert (3 * 7 * 100) % BLOCK != 0         # the padding case is hit


def test_boundary_bytes_real_codec_ratio():
    """Learned-codec bytes follow cfg.bottleneck_dim / maxout k, not a
    hardcoded 2x."""
    cfg = tiny_dense_config(bottleneck_dim=16)           # 64 -> 16: 4x
    assert F.boundary_bytes(cfg, 2, 8, "none") == 2 * 8 * 64 * 2
    assert F.boundary_bytes(cfg, 2, 8, "bottleneck") == 2 * 8 * 16 * 2
    cfg4 = tiny_dense_config(maxout_k=4)                 # 64 -> 16: 4x
    assert F.boundary_bytes(cfg4, 2, 8, "maxout") == 2 * 8 * 16 * 2
    # changing the config changes the bytes (the old bug: it didn't)
    wide = tiny_dense_config(bottleneck_dim=32)
    assert (F.boundary_bytes(wide, 2, 8, "bottleneck")
            == 2 * F.boundary_bytes(cfg, 2, 8, "bottleneck"))


def test_swarm_boundary_nbytes_matches_flops():
    """The sim charges exactly the analytic per-mode wire bytes."""
    cfg = tiny_dense_config(bottleneck_dim=16, maxout_k=4)
    for mode in codecs.MODES:
        scfg = SwarmConfig(n_stages=2, seq_len=32, codec=mode)
        r = SwarmRunner(cfg, scfg, adamw(), numeric=False)
        mb = r.next_microbatch()
        assert r.boundary_nbytes(mb) == F.boundary_bytes(
            cfg, mb.size, 32, mode)
    # booleans keep their historical meaning
    r = SwarmRunner(cfg, SwarmConfig(n_stages=2, seq_len=32, codec="int8"),
                    adamw(), numeric=False)
    assert r.compress_mode == "int8"


def test_baselines_see_codec_wire_bytes():
    """Fewer boundary bytes -> strictly higher pipeline throughput in the
    baseline cost model (the fixed formula propagates)."""
    from repro.core.baselines import gpipe
    from repro.core.peer import T4
    cfg = tiny_dense_config(bottleneck_dim=8)
    thr = {m: gpipe(cfg, T4, seq=512, compress=m).throughput
           for m in ("none", "bottleneck")}
    assert thr["bottleneck"] > thr["none"]


# ------------------------------------------------------------ elastic path
def test_elastic_codec_wire_shape_and_gradient_flow():
    """Stage programs emit the c-dim wire tensor, and w_c/w_d receive
    nonzero gradients through one fwd+bwd chain."""
    cfg = tiny_dense_config(bottleneck_dim=16, maxout_k=4)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 256)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 256)
    for mode in ("bottleneck", "maxout"):
        progs = build_stage_programs(cfg, 2, 32, compress=mode)
        params = init_stage_params(progs, jax.random.PRNGKey(0))
        y = progs[0].fwd(params[0], tokens)
        assert y.shape[-1] == codecs.wire_dim(cfg, mode) == 16
        loss, gx, gp1 = progs[1].bwd(params[1], y, labels)
        _, gp0 = progs[0].bwd(params[0], tokens, gx)
        assert np.isfinite(float(loss))
        assert gx.shape == y.shape          # backward wire is c-dim too
        assert float(jnp.max(jnp.abs(gp1["boundary"]["w_d"]))) > 0
        if mode == "bottleneck":
            assert float(jnp.max(jnp.abs(gp0["boundary"]["w_c"]))) > 0
        else:
            assert "boundary" not in params[0]   # maxout sender: param-free


def test_swarm_trains_with_learned_codecs():
    """Full elastic system: learned codecs train end-to-end and the
    optimizer updates the codec params (one step on the elastic path)."""
    cfg = tiny_dense_config(n_layers=2, bottleneck_dim=16)
    for mode in ("bottleneck", "maxout"):
        scfg = SwarmConfig(n_stages=2, microbatch_size=2, seq_len=32,
                           global_batch=4, n_trainers=2,
                           rebalance_period=0.0, codec=mode, max_steps=2)
        r = SwarmRunner(cfg, scfg, adamw(lr=1e-2, grad_clip=0.0),
                        numeric=True, seed=0)
        r.build(peers_per_stage=1)
        recv = next(p for p in r.peers.values() if p.stage == 1)
        w0 = np.asarray(recv.state.params["boundary"]["w_d"]).copy()
        m = r.run(until=1e6)
        assert len(m["loss"]) == 2 and all(np.isfinite(m["loss"]))
        w1 = np.asarray(recv.state.params["boundary"]["w_d"])
        assert np.abs(w1 - w0).max() > 0     # codec params were updated


def _reference_losses(cfg, opt, programs, n_steps, seq, mb, gb, seed=0,
                      data_seed=17):
    """Sequential twin of the elastic run: same stage programs (codec
    included), same data order, same token-weighted averaging."""
    from repro.data.synthetic import SyntheticLM
    params = init_stage_params(programs, jax.random.PRNGKey(seed))
    opt_states = [opt.init(p) for p in params]
    ds = SyntheticLM(cfg.vocab_size, seq, mb, seed=data_seed)
    idx, losses = 0, []
    for _ in range(n_steps):
        grads = [jax.tree.map(jnp.zeros_like, p) for p in params]
        loss_sum, tok = 0.0, 0
        for _ in range(gb // mb):
            b = ds.batch(idx)
            idx += 1
            x = programs[0].fwd(params[0], b["tokens"])
            loss, gx, gp1 = programs[1].bwd(params[1], x, b["labels"])
            _, gp0 = programs[0].bwd(params[0], b["tokens"], gx)
            grads[0] = jax.tree.map(jnp.add, grads[0], gp0)
            grads[1] = jax.tree.map(jnp.add, grads[1], gp1)
            loss_sum += float(loss)
            tok += mb * seq
        losses.append(loss_sum / tok)
        for s in range(2):
            gm = jax.tree.map(lambda g: g / tok, grads[s])
            upd, opt_states[s] = opt.update(gm, opt_states[s], params[s])
            params[s] = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                     params[s], upd)
    return losses


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["bottleneck", "maxout"])
def test_elastic_codec_equals_reference(mode):
    """App. E equivalence holds under learned codecs: the stochastic
    elastic run reproduces the sequential reference loss trajectory."""
    cfg = tiny_dense_config(bottleneck_dim=16, maxout_k=4)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    scfg = SwarmConfig(n_stages=2, microbatch_size=2, seq_len=32,
                       global_batch=8, n_trainers=3, rebalance_period=0.0,
                       codec=mode, max_steps=3)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=2)
    metrics = runner.run(until=1e6)
    programs = build_stage_programs(cfg, 2, 32, compress=mode)
    ref = _reference_losses(cfg, opt, programs, 3, 32, 2, 8)
    assert len(metrics["loss"]) == 3
    np.testing.assert_allclose(metrics["loss"], ref, atol=2e-4)


# ------------------------------------------------------------ GSPMD path
_CODEC_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ArchConfig
    from repro.optim.adamw import Optimizer
    from repro.train.steps import make_state
    from repro.dist.pipeline import (make_pipeline_train_step,
                                     make_reference_loss_fn)
    from repro.data import make_batch

    MODE = {mode!r}
    cfg = ArchConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                     head_dim=16, compute_dtype="float32",
                     param_dtype="float32", boundary_compression=MODE,
                     bottleneck_dim=16, maxout_k=4, pipeline_stages=2)
    grad_opt = Optimizer(init=lambda p: {{"z": jnp.zeros(())}},
                         update=lambda g, s, p: (g, s))
    state = make_state(cfg, grad_opt, jax.random.PRNGKey(0))
    assert "boundary" in state["params"]
    batch = make_batch(cfg.vocab_size, 32, 8)

    # staged sequential reference: SAME codec roundtrip per boundary, no
    # pipeline machinery (see dist/pipeline.py::make_reference_loss_fn)
    ref_fn = make_reference_loss_fn(cfg, 2, 4)
    (ref_loss, _), ref_g = jax.value_and_grad(ref_fn, has_aux=True)(
        state["params"], batch)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    pipe_step = make_pipeline_train_step(cfg, grad_opt, n_stages=2,
                                         n_microbatches=4, remat=False)
    with mesh:
        out_state, m = jax.jit(pipe_step)(state, batch)
    print("ref", float(ref_loss), "pipe", float(m["loss"]))
    assert abs(float(ref_loss) - float(m["loss"])) < 1e-4
    pipe_g = jax.tree.map(lambda pn, p0: pn - p0, out_state["params"],
                          state["params"])
    for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(pipe_g)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=1e-3)
    # gradient-flow: w_c/w_d receive nonzero grads after one step
    for k, g in pipe_g["boundary"].items():
        assert float(jnp.max(jnp.abs(g))) > 0, k
    print("CODEC_PIPE_OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["bottleneck", "maxout"])
def test_pipeline_codec_equals_staged_reference(mode):
    """The GSPMD pipeline with a learned codec computes the SAME step as
    the sequential staged reference on a 2x2x2 mesh — loss, layer grads,
    and nonzero codec grads (the wire buffer carries the c-dim tensor)."""
    r = subprocess.run([sys.executable, "-c",
                        _CODEC_PIPELINE.format(mode=mode)],
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=600)
    assert "CODEC_PIPE_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["bottleneck", "maxout"])
def test_pipeline_codec_training_trajectory(mode):
    """Acceptance: the pipelined step trains end-to-end with a real
    optimizer and tracks the staged reference loss trajectory within the
    suite's compression tolerance (per-step math is exact — see
    test_pipeline_codec_equals_staged_reference; adamw amplifies f32
    reduction noise to O(lr), hence the loose bound here)."""
    from repro.data import make_batch
    from repro.dist.pipeline import (make_pipeline_train_step,
                                     make_reference_loss_fn)
    from repro.train.steps import make_state
    cfg = tiny_dense_config(boundary_compression=mode, bottleneck_dim=16,
                            maxout_k=4, pipeline_stages=2)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    state_p = make_state(cfg, opt, jax.random.PRNGKey(0))
    state_r = jax.tree.map(lambda x: x, state_p)
    pipe = jax.jit(make_pipeline_train_step(cfg, opt, 2, 4, remat=False))
    ref_fn = make_reference_loss_fn(cfg, 2, 4)

    @jax.jit
    def ref_step(state, batch):
        (loss, _), g = jax.value_and_grad(ref_fn, has_aux=True)(
            state["params"], batch)
        upd, o = opt.update(g, state["opt"], state["params"])
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state["params"], upd)
        return {"params": params, "opt": o,
                "step": state["step"] + 1}, loss

    traj_p, traj_r = [], []
    for i in range(4):
        batch = make_batch(cfg.vocab_size, 32, 8, seed=i)
        state_p, m = pipe(state_p, batch)
        state_r, rl = ref_step(state_r, batch)
        traj_p.append(float(m["loss"]))
        traj_r.append(float(rl))
    np.testing.assert_allclose(traj_p, traj_r, atol=0.05)
    assert traj_p[-1] < traj_p[0]        # it actually learns


def test_pipeline_learned_codec_requires_declared_stages():
    """Clear error when the config doesn't carry the codec params."""
    from repro.dist.pipeline import make_pipeline_train_step
    cfg = tiny_dense_config(boundary_compression="bottleneck",
                            bottleneck_dim=16)    # pipeline_stages unset
    with pytest.raises(ValueError, match="pipeline_stages"):
        make_pipeline_train_step(cfg, adamw(), n_stages=2, n_microbatches=4)
