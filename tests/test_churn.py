"""Exactly-once elastic gradient accounting under churn (paper App. A).

The load-bearing correctness property of elastic training (cf. Varuna,
arXiv:2111.04007; DeDLOC, arXiv:2106.10207): every optimizer step
averages exactly ``global_batch`` samples even while peers fail, join,
and migrate — gradients lost with dead peers are recomputed by
survivors, and nothing is ever double-counted.  The churn-equivalence
tests assert the strong form: a numeric SwarmRunner replaying a
preemption trace (failures + a warm join + a migration) reproduces the
*fault-free* reference loss trajectory on the same sample set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reference_losses, tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent, MicrobatchLedger
from repro.core.faults import synth_preemptible_trace
from repro.core.sim import Sleep
from repro.runtime import build_stage_programs
from repro.optim import adamw

SEQ, MB, GB, STEPS = 32, 2, 8, 3


# ------------------------------------------------------------ ledger unit
def test_ledger_exactly_once_and_reissue():
    led = MicrobatchLedger(2)
    led.open_round([0, 1])
    assert led.next_index() == (0, 1)
    assert led.record(0, 0, "a")
    assert not led.record(0, 0, "a")        # double accumulation refused
    assert not led.record(0, 0, "b")        # also on another peer
    assert led.record(1, 0, "b")
    led.settle(0)
    assert led.next_index() == (1, 1)
    assert led.record(0, 1, "a") and led.record(1, 1, "b")
    led.settle(1)
    assert led.complete()
    assert led.next_index() is None
    # peer b dies: exactly its indices re-issue, as attempt 2
    assert sorted(led.release_peer(1, "b")) == [0, 1]
    assert not led.complete()
    assert led.next_index() == (0, 2)
    assert not led.record(0, 0, "c")        # stage 0 still holds it
    assert led.record(1, 0, "c")            # stage 1 recomputes
    led.settle(0)
    assert led.next_index() == (1, 2)
    assert led.record(1, 1, "c")
    led.settle(1)
    assert led.complete()


def test_ledger_release_during_flight_requeues_on_settle():
    led = MicrobatchLedger(2)
    led.open_round([5])
    assert led.next_index() == (5, 1)
    led.record(1, 5, "b")
    led.release_peer(1, "b")                # holder dies mid-flight
    assert led.next_index() is None         # still in flight: no re-issue
    led.record(0, 5, "a")
    led.settle(5)                           # flight ends -> stage 1 short
    assert led.next_index() == (5, 2)


def test_ledger_rejects_stale_round_indices():
    led = MicrobatchLedger(1)
    led.open_round([0, 1])
    led.next_index()
    led.open_round([2, 3])
    assert not led.record(0, 0, "a")        # previous round's index
    assert led.record(0, 2, "a")


# ------------------------------------------------------- span peers
def test_ledger_span_peer_holds_one_row_per_covered_stage():
    """A span peer admits each covered (stage, microbatch) pair exactly
    once — and a re-issued attempt after the span peer's death folds
    ONLY the stages whose grads died with it, skipping survivors."""
    led = MicrobatchLedger(3)
    led.open_round([0])
    assert led.next_index() == (0, 1)
    # stage 0 held by a single-stage survivor; the span peer covers
    # [1, 3) and records one row per covered stage
    assert led.record(0, 0, "single")
    assert led.record(1, 0, "span") and led.record(2, 0, "span")
    assert not led.record(1, 0, "span")     # exactly once per pair
    assert not led.record(2, 0, "other")
    led.settle(0)
    assert led.complete()
    # the span peer dies: exactly ITS rows release (both covered stages)
    assert sorted(led.release_all("span")) == [(1, 0), (2, 0)]
    assert led.next_index() == (0, 2)       # re-issued, attempt 2
    # the re-issue skips the surviving stage-0 gradient...
    assert not led.record(0, 0, "other")
    # ...and recomputes exactly the span's lost stages
    assert led.record(1, 0, "other") and led.record(2, 0, "other")
    led.settle(0)
    assert led.complete()


def test_swarm_accumulate_spans_all_covered_stages_exactly_once():
    """SwarmRunner.accumulate with a span peer: one ledger row + one
    fold per covered stage per microbatch, refused on re-delivery, and
    partial-fold when another peer already holds one covered stage."""
    cfg = tiny_dense_config()
    scfg = SwarmConfig(n_stages=2, microbatch_size=1, seq_len=64,
                       global_batch=4, n_trainers=0, rebalance_period=0.0,
                       codec="none", max_steps=1)
    r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=0,
                    record_accumulation=True)
    span_peer = r.add_peer(range(0, 2))      # timing-mode span peer
    single = r.add_peer(1)
    from repro.core.trainer import Microbatch
    mb = Microbatch(index=r.ledger.round_indices[0], size=1, n_tokens=64)
    assert r.accumulate(span_peer, None, mb, loss=1.0)
    assert r.ledger.acc[0][mb.index] == span_peer.id
    assert r.ledger.acc[1][mb.index] == span_peer.id
    # per-stage bookkeeping on the span state; loss lands on the LAST
    # stage only (the swarm's loss metric reads stage S-1)
    assert span_peer.state.stage_view(0).token_count == 64
    assert span_peer.state.stage_view(1).token_count == 64
    assert span_peer.state.stage_view(0).loss_sum == 0.0
    assert span_peer.state.stage_view(1).loss_sum == 1.0
    # re-delivery folds nothing anywhere
    assert not r.accumulate(span_peer, None, mb, loss=1.0)
    assert span_peer.state.stage_view(1).token_count == 64
    # a second microbatch partially held elsewhere: the span peer folds
    # only its missing stage
    mb2 = Microbatch(index=r.ledger.round_indices[1], size=1, n_tokens=64)
    assert r.accumulate(single, None, mb2, loss=None, stage=1)
    assert r.accumulate(span_peer, None, mb2, loss=2.0)
    assert r.ledger.acc[1][mb2.index] == single.id      # survivor kept
    assert r.ledger.acc[0][mb2.index] == span_peer.id
    assert span_peer.state.stage_view(0).token_count == 128
    assert span_peer.state.stage_view(1).token_count == 64
    assert span_peer.state.stage_view(1).loss_sum == 1.0  # loss skipped


def test_span_peer_kill_reissues_only_lost_stages_under_churn():
    """Runner-level: kill a span peer mid-round; every re-issued
    accumulation (attempt > 1) lands on a previously-released (stage,
    index) pair — stages whose grads survived on other peers are never
    folded twice (replayed from the audit trail)."""
    cfg = tiny_dense_config()
    scfg = SwarmConfig(n_stages=2, microbatch_size=1, seq_len=512,
                       global_batch=8, n_trainers=4, rebalance_period=0.0,
                       codec="none", max_steps=6)
    r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=3,
                    record_accumulation=True)
    r.build(peers_per_stage=2)
    span_peer = r.add_peer(range(0, 2))
    from repro.core.sim import Sleep as _Sleep

    def killer(rr, victim):
        # strike only while the victim HOLDS gradients of the open round
        # (a kill between rounds releases nothing and tests nothing)
        while not rr.stopped and victim.alive:
            if not rr._dispatch_paused and any(
                    victim.id in d.values() for d in rr.ledger.acc):
                rr._fail_peer(victim)
                return
            yield _Sleep(0.01)

    r.sim.spawn(killer(r, span_peer))
    r.run(until=60.0)
    assert r.step > 0 and r.metrics["failures"] == 1
    released = set()
    for kind, step, stage, idx, attempt, pid in r.ledger_log:
        key = (step, stage, idx)
        if kind == "rel":
            released.add(key)
        elif kind == "acc" and attempt > 1:
            # a recompute may only land where a gradient was lost
            assert key in released, (key, pid)
    assert any(pid == span_peer.id and kind == "rel"
               for kind, *_x, pid in r.ledger_log)
    _assert_exactly_once(r, 2, 8)


# ------------------------------------------------- churn equivalence
@pytest.fixture(scope="module")
def churn_setup():
    cfg = tiny_dense_config()
    programs = build_stage_programs(cfg, 2, SEQ)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    return cfg, programs, opt


def _reference_losses(cfg, programs, opt, seed):
    """Fault-free sequential twin (shared oracle in conftest)."""
    return reference_losses(cfg, programs, opt, seed, STEPS, SEQ, MB, GB)


def _force_migration(runner, at):
    """Deterministically migrate one peer out of a >1-serving stage."""
    yield Sleep(at)
    if runner.stopped:
        return
    for s in range(runner.n_stages):
        group = sorted((p for p in runner.peers.values()
                        if p.alive and p.serving and p.stage == s),
                       key=lambda p: p.id)
        if len(group) > 1:
            yield from runner._migrate(group[0],
                                       (s + 1) % runner.n_stages)
            return


def _assert_exactly_once(runner, n_stages, K):
    """Replay the ledger audit trail: a (round, stage, index) pair is
    never HELD twice (an accumulation while a prior one is still live is
    a double count; re-accumulating after a release is the recompute
    path and exact), and at each All-Reduce barrier every stage holds
    exactly the round's K indices."""
    held = set()
    for kind, step, stage, idx, attempt, pid in runner.ledger_log:
        key = (step, stage, idx)
        if kind == "acc":
            assert key not in held, \
                f"double accumulation: {key} attempt={attempt} peer={pid}"
            held.add(key)
        elif kind == "rel":
            assert key in held, f"release of unheld {key}"
            held.discard(key)
        else:                           # "step": the All-Reduce barrier
            for s in range(n_stages):
                n = sum(1 for (t, sg, _i) in held
                        if t == step and sg == s)
                assert n == K, (step, s, n)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_churn_equals_fault_free_reference(churn_setup, seed):
    """Failures + a warm join + a drained migration leave the loss
    trajectory bitwise-accounted: identical sample set per step, every
    lost gradient recomputed exactly once (mirrors
    test_swarm_equals_synchronous_training, but under churn)."""
    cfg, programs, opt = churn_setup
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="none", max_steps=STEPS)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=seed,
                         programs=programs, record_accumulation=True)
    runner.build(peers_per_stage=3)
    runner.apply_trace([TraceEvent(0.01 + 0.01 * seed, -1),
                        TraceEvent(0.05, -1),
                        TraceEvent(0.22, +1)])
    runner.sim.spawn(_force_migration(runner, at=0.12))
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    assert m["failures"] == 2 and m["joins"] == 1
    ref = _reference_losses(cfg, programs, opt, seed)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 2, GB // MB)


def test_revived_peer_serves_again(churn_setup):
    """Peer.revive wired into the trace joins: a dead peer object comes
    back warm — announced, un-banned, and accumulating."""
    cfg, programs, opt = churn_setup
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=2, rebalance_period=0.0,
                       codec="none", max_steps=STEPS)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                         programs=programs, record_accumulation=True)
    runner.build(peers_per_stage=2)
    runner.apply_trace([TraceEvent(0.02, -1), TraceEvent(0.1, +1)])
    m = runner.run(until=1e6)
    assert m["failures"] == 1 and m["joins"] == 1
    dead_then_back = [p for p in runner.peers.values() if p.alive
                      and p._generation > 0]
    assert len(dead_then_back) == 1          # the SAME object rejoined
    peer = dead_then_back[0]
    assert peer.serving
    # it re-entered the DHT (raw store: TTLs all lapse once the virtual
    # clock jumps to `until` at run end) and did real work after reviving
    assert any(peer.id in runner.dht._store.get(
        runner.dht.stage_key(s), {}) for s in range(runner.n_stages))
    assert any(kind == "acc" and pid == peer.id
               for (kind, *_rest, pid) in runner.ledger_log)
    np.testing.assert_allclose(
        m["loss"], _reference_losses(cfg, programs, opt, 0), atol=2e-4)


# ------------------------------------------------- invariant under heavy churn
def _run_throughput_churn(seed):
    cfg = tiny_dense_config()
    # impatient trainers (max_retries=2): attempts fail wholesale after
    # partial backward accumulation, exercising the re-issue path where
    # the pre-fix code double-counted surviving stages' gradients
    scfg = SwarmConfig(n_stages=2, microbatch_size=1, seq_len=512,
                       global_batch=16, n_trainers=6, rebalance_period=1.0,
                       codec="int8", max_steps=20, trainer_max_retries=2)
    r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=seed,
                    record_accumulation=True)
    r.build(peers_per_stage=3)
    # rounds last ~0.2 virtual seconds: a 3 s mean lifetime makes the
    # trace bite several times within the 20-step run
    r.apply_trace(synth_preemptible_trace(
        horizon_s=60.0, target_peers=6, mean_lifetime_s=3.0, seed=seed))
    r.run(until=120.0)
    return r


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ledger_invariant_under_heavy_churn(seed):
    """No (stage, microbatch) pair is ever accumulated twice, and every
    completed round holds the full global batch at every stage — under a
    hostile trace (mean lifetime 3 s) with rebalancing on."""
    r = _run_throughput_churn(seed)
    assert r.metrics["failures"] > 0         # the trace actually bites
    assert r.step > 0
    _assert_exactly_once(r, 2, 16)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_ledger_invariant_property(seed):
    """Hypothesis sweep of the same invariant over random traces."""
    r = _run_throughput_churn(seed % 997)
    _assert_exactly_once(r, 2, 16)
