"""Async stage execution (ISSUE 7): in-flight boundary transfers +
dispatch/collect + bounded-staleness All-Reduce.

The load-bearing properties:

* **delay=0 is bitwise** — turning overlap on changes WHEN boundary
  bytes move (they occupy the NIC links, not the compute queue), never
  WHAT is computed: with deterministic routing (one trainer, one peer
  per stage slot) the loss trajectory is float-for-float identical to
  the blocking tick, on the numeric, mesh, span, and mesh-span
  backends alike;
* **delay=1 is DPU** — a ``staleness=1`` runner (which wraps its
  optimizer in delayed parameter updates internally) reproduces the
  sequential DPU reference exactly (ATOM-style staleness accounting,
  paper §3.2);
* **churn equivalence survives overlap** — the test_churn trace
  (failures + warm join + forced migration) on an async swarm still
  matches the fault-free DPU reference at 2e-4, exactly-once accounted;
* **mesh spans** — ``MeshExecutor.for_span`` with width > 1 yields a
  device-placed span executor whose snapshots interop with single-stage
  executors and whose mixed-swarm trajectory matches the reference;
* **overlap never loses** — the rebalancer prices an overlapped edge at
  ``max(compute, wire)`` <= ``compute + wire`` serial.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reference_losses, tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.core.rebalance import pipeline_throughput
from repro.launch.mesh import make_peer_mesh
from repro.optim import adamw, delayed_parameter_updates
from repro.runtime import (MeshExecutor, MeshSpanExecutor,
                           PipelineExecutor, build_stage_programs)
from test_churn import _assert_exactly_once, _force_migration

SEQ, MB, GB, STEPS = 32, 2, 8, 3

BACKENDS = ("numeric", "mesh", "span", "mesh_span")


def _scfg(**kw):
    # one trainer: deterministic microbatch routing, so sync and async
    # runs see the identical (peer, sample) schedule — the precondition
    # for bitwise comparison (multi-trainer closeness is the churn test)
    base = dict(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                global_batch=GB, n_trainers=1, rebalance_period=0.0,
                codec="none", max_steps=STEPS)
    base.update(kw)
    return SwarmConfig(**base)


def _run(backend, seed, **scfg_kw):
    cfg = tiny_dense_config()
    r = SwarmRunner(cfg, _scfg(**scfg_kw), adamw(lr=1e-2, grad_clip=0.0),
                    numeric=True, seed=seed)
    if backend == "numeric":
        r.build(peers_per_stage=1)
    elif backend == "mesh":
        mesh = make_peer_mesh()
        for s in range(2):
            r.add_peer(s, executor=MeshExecutor(cfg, 2, SEQ, s, mesh))
        r.build(peers_per_stage=0)
    elif backend == "span":
        r.add_peer(range(0, 2), executor=PipelineExecutor(
            cfg, 2, SEQ, (0, 2)))
        r.build(peers_per_stage=0)
    else:                                    # mesh_span: for_span width 2
        base = MeshExecutor(cfg, 2, SEQ, 0, make_peer_mesh())
        r.add_peer(range(0, 2), executor=base.for_span(range(0, 2)))
        r.build(peers_per_stage=0)
    m = r.run(until=1e6)
    assert r.step == STEPS
    return r, m


# ------------------------------------------------- delay=0: bitwise
@pytest.mark.parametrize("backend", BACKENDS)
def test_overlap_delay0_bitwise_equals_sync(backend):
    """overlap=True, staleness=0 reorders only the virtual clock: the
    loss floats are IDENTICAL to the blocking tick on every backend."""
    _, sync = _run(backend, seed=0)
    ra, asy = _run(backend, seed=0, overlap=True)
    assert asy["loss"] == sync["loss"], (backend, asy["loss"], sync["loss"])
    # and the async run genuinely put boundary bytes in flight
    assert asy["inflight_bytes"] > 0
    assert asy["overlap_fraction"] >= 0
    if backend in ("numeric", "mesh"):
        # a whole-pipe span peer has no peer-to-peer edge to hide, so a
        # positive hidden fraction is only guaranteed with >= 2 peers
        assert asy["overlap_fraction"] > 0
    assert all(v >= 0.0 for v in asy["peer_idle_s"].values())


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_overlap_delay0_bitwise_property(seed):
    """Hypothesis sweep of the bitwise property over init seeds."""
    seed %= 997
    _, sync = _run("numeric", seed=seed)
    _, asy = _run("numeric", seed=seed, overlap=True)
    assert asy["loss"] == sync["loss"]


def test_overlap_finishes_no_later_than_sync():
    """Hiding wire behind compute can only shrink the virtual makespan."""
    rs, _ = _run("numeric", seed=0)
    ra, _ = _run("numeric", seed=0, overlap=True)
    assert ra.sim.now <= rs.sim.now + 1e-9, (ra.sim.now, rs.sim.now)


# ------------------------------------------------- delay=1: DPU
def test_staleness1_equals_sequential_dpu_reference():
    """A staleness=1 runner wraps its optimizer in DPU internally; its
    trajectory equals the sequential reference driven by an explicitly
    DPU-wrapped optimizer — staleness accounting is exact, not lossy."""
    cfg = tiny_dense_config()
    _, m = _run("numeric", seed=0, overlap=True, staleness=1)
    programs = build_stage_programs(cfg, 2, SEQ)
    ref_opt = delayed_parameter_updates(adamw(lr=1e-2, grad_clip=0.0),
                                        delay=1)
    ref = reference_losses(cfg, programs, ref_opt, 0, STEPS, SEQ, MB, GB)
    np.testing.assert_array_equal(m["loss"], ref)


def test_dpu_flag_implies_staleness():
    scfg = _scfg(dpu=True)
    assert scfg.staleness == 1
    with pytest.raises(ValueError):
        _scfg(staleness=-1)


# ------------------------------------------------- churn equivalence
@pytest.mark.parametrize("seed", [0, 1])
def test_async_churn_equals_dpu_reference(seed):
    """The test_churn trace (2 failures, a warm join, a forced
    migration) on an OVERLAPPED, staleness=1 swarm still reproduces the
    fault-free sequential DPU trajectory at 2e-4 — the exactly-once
    ledger is oblivious to transfers being in flight."""
    cfg = tiny_dense_config()
    programs = build_stage_programs(cfg, 2, SEQ)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="none", max_steps=STEPS, overlap=True,
                       staleness=1)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=seed,
                         programs=programs, record_accumulation=True)
    runner.build(peers_per_stage=3)
    runner.apply_trace([TraceEvent(0.01 + 0.01 * seed, -1),
                        TraceEvent(0.05, -1),
                        TraceEvent(0.22, +1)])
    runner.sim.spawn(_force_migration(runner, at=0.12))
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    assert m["failures"] == 2 and m["joins"] == 1
    ref = reference_losses(
        cfg, programs, delayed_parameter_updates(opt, delay=1), seed,
        STEPS, SEQ, MB, GB)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 2, GB // MB)


# ------------------------------------------------- mesh spans (width > 1)
def test_mesh_for_span_widths():
    cfg = tiny_dense_config()
    mex = MeshExecutor(cfg, 2, SEQ, 0, make_peer_mesh())
    wide = mex.for_span(range(0, 2))
    assert isinstance(wide, MeshSpanExecutor)
    assert wide.stages == range(0, 2)
    assert wide.for_span(range(0, 2)) is wide
    narrow = wide.for_span(range(1, 2))
    assert isinstance(narrow, MeshExecutor) and narrow.stage == 1
    assert mex.for_span(range(0, 1)) is mex


def test_mesh_span_snapshot_interop_with_singles():
    """Per-stage snapshots cross MeshSpanExecutor <-> single-stage
    executors bitwise, and the whole-state snapshot round-trips."""
    from repro.runtime import build_numeric_executors
    cfg = tiny_dense_config()
    num = build_numeric_executors(cfg, 2, SEQ)
    mspan = MeshExecutor(cfg, 2, SEQ, 0,
                         make_peer_mesh()).for_span(range(0, 2))
    sts = [e.init_state(jax.random.PRNGKey(3)) for e in num]
    for st_ in sts:
        st_.opt = adamw().init(st_.params)
        st_.version = 5
    pst = mspan.init_state(jax.random.PRNGKey(4))
    for s in range(2):
        mspan.restore(pst, num[s].snapshot(sts[s]), stage=s)
    assert pst.stage_view(0).version == 5
    for s in range(2):
        back = mspan.snapshot(pst, stage=s)
        st2 = num[s].init_state(jax.random.PRNGKey(9))
        num[s].restore(st2, back)
        for a, b in zip(jax.tree.leaves(st2.params),
                        jax.tree.leaves(sts[s].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert all(float(jnp.max(jnp.abs(x))) == 0.0
                   for x in jax.tree.leaves(st2.grad_acc))
    whole = mspan.snapshot(pst)
    pst2 = mspan.init_state(jax.random.PRNGKey(11))
    mspan.restore(pst2, whole)
    for s in range(2):
        for a, b in zip(jax.tree.leaves(pst2.stage_view(s).params),
                        jax.tree.leaves(sts[s].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mesh_span_in_mixed_swarm_equals_reference():
    """A MeshExecutor.for_span(width=2) peer next to single-stage numeric
    peers, under the async tick, matches the fault-free reference."""
    cfg = tiny_dense_config()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    scfg = _scfg(n_trainers=3, overlap=True)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                         record_accumulation=True)
    runner.build(peers_per_stage=2)
    base = MeshExecutor(cfg, 2, SEQ, 0, make_peer_mesh())
    span_peer = runner.add_peer(range(0, 2),
                                executor=base.for_span(range(0, 2)))
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    span_accs = {s for (k, _t, s, _i, _a, pid) in runner.ledger_log
                 if k == "acc" and pid == span_peer.id}
    assert span_accs == {0, 1}, span_accs
    ref = reference_losses(cfg, runner.programs, opt, 0, STEPS, SEQ,
                           MB, GB)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    _assert_exactly_once(runner, 2, GB // MB)


# ------------------------------------------------- XLA flags smoke
_XLA_SMOKE = textwrap.dedent("""
    import os, sys
    sys.path.insert(0, "src")
    os.environ["REPRO_XLA_ASYNC"] = "1"
    from repro.launch.mesh import ASYNC_XLA_FLAGS, enable_async_xla_flags
    assert enable_async_xla_flags()
    flags = os.environ["XLA_FLAGS"].split()
    assert all(f in flags for f in ASYNC_XLA_FLAGS), flags
    # idempotent: a second call appends nothing
    enable_async_xla_flags()
    assert os.environ["XLA_FLAGS"].split() == flags
    # jax still initializes and compiles with the flags set
    import jax, jax.numpy as jnp
    y = jax.jit(lambda x: (x * 2).sum())(jnp.arange(8.0))
    assert float(y) == 56.0
    print("XLA_ASYNC_SMOKE_OK")
""")


def test_async_xla_flags_gate_off_by_default():
    env_gate = os.environ.pop("REPRO_XLA_ASYNC", None)
    try:
        from repro.launch.mesh import enable_async_xla_flags
        before = os.environ.get("XLA_FLAGS")
        assert not enable_async_xla_flags()
        assert os.environ.get("XLA_FLAGS") == before
    finally:
        if env_gate is not None:
            os.environ["REPRO_XLA_ASYNC"] = env_gate


def test_async_xla_flags_import_and_compile_smoke():
    """Subprocess (flags must precede the first jax init): gate on,
    merge flags, then import jax and jit through them."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _XLA_SMOKE],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "XLA_ASYNC_SMOKE_OK" in r.stdout


# ------------------------------------------------- rebalance pricing
def test_rebalance_prices_overlapped_wire():
    """max(compute, wire) per span edge: overlapped throughput dominates
    serial, and they coincide exactly when the wire is free."""
    spans = [(0, 2), (2, 3)]
    costs = [1.0, 1.0, 1.0]
    serial = pipeline_throughput(spans, 1.0, stage_costs=costs,
                                 boundary_cost=0.5)
    overlapped = pipeline_throughput(spans, 1.0, stage_costs=costs,
                                     boundary_cost=0.5, overlap_wire=True)
    assert overlapped > serial
    for bc in (0.0, 0.25, 1.0, 4.0):
        s = pipeline_throughput(spans, 1.0, stage_costs=costs,
                                boundary_cost=bc)
        o = pipeline_throughput(spans, 1.0, stage_costs=costs,
                                boundary_cost=bc, overlap_wire=True)
        assert o >= s
        if bc == 0.0:
            assert o == s
