"""Stage-runtime layer: executor protocol, mesh-backed peers, the shared
compile cache, and checkpoint-backed elastic resume.

The tentpole property is heterogeneity (paper §3; Diskin et al.'s pooled
hardware): a swarm mixing single-device (NumericExecutor) and mesh-backed
(MeshExecutor) peers, under churn and with a *learned* boundary codec,
must reproduce the fault-free reference loss trajectory — same tolerance
as tests/test_churn.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.core.sim import Sleep
from repro.launch.mesh import make_peer_mesh
from repro.optim import adamw
from repro.runtime import (MeshExecutor, NumericExecutor, StageExecutor,
                           build_numeric_executors, compile_stats,
                           get_stage_programs, reset_compile_stats)

SEQ, MB, GB, STEPS = 32, 2, 8, 3


def _codec_cfg():
    return tiny_dense_config(boundary_compression="bottleneck",
                             bottleneck_dim=16)


def _reference_losses(cfg, programs, opt, seed, steps=STEPS):
    """Fault-free sequential twin (shared oracle in conftest)."""
    from conftest import reference_losses
    return reference_losses(cfg, programs, opt, seed, steps, SEQ, MB, GB)


# ------------------------------------------------- mixed-backend swarm
def test_mixed_mesh_numeric_churn_equals_reference():
    """A churn trace on a heterogeneous swarm — mesh-backed peers at both
    stages next to numeric peers, learned bottleneck codec on — matches
    the fault-free reference trajectory within the churn tolerance."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="bottleneck", max_steps=STEPS)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                         record_accumulation=True)
    runner.build(peers_per_stage=2)
    mesh = make_peer_mesh()
    for s in range(2):
        runner.add_peer(s, executor=MeshExecutor(
            cfg, 2, SEQ, s, mesh, compress="bottleneck"))
    assert any(isinstance(p.executor, MeshExecutor)
               for p in runner.peers.values())
    runner.apply_trace([TraceEvent(0.02, -1), TraceEvent(0.05, -1),
                        TraceEvent(0.25, +1)])
    m = runner.run(until=1e6)
    assert runner.step == STEPS
    assert m["failures"] == 2 and m["joins"] == 1
    # mesh peers actually accumulated gradients (they served, not idled)
    mesh_ids = {p.id for p in runner.peers.values()
                if isinstance(p.executor, MeshExecutor)}
    assert any(kind == "acc" and pid in mesh_ids
               for (kind, *_r, pid) in runner.ledger_log)
    ref = _reference_losses(cfg, runner.programs, opt, seed=0)
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)
    from test_churn import _assert_exactly_once
    _assert_exactly_once(runner, 2, GB // MB)


def test_mesh_numeric_snapshot_restore_roundtrip():
    """State downloads cross backends: numeric -> mesh -> numeric via the
    executors' snapshot/restore wire format, bitwise."""
    cfg = _codec_cfg()
    execs = build_numeric_executors(cfg, 2, SEQ, compress="bottleneck")
    mesh_ex = MeshExecutor(cfg, 2, SEQ, 0, make_peer_mesh(),
                           compress="bottleneck")
    st = execs[0].init_state(jax.random.PRNGKey(3))
    st.opt = adamw().init(st.params)
    st.version = 7
    snap = execs[0].snapshot(st)
    mesh_st = mesh_ex.init_state(jax.random.PRNGKey(4))
    mesh_ex.restore(mesh_st, snap)
    assert mesh_st.version == 7
    back = mesh_ex.snapshot(mesh_st)
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st2 = execs[0].init_state(jax.random.PRNGKey(5))
    execs[0].restore(st2, back)
    for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # grad accumulators come back zeroed: a download never imports grads
    assert all(float(jnp.max(jnp.abs(x))) == 0.0
               for x in jax.tree.leaves(st2.grad_acc))


def test_executors_satisfy_protocol():
    cfg = _codec_cfg()
    num = build_numeric_executors(cfg, 2, SEQ, compress="bottleneck")[0]
    msh = MeshExecutor(cfg, 2, SEQ, 0, make_peer_mesh(),
                       compress="bottleneck")
    assert isinstance(num, StageExecutor)
    assert isinstance(msh, StageExecutor)
    assert num.for_stage(1).stage == 1
    assert msh.for_stage(1).stage == 1 and msh.for_stage(0) is msh


# ------------------------------------------------- shared compile cache
def test_compile_cache_one_trace_per_stage_shape_and_codec():
    """N peers of one stage trigger exactly ONE compile per (stage, kind,
    shape, codec mode) — and a second runner with the same configuration
    re-traces nothing (process-wide cache)."""
    reset_compile_stats()
    cfg = tiny_dense_config()
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="none", max_steps=1)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    r1 = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    r1.build(peers_per_stage=4)                 # 4 peers x 2 stages
    r1.run(until=1e6)
    st = compile_stats()
    assert st["per_key"], "no traces recorded"
    assert all(v == 1 for v in st["per_key"].values()), st["per_key"]
    # one fwd + one bwd per stage = 4 jits total, not peers x stages x 2
    assert st["traces"] == 4, st["per_key"]
    r2 = SwarmRunner(cfg, scfg, opt, numeric=True, seed=1)
    r2.build(peers_per_stage=4)
    r2.run(until=1e6)
    assert compile_stats()["traces"] == 4       # zero new traces


def test_codec_mode_is_part_of_the_cache_key():
    cfg = _codec_cfg()
    p_none = get_stage_programs(cfg, 2, SEQ, "none")
    p_btl = get_stage_programs(cfg, 2, SEQ, "bottleneck")
    assert p_none is not p_btl
    assert p_btl is get_stage_programs(cfg, 2, SEQ, "bottleneck")


# ------------------------------------------------- checkpoint resume
def _strand_stage(runner, stage, at):
    yield Sleep(at)
    for p in [p for p in runner.peers.values()
              if p.alive and p.stage == stage]:
        runner._fail_peer(p)


def test_stage_resumes_from_latest_checkpoint(tmp_path):
    """A stage that loses ALL its peers resumes from the latest completed
    step's checkpoint (repro.ckpt via executor snapshot/restore), not the
    step-0 reference — and the loss trajectory continues exactly as
    fault-free training (the checkpoint IS the post-step state)."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    # 4 steps: kill lands after the early checkpoints, leaving post-kill
    # steps inside the PR 3 churn tolerance (f32 accumulation-order noise
    # compounds through adam beyond that horizon regardless of churn)
    total = STEPS + 1
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="bottleneck", max_steps=total,
                       ckpt_dir=str(tmp_path))
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=2)
    # both stage-1 peers die in one instant mid-run; a fresh join later
    # finds no donors and must fall back to the on-disk checkpoint
    t_kill = 0.30
    runner.sim.spawn(_strand_stage(runner, stage=1, at=t_kill))
    runner.apply_trace([TraceEvent(t_kill + 0.2, +1)])
    m = runner.run(until=1e6)
    assert runner.step == total
    assert m["failures"] == 2 and m["joins"] == 1
    # the join restored stage 1 from a completed step > 0
    restores = [r for r in m["ckpt_restores"] if r[0] == 1]
    assert restores, "join did not restore from the checkpoint"
    resumed_step = restores[-1][1]
    assert resumed_step >= 1
    from repro.ckpt import latest_step, stage_dir
    assert latest_step(stage_dir(str(tmp_path), 1)) == total
    # loss continuity: the full trajectory (including the steps AFTER the
    # stage was wiped) equals fault-free training
    ref = _reference_losses(cfg, runner.programs, opt, seed=0, steps=total)
    assert len(m["loss"]) == total
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)


def test_stale_checkpoint_triggers_global_rollback(tmp_path):
    """ckpt_period=2: a stage stranded one step past the latest
    checkpoint must NOT resume alone from the older step (that would be
    a mixed-version pipeline) — the runner rewinds the whole pipeline to
    the checkpoint, replays the lost steps on the same sample indices,
    and the final trajectory still equals fault-free training."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    total = 4
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="bottleneck", max_steps=total,
                       ckpt_dir=str(tmp_path), ckpt_period=2)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=2)

    def script(r):
        # strand stage 1 right after step 3 completes: latest on-disk
        # checkpoint is step 2 (period 2), one step behind the pipeline
        while (r.step < 3 or r._dispatch_paused) and not r.stopped:
            yield Sleep(0.01)
        if r.stopped:
            return
        for p in [p for p in r.peers.values()
                  if p.alive and p.stage == 1]:
            r._fail_peer(p)
        yield Sleep(0.1)
        yield from r._join_new_peer()

    runner.sim.spawn(script(runner))
    m = runner.run(until=1e6)
    assert runner.step == total
    assert m["rollbacks"] == [(3, 2)], m["rollbacks"]
    # every stage was rewound to step 2 (not just the stranded one)
    assert {s for s, k in m["ckpt_restores"] if k == 2} == {0, 1}
    ref = _reference_losses(cfg, runner.programs, opt, seed=0, steps=total)
    assert len(m["loss"]) == total
    np.testing.assert_allclose(m["loss"], ref, atol=2e-4)


def test_rollback_after_cold_resume_truncates_relative_losses(tmp_path):
    """Rollback inside a RESUMED runner: its loss list starts at the
    resume step, so the rollback must truncate by offset (a bug here
    leaves a duplicate loss entry and desyncs the trajectory)."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)

    def make(max_steps, period):
        scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                           global_batch=GB, n_trainers=3,
                           rebalance_period=0.0, codec="bottleneck",
                           max_steps=max_steps, ckpt_dir=str(tmp_path),
                           ckpt_period=period)
        r = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
        r.build(peers_per_stage=2)
        return r

    r1 = make(2, 1)
    m1 = r1.run(until=1e6)
    r2 = make(4, 2)                    # resumes at step 2; saves at 4
    assert r2.step == 2

    def script(r):
        # strand stage 1 after step 3: latest cut is still step 2
        while (r.step < 3 or r._dispatch_paused) and not r.stopped:
            yield Sleep(0.01)
        if r.stopped:
            return
        for p in [p for p in r.peers.values()
                  if p.alive and p.stage == 1]:
            r._fail_peer(p)
        yield Sleep(0.1)
        yield from r._join_new_peer()

    r2.sim.spawn(script(r2))
    m2 = r2.run(until=1e6)
    assert r2.step == 4
    assert m2["rollbacks"] == [(3, 2)]
    assert len(m2["loss"]) == 2        # steps 3 and 4, no duplicates
    ref = _reference_losses(cfg, r2.programs, opt, seed=0, steps=4)
    np.testing.assert_allclose(m1["loss"] + m2["loss"], ref, atol=2e-4)


def test_runner_cold_start_resumes_previous_run(tmp_path):
    """A new SwarmRunner constructed over a non-empty ckpt_dir CONTINUES
    that run: step counter and data cursor adopt the latest consistent
    cut, so the combined trajectory equals one uninterrupted run (and
    later saves aren't pruned in favor of the stale older-run ones)."""
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)

    def make(max_steps):
        scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                           global_batch=GB, n_trainers=3,
                           rebalance_period=0.0, codec="bottleneck",
                           max_steps=max_steps, ckpt_dir=str(tmp_path))
        r = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
        r.build(peers_per_stage=2)
        return r

    r1 = make(max_steps=2)
    m1 = r1.run(until=1e6)
    assert r1.step == 2
    r2 = make(max_steps=4)          # fresh process stand-in, same dir
    assert r2.step == 2             # adopted the latest cut, not step 0
    m2 = r2.run(until=1e6)
    assert r2.step == 4
    from repro.ckpt import latest_step, stage_dir
    assert latest_step(stage_dir(str(tmp_path), 0)) == 4   # not stale-pruned
    ref = _reference_losses(cfg, r1.programs, opt, seed=0, steps=4)
    np.testing.assert_allclose(m1["loss"] + m2["loss"], ref, atol=2e-4)


def test_without_ckpt_dir_falls_back_to_step0_reference():
    cfg = _codec_cfg()
    opt = adamw(lr=1e-2, grad_clip=0.0)
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=2, rebalance_period=0.0,
                       codec="bottleneck", max_steps=1)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=1)
    peer = runner.add_peer(0)
    runner._restore_from_checkpoint(peer, 0)
    assert runner.metrics["ckpt_restores"] == []
    for a, b in zip(jax.tree.leaves(peer.state.params),
                    jax.tree.leaves(runner._ref_params[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_MULTIDEV_MIXED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, "src"); sys.path.insert(0, "tests")
    import jax, jax.numpy as jnp, numpy as np
    from conftest import tiny_dense_config
    from repro.core import SwarmRunner, SwarmConfig, TraceEvent
    from repro.data.synthetic import SyntheticLM
    from repro.launch.mesh import make_peer_mesh
    from repro.dist.sharding import DEFAULT_RULES, ShardingRules
    from repro.optim.adamw import Optimizer
    from repro.runtime import MeshExecutor, build_numeric_executors

    SEQ, MB, GB, STEPS = 32, 4, 16, 3
    cfg = tiny_dense_config(boundary_compression="bottleneck",
                            bottleneck_dim=16)
    mesh = make_peer_mesh(4)                     # a REAL 4-device slice

    # ---- (1) replicated-rules mesh bwd is BITWISE equal to numeric:
    # the executor plumbing (placement, codec wire, host crossing) adds
    # no numerics of its own.  Microbatch of 2 on 4 devices: 2 % 4 != 0,
    # so the divisibility fallback replicates the batch too — nothing is
    # distributed, hence bitwise is the right bar here
    repl = ShardingRules(rules={k: None for k in DEFAULT_RULES.rules})
    num = build_numeric_executors(cfg, 2, SEQ, compress="bottleneck")
    st_n = [e.init_state(jax.random.PRNGKey(0)) for e in num]
    b = SyntheticLM(cfg.vocab_size, SEQ, 2, seed=17).batch(0)
    w = num[0].wire_fwd(num[0].run_fwd(st_n[0], b["tokens"]))
    loss_n, gx_n, gp_n = num[1].run_bwd(st_n[1], w, labels=b["labels"])
    mex = MeshExecutor(cfg, 2, SEQ, 1, mesh, compress="bottleneck",
                       rules=repl)
    st_m = mex.init_state(jax.random.PRNGKey(9))
    mex.restore(st_m, num[1].snapshot(st_n[1]))
    loss_m, gx_m, gp_m = mex.run_bwd(st_m, w, labels=b["labels"])
    assert float(loss_n) == float(loss_m)
    for a, c in zip(jax.tree.leaves(gp_n), jax.tree.leaves(gp_m)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    # ---- (2) sharded-rules mixed swarm under churn: params FSDP over
    # the peer's data axis, microbatch (4) genuinely split over the 4
    # devices.  Cross-device reduction order makes gradients differ
    # from single-device at f32-noise scale (~1e-5 relative), so the
    # trajectory criterion is loss-scale closeness with plain SGD (no
    # adam sign-normalization, which amplifies bit noise to O(lr))
    lr = 1e-2
    opt = Optimizer(init=lambda p: {"n": jnp.zeros(())},
                    update=lambda g, s, p: (
                        jax.tree.map(lambda x: -lr * x, g), s))
    scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                       global_batch=GB, n_trainers=3, rebalance_period=0.0,
                       codec="bottleneck", max_steps=STEPS)
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=1)
    for s in range(2):
        ex = MeshExecutor(cfg, 2, SEQ, s, mesh, compress="bottleneck")
        assert ex.device_count == 4
        runner.add_peer(s, executor=ex)
    runner.apply_trace([TraceEvent(0.05, -1)])   # churn on top
    m = runner.run(until=1e6)
    assert runner.step == STEPS

    from conftest import reference_losses
    losses = reference_losses(cfg, runner.programs, opt, 0, STEPS,
                              SEQ, MB, GB)
    assert max(losses) - min(losses) > 1e-3      # params actually move
    np.testing.assert_allclose(m["loss"], losses, atol=2e-3)
    print("MULTIDEV_MIXED_OK", m["loss"])
""")


@pytest.mark.slow
def test_mixed_swarm_with_real_multidevice_mesh_peer():
    """Subprocess (needs its own XLA device-count override): peers backed
    by a genuine 4-device mesh, mixed with single-device peers and churn.
    Asserts (1) bitwise executor equivalence under replicated placement
    and (2) trajectory closeness under real FSDP sharding + split batch."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_MIXED],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MULTIDEV_MIXED_OK" in r.stdout


# ------------------------------------------------- swarm-level fairness
def test_faster_peer_receives_proportionally_more_microbatches():
    """Alg. 1 end-to-end: with one 2x-faster device serving the same
    stage, the wiring routes it ~2x the microbatches (loose bound: the
    sim adds network time on top of compute)."""
    from repro.core.peer import DeviceProfile, MBPS
    # slow enough that compute (not network latency) dominates response
    # time — the regime where IWRR's throughput-weighting shows
    slow = DeviceProfile("slow", 2e9, 800 * MBPS, 800 * MBPS, 1e-4)
    fast = DeviceProfile("fast", 4e9, 800 * MBPS, 800 * MBPS, 1e-4)
    cfg = tiny_dense_config(n_layers=2)
    scfg = SwarmConfig(n_stages=1, microbatch_size=1, seq_len=512,
                       global_batch=64, n_trainers=4, rebalance_period=0.0,
                       codec="none", max_steps=6)
    r = SwarmRunner(cfg, scfg, adamw(), numeric=False, seed=0,
                    profile_fn=lambda i: (fast, slow)[i % 2],
                    record_accumulation=True)
    r.build(peers_per_stage=2)
    r.run(until=1e6)
    counts = {}
    for kind, _step, _s, _i, _a, pid in r.ledger_log:
        if kind == "acc":
            counts[pid] = counts.get(pid, 0) + 1
    by_profile = {p.id: p.profile.name for p in r.peers.values()}
    n_fast = sum(c for pid, c in counts.items()
                 if by_profile[pid] == "fast")
    n_slow = sum(c for pid, c in counts.items()
                 if by_profile[pid] == "slow")
    assert n_slow > 0
    ratio = n_fast / n_slow
    assert 1.5 <= ratio <= 2.8, (n_fast, n_slow, ratio)
