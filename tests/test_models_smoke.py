"""Per-assigned-architecture smoke tests: REDUCED same-family config, one
train step + one prefill + one decode step on CPU; asserts shapes + no
NaNs (the FULL configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, ASSIGNED, get_reduced, ShapeSpec
from repro.data import make_batch
from repro.optim import adamw
from repro.train.steps import (make_train_step, make_serve_step,
                               make_prefill_step, make_state,
                               decode_cache_specs)

SEQ, BATCH = 32, 2


def _batch_for(cfg, key):
    batch = make_batch(cfg.vocab_size, SEQ, BATCH)
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(jnp.arange(SEQ),
                                              (3, BATCH, SEQ))
    if cfg.family == "audio":
        batch["audio_embed"] = jax.random.normal(
            key, (BATCH, cfg.encoder_max_len, cfg.d_model),
            cfg.compute_jdtype)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + ["swarm-1b"])
def test_arch_train_step(arch):
    cfg = get_reduced(arch)
    opt = adamw(lr=1e-3)
    key = jax.random.PRNGKey(0)
    state = make_state(cfg, opt, key)
    batch = _batch_for(cfg, key)
    step = jax.jit(make_train_step(cfg, opt))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_decode_step(arch):
    cfg = get_reduced(arch)
    opt = adamw()
    state = make_state(cfg, opt, jax.random.PRNGKey(0))
    shape = ShapeSpec("d", 48, BATCH, "decode")
    cs = decode_cache_specs(cfg, shape)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cs)
    step = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    for pos in range(3):
        tok, caches = step(state["params"], caches, tok, jnp.int32(pos))
    assert tok.shape == (BATCH, 1)
    assert int(tok.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_prefill_step(arch):
    cfg = get_reduced(arch)
    opt = adamw()
    key = jax.random.PRNGKey(1)
    state = make_state(cfg, opt, key)
    batch = _batch_for(cfg, key)
    batch.pop("labels")
    step = jax.jit(make_prefill_step(cfg))
    nxt, caches = step(state["params"], batch)
    assert nxt.shape == (BATCH, 1)
    for leaf in jax.tree.leaves(caches):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


def test_prefill_decode_consistency():
    """prefill(S tokens) then decode == full forward over S+1 tokens."""
    cfg = get_reduced("yi-6b")
    from repro.models import model as M
    from repro.models import params as P
    params = P.init(jax.random.PRNGKey(3),
                    __import__("repro.train.steps",
                               fromlist=["model_specs"]).model_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, SEQ + 1), 0,
                              cfg.vocab_size)
    full_logits, _ = M.lm_apply(cfg, params, toks, remat=False)
    logits_p, caches = M.lm_prefill(cfg, params, toks[:, :SEQ],
                                    cache_len=SEQ + 1, remat=False,
                                    last_only=False)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, :SEQ], np.float32),
        np.asarray(logits_p, np.float32), atol=2e-4)
    logits_d, _ = M.lm_decode_step(cfg, params, toks[:, SEQ:SEQ + 1],
                                   caches, jnp.int32(SEQ))
    np.testing.assert_allclose(np.asarray(full_logits[:, -1], np.float32),
                               np.asarray(logits_d[:, 0], np.float32),
                               atol=2e-3)


def test_ring_cache_matches_full_cache_for_swa():
    """Sliding-window decode with a ring buffer == with a full cache."""
    cfg = get_reduced("h2o-danube-3-4b")      # sliding_window = 8
    from repro.models import model as M
    from repro.train.steps import model_specs
    from repro.models import params as P
    params = P.init(jax.random.PRNGKey(5), model_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 24), 0,
                              cfg.vocab_size)
    # reference: full forward logits for last position
    full_logits, _ = M.lm_apply(cfg, params, toks, remat=False)
    # decode token-by-token with the ring cache (size == window == 8)
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        __import__("repro.train.steps", fromlist=["decode_cache_specs"]
                   ).decode_cache_specs(cfg, ShapeSpec("d", 24, 1,
                                                       "decode")))
    logits = None
    for pos in range(24):
        logits, caches = M.lm_decode_step(cfg, params, toks[:, pos:pos + 1],
                                          caches, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(full_logits[:, -1], np.float32),
                               np.asarray(logits[:, 0], np.float32),
                               atol=2e-3)


def test_full_configs_param_counts():
    """Full configs instantiate abstractly with plausible param counts."""
    from repro.models import flops as F
    from repro.configs import get_config
    expected = {
        "yi-6b": (5.5e9, 7.5e9),
        "gemma-2b": (2.0e9, 3.2e9),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "deepseek-v2-236b": (2.0e11, 2.6e11),
        "llama4-scout-17b-a16e": (4.0e10, 1.4e11),
        "hymba-1.5b": (1.2e9, 2.2e9),
        # our xLSTM blocks omit the 2x pre-up-projection (DESIGN.md §5):
        # ~75M for the "125m" geometry
        "xlstm-125m": (6.0e7, 2.2e8),
        "whisper-large-v3": (1.4e9, 2.0e9),
    }
    for arch, (lo, hi) in expected.items():
        n = F.total_params(get_config(arch))
        assert lo < n < hi, (arch, n)
