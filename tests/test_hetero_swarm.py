"""Heterogeneous stage kinds, end-to-end (the StagePlan fast lane).

One pipeline, many block kinds: the :class:`~repro.models.stage_plan
.StagePlan` computed in ``models/`` must drive every layer identically —
stage programs (``runtime/``), the reference loss (``dist/``), swarm
pricing (``core/``) — for three workloads the paper's uniform-stack
tests never exercise:

* **mixed attention + SSM** decoder stacks (per-kind stage runs),
* **whisper encoder-decoder** with the encoder pod placed exactly at
  the cross-attention boundary,
* **recurrent-state (mamba) serving** whose carry must survive span-peer
  death through the keyed slot ledger.

Plus the compile discipline the plan exists to guarantee: one jit per
(stage, kind-run), zero re-traces for a second same-shape runner.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reference_losses, tiny_dense_config
from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.models.config import ArchConfig, MoEConfig, SSMConfig
from repro.models.stage_plan import get_stage_plan, make_stage_plan
from repro.optim import adamw
from repro.runtime import build_stage_programs, init_stage_params
from repro.runtime.stage_model import split_whisper_params

SEQ, MB, GB, STEPS = 32, 2, 8, 3


def mixed_config(**kw):
    """2 attention layers feeding 2 mamba layers — a 2-stage split puts
    one kind per stage, a 4-stage split one layer per stage."""
    base = dict(name="tiny-mixed",
                block_pattern=("attn", "attn", "mamba", "mamba"),
                ssm=SSMConfig(state_dim=8, chunk=16))
    base.update(kw)
    return tiny_dense_config(**base)


def whisper_config():
    return ArchConfig(name="tiny-whisper", family="audio", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=256, head_dim=16, encoder_layers=2,
                      encoder_max_len=8, compute_dtype="float32",
                      param_dtype="float32")


# -------------------------------------------------------------- the plan
class TestStagePlan:
    def test_mixed_runs_slots_and_fusion(self):
        plan = get_stage_plan(mixed_config(), 2)
        assert plan.stages[0].runs == (("attn", 2),)
        assert plan.stages[1].runs == (("mamba", 2),)
        assert plan.stages[0].aux_slots == ()
        assert plan.stages[1].aux_slots == ("kv",)   # recurrent carry
        assert not plan.periodic
        # the kind boundary between stages 0 and 1 never fuses
        assert plan.fusion_groups((0, 2)) == [(0, 1), (1, 1)]

    def test_whisper_pod_at_cross_attention_boundary(self):
        cfg = whisper_config()
        plan = get_stage_plan(cfg, 3)
        assert plan.is_encdec and not plan.periodic
        assert plan.stages[0].runs == (("whisper_enc", 2),)
        assert not plan.stages[0].owns_embed          # token embed is
        assert plan.stages[1].owns_embed              # the decoder's
        assert plan.stages[2].owns_head
        assert plan.stages[1].aux_slots == ("kv",)
        # boundary 0 (the pod hand-off) ships encoder output + token
        # ids; interior boundaries additionally ship the hidden state
        b0 = plan.boundary_bytes(0, MB, SEQ)
        b1 = plan.boundary_bytes(1, MB, SEQ)
        enc = 2.0 * MB * cfg.encoder_max_len * cfg.d_model
        tok = 4.0 * MB * SEQ
        assert b0 == pytest.approx(enc + tok)
        assert b1 == pytest.approx(b0 + 2.0 * MB * SEQ * cfg.d_model)

    def test_expert_sharded_moe_prices_routed_tokens(self):
        cfg = mixed_config(
            block_pattern=("attn", "attn", "moe", "moe"),
            moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                          expert_sharded=True))
        plan = get_stage_plan(cfg, 4)
        dense = dataclasses.replace(cfg.moe, expert_sharded=False)
        base = get_stage_plan(
            dataclasses.replace(cfg, moe=dense), 4).boundary_bytes(
                0, MB, SEQ)
        # entering a MoE stage: top_k routed copies of every token
        assert plan.boundary_bytes(1, MB, SEQ) == pytest.approx(2 * base)
        assert plan.boundary_bytes(2, MB, SEQ) == pytest.approx(2 * base)
        # attn -> attn boundary keeps the uniform price
        assert plan.boundary_bytes(0, MB, SEQ) == pytest.approx(base)

    def test_share_groups_with_mixed_kinds_is_rejected(self):
        from repro.models import model as model_lib
        cfg = mixed_config(share_groups=2)
        with pytest.raises(ValueError, match="share_groups"):
            make_stage_plan(cfg, 2)
        with pytest.raises(ValueError, match="share_groups"):
            model_lib.lm_specs(cfg)


# ------------------------------------------------- mixed-kind training
@pytest.fixture(scope="module")
def mixed_setup():
    cfg = mixed_config()
    programs = build_stage_programs(cfg, 2, SEQ)
    opt = adamw(lr=1e-2, grad_clip=0.0)
    return cfg, programs, opt


class TestMixedKindSwarm:
    def test_fault_free_equals_reference(self, mixed_setup):
        """An attention-stage + mamba-stage swarm reproduces the
        sequential fault-free trajectory token for token."""
        cfg, programs, opt = mixed_setup
        scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                           global_batch=GB, n_trainers=3,
                           rebalance_period=0.0, codec="none",
                           max_steps=STEPS)
        r = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                        programs=programs)
        r.build(peers_per_stage=2)
        m = r.run(until=1e6)
        ref = reference_losses(cfg, programs, opt, 0, STEPS, SEQ, MB, GB)
        assert r.step == STEPS
        np.testing.assert_allclose(m["loss"], ref, atol=2e-4)

    def test_churn_equals_reference(self, mixed_setup):
        """Failures + a warm join leave the mixed-kind trajectory within
        2e-4 of the fault-free oracle (exactly-once under churn holds
        across kind boundaries)."""
        cfg, programs, opt = mixed_setup
        scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                           global_batch=GB, n_trainers=3,
                           rebalance_period=0.0, codec="none",
                           max_steps=STEPS)
        r = SwarmRunner(cfg, scfg, opt, numeric=True, seed=1,
                        programs=programs)
        r.build(peers_per_stage=3)
        r.apply_trace([TraceEvent(0.02, -1), TraceEvent(0.05, -1),
                       TraceEvent(0.22, +1)])
        m = r.run(until=1e6)
        assert r.step == STEPS
        assert m["failures"] == 2 and m["joins"] == 1
        ref = reference_losses(cfg, programs, opt, 1, STEPS, SEQ, MB, GB)
        np.testing.assert_allclose(m["loss"], ref, atol=2e-4)


# ------------------------------------------------------ whisper staged
W_SEQ, W_MB, W_GB, W_STEPS = 16, 2, 4, 2


def _whisper_batch(cfg, idx, b=W_MB, seq=W_SEQ):
    rng = np.random.default_rng(1000 + idx)
    audio = rng.standard_normal(
        (b, cfg.encoder_max_len, cfg.d_model)).astype(np.float32)
    tok = rng.integers(0, cfg.vocab_size, size=(b, seq),
                       dtype=np.int32)
    lab = rng.integers(0, cfg.vocab_size, size=(b, seq),
                       dtype=np.int32)
    return {"tokens": {"audio": audio, "tok": tok}, "labels": lab}


def _whisper_reference(cfg, programs, opt, seed, steps=W_STEPS,
                       seq=W_SEQ, mb=W_MB, gb=W_GB):
    """conftest.reference_losses with whisper's tree-valued boundaries
    and audio+token data (same accumulation conventions)."""
    S = len(programs)
    params = init_stage_params(programs, jax.random.PRNGKey(seed))
    opt_states = [opt.init(p) for p in params]
    idx, losses = 0, []
    for _ in range(steps):
        grads = [jax.tree.map(jnp.zeros_like, p) for p in params]
        loss_sum, tok = 0.0, 0
        for _ in range(gb // mb):
            b = _whisper_batch(cfg, idx)
            idx += 1
            xs = [b["tokens"]]
            for s in range(S - 1):
                xs.append(programs[s].fwd(params[s], xs[-1]))
            loss, gx, gp = programs[S - 1].bwd(params[S - 1], xs[-1],
                                               b["labels"])
            grads[S - 1] = jax.tree.map(jnp.add, grads[S - 1], gp)
            for s in range(S - 2, 0, -1):
                gx, gp = programs[s].bwd(params[s], xs[s], gx)
                grads[s] = jax.tree.map(jnp.add, grads[s], gp)
            _, gp = programs[0].bwd(params[0], xs[0], gx)
            grads[0] = jax.tree.map(jnp.add, grads[0], gp)
            loss_sum += float(loss)
            tok += mb * seq
        losses.append(loss_sum / tok)
        for s in range(S):
            gm = jax.tree.map(lambda g: g / tok, grads[s])
            upd, opt_states[s] = opt.update(gm, opt_states[s], params[s])
            params[s] = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                     params[s], upd)
    return losses


class TestWhisperStaged:
    def test_staged_chain_matches_whisper_apply(self):
        """Stage programs sliced out of a full whisper tree reproduce
        the whole-model loss exactly (the pod hand-off and payload-tree
        boundaries lose nothing)."""
        from repro.models import params as P
        from repro.models import whisper as W
        from repro.train import steps as steps_lib
        cfg = whisper_config()
        programs = build_stage_programs(cfg, 3, W_SEQ)
        full = P.init(jax.random.PRNGKey(0), W.whisper_specs(cfg))
        staged = split_whisper_params(cfg, 3, full)
        b = _whisper_batch(cfg, 0)
        x = b["tokens"]
        for s in range(2):
            x = programs[s].fwd(staged[s], x)
        loss, _, _ = programs[2].bwd(staged[2], x, b["labels"])
        logits, _ = W.whisper_apply(
            cfg, full, {"audio_embed": b["tokens"]["audio"],
                        "tokens": b["tokens"]["tok"]})
        ref = steps_lib.cross_entropy(logits, b["labels"])  # token mean
        np.testing.assert_allclose(float(loss) / (W_MB * W_SEQ),
                                   float(ref), rtol=1e-6)

    def test_whisper_swarm_trains_elastic(self):
        """A 3-stage whisper swarm (encoder pod + 2 decoder stages)
        trains through a failure + warm join, matching the fault-free
        reference trajectory."""
        cfg = whisper_config()
        programs = build_stage_programs(cfg, 3, W_SEQ)
        opt = adamw(lr=1e-2, grad_clip=0.0)
        scfg = SwarmConfig(n_stages=3, microbatch_size=W_MB,
                           seq_len=W_SEQ, global_batch=W_GB,
                           n_trainers=2, rebalance_period=0.0,
                           codec="none", max_steps=W_STEPS)
        r = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0,
                        programs=programs,
                        data_fn=lambda i: _whisper_batch(cfg, i))
        r.build(peers_per_stage=2)
        r.apply_trace([TraceEvent(0.03, -1), TraceEvent(0.2, +1)])
        m = r.run(until=1e6)
        assert r.step == W_STEPS
        assert m["failures"] == 1 and m["joins"] == 1
        ref = _whisper_reference(cfg, programs, opt, 0)
        np.testing.assert_allclose(m["loss"], ref, atol=2e-4)


# ----------------------------------------------- recurrent serving carry
class TestRecurrentServing:
    def test_mamba_carry_survives_span_death(self):
        """Kill a decode span peer serving mamba stages mid-generation:
        the recurrent carry is NOT recomputable from a KV ring, so the
        replacement re-prefills exactly the dead span's stages from the
        recorded boundary history — greedy outputs stay token-for-token
        equal to the single-process reference, and the strict slot
        ledger (raises on double prefill) proves exactly-once."""
        from repro.serve import ServeConfig, ServeRunner
        from repro.serve.runner import reference_generate
        cfg = tiny_dense_config(name="tiny-mamba",
                                block_pattern=("mamba",) * 4,
                                ssm=SSMConfig(state_dim=8, chunk=16))
        plan = get_stage_plan(cfg, 4)
        assert all(s.aux_slots == ("kv",) for s in plan.stages)
        r = ServeRunner(cfg, ServeConfig(n_stages=4, max_batch=2,
                                         max_sessions=1), seed=0)
        for name, span in (("d0a", (0, 2)), ("d1a", (2, 4)),
                           ("d0b", (0, 2)), ("d1b", (2, 4))):
            r.add_peer(span, pool="decode", name=name)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, size=(4, 8))
        reqs = [r.submit(p, 6) for p in prompts]
        r.schedule_fail(0.045, "d1a")               # lands mid-decode
        summary = r.run()
        ref = reference_generate(cfg, r.params, prompts, 6)
        np.testing.assert_array_equal(
            np.stack([q.tokens for q in reqs]), ref)
        assert summary["failed"] == 0
        assert summary["reprefills"] >= 1
        assert summary["reprefilled_stages"] == 2 * summary["reprefills"]
        assert all(c == 0 for c in r.kv.stage_counts())


# --------------------------------------------------- compile discipline
class TestCompileDiscipline:
    def test_one_jit_per_stage_kind_and_no_retraces(self):
        """A mixed-kind swarm compiles each (stage, fwd/bwd, shapes)
        exactly once, and a second identical runner re-traces nothing
        (the process-wide program cache keyed on the plan's inputs)."""
        from repro.runtime.numeric import compile_stats, \
            reset_compile_stats
        cfg = mixed_config()
        opt = adamw(lr=1e-2, grad_clip=0.0)
        scfg = SwarmConfig(n_stages=2, microbatch_size=MB, seq_len=SEQ,
                           global_batch=GB, n_trainers=2,
                           rebalance_period=0.0, codec="none",
                           max_steps=2)
        reset_compile_stats()
        r1 = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
        r1.build(peers_per_stage=2)
        r1.run(until=1e6)
        s1 = compile_stats()
        assert s1["traces"] > 0
        assert all(n == 1 for n in s1["per_key"].values()), s1["per_key"]
        r2 = SwarmRunner(cfg, scfg, opt, numeric=True, seed=1)
        r2.build(peers_per_stage=2)
        r2.run(until=1e6)
        s2 = compile_stats()
        assert s2["traces"] == s1["traces"]          # zero re-traces
        assert s2["per_key"] == s1["per_key"]
