"""Elastic re-meshing policy: balanced stage partitioning + replans."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.launch.elastic import (balanced_splits, plan_mesh,
                                  replan_on_failure, replan_on_join,
                                  layer_costs)


def test_uniform_costs_split_evenly():
    assert balanced_splits([1.0] * 8, 4) == (2, 2, 2, 2)


def test_heterogeneous_costs_balance_maxload():
    # one heavy layer should sit alone
    costs = [1, 1, 1, 10]
    assert balanced_splits(costs, 2) == (3, 1)


def test_heterogeneous_pod_speeds():
    # a 2x faster second pod takes ~2x the layers
    splits = balanced_splits([1.0] * 9, 2, speeds=[1.0, 2.0])
    assert splits[1] > splits[0]


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=24),
       st.integers(1, 4))
def test_splits_partition_property(costs, n):
    if n > len(costs):
        return
    splits = balanced_splits(costs, n)
    assert len(splits) == n
    assert sum(splits) == len(costs)
    assert all(s >= 1 for s in splits)
    # optimality sanity: max stage <= total (trivial) and >= total/n
    prefix, lo = [], 0
    mx = 0.0
    for s in splits:
        mx = max(mx, sum(costs[lo:lo + s]))
        lo += s
    assert mx >= sum(costs) / n - 1e-9


def test_plan_and_replan_deepseek():
    cfg = get_config("deepseek-v2-236b")
    plan = plan_mesh(cfg, n_pods=4)
    assert sum(plan.layer_splits) == 60
    assert plan.bubble_fraction == pytest.approx(3 / 11)
    # pod failure: shrink to 3, all layers still covered
    p2 = replan_on_failure(cfg, plan, surviving_pods=3)
    assert sum(p2.layer_splits) == 60 and len(p2.layer_splits) == 3
    # join back
    p3 = replan_on_join(cfg, p2, new_total=4)
    assert p3.layer_splits == plan.layer_splits


def test_survives_to_single_pod():
    cfg = get_config("gemma-2b")
    plan = plan_mesh(cfg, 2)
    p = replan_on_failure(cfg, plan, surviving_pods=1)
    assert p.layer_splits == (cfg.n_layers,)


def test_layer_costs_uniform_for_uniform_archs():
    cfg = get_config("yi-6b")
    costs = layer_costs(cfg, 4096)
    assert len(set(costs)) == 1
