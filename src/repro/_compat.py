"""jax version compatibility shims.

The distribution layer is written against the modern mesh API
(``jax.make_mesh(..., axis_types=(jax.sharding.AxisType.Auto, ...))``).
Older jaxlibs (< 0.5) predate ``AxisType``; every mesh there is
implicitly GSPMD-auto, which is exactly the semantics the codebase
assumes, so the shim only has to make the *spelling* work:

* ``jax.sharding.AxisType`` — minimal enum with Auto/Explicit/Manual.
* ``jax.make_mesh`` — accept and drop an ``axis_types`` kwarg.

Import this module before any ``jax.make_mesh(axis_types=...)`` call
(``repro.dist`` and ``repro.launch.mesh`` both do).  On jax >= 0.5 the
shim is a no-op.  Importing jax here does NOT initialize a backend, so
the dry-run's XLA_FLAGS device-count override still wins (flags are
read at first backend init, not at import).
"""
from __future__ import annotations

import enum
import inspect

import jax


def _install() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types          # pre-AxisType jax: every axis is Auto
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh


_install()
