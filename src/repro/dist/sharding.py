"""Logical-axis -> mesh-axis sharding rules and NamedSharding builders.

Every parameter / cache tensor in the repo carries logical axis names on
its :class:`~repro.models.params.ParamSpec` (``("embed", "heads",
"head_dim")`` etc.).  :class:`ShardingRules` maps those names onto the
production mesh ``("pod", "data", "model")`` with two safety rules,
applied uniformly here and in :mod:`repro.dist.constrain`:

* **divisibility fallback** — a dim whose size does not divide the
  product of its mesh axes is dropped to replication (e.g. 4 kv-heads on
  a 16-way ``model`` axis);
* **first-use-wins** — a mesh axis may appear only once per
  PartitionSpec; later dims that want an already-taken axis replicate
  instead (e.g. a square ``("mlp", "embed2")`` weight).

``DEFAULT_RULES`` is FSDP-over-``data`` + tensor-parallel-over-``model``:
the paper's SWARM stages are *internally* data+tensor parallel, while the
``pod`` axis is reserved for the pipeline (``state_shardings(...,
pipeline=True)`` maps the stacked ``layers`` dim onto it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

from repro import _compat  # noqa: F401  (AxisType shim for older jax)
from repro.dist.constrain import AxisSpec, resolve_spec
from repro.models import params as P

Tree = Any


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """One ``logical axis name -> mesh axes`` table (str | tuple | None)."""

    rules: dict[str, AxisSpec]

    def with_rules(self, **overrides: AxisSpec) -> "ShardingRules":
        return ShardingRules(rules={**self.rules, **overrides})

    def spec_for(self, names, shape, mesh) -> jax.sharding.PartitionSpec:
        """PartitionSpec for one tensor with logical ``names`` per dim."""
        return resolve_spec([self.rules.get(n) for n in names], shape, mesh)

    def sharding_for(self, spec: P.ParamSpec, mesh) -> jax.sharding.NamedSharding:
        return jax.sharding.NamedSharding(
            mesh, self.spec_for(spec.axes, spec.shape, mesh))


DEFAULT_RULES = ShardingRules(rules={
    # structural dims
    "layers": None,           # stacked-layer dim; -> "pod" under pipeline
    "stage": "pod",
    # weight dims
    "embed": "data",          # FSDP: shard the embed dim over data
    "embed2": "model",        # second embed dim of square projections
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_dim": None,
    "v_dim": None,
    "vocab": "model",
    "experts": "model",       # expert parallelism shares the model axis
    "expert_mlp": None,
    "kv_lora": None,
    "q_lora": None,
    "bottleneck": "model",    # codec wire dim: TP like "mlp" (w_c/w_d are
                              # [embed, bottleneck] / [bottleneck, embed])
    "state": None,
    "conv": None,
    "pos": None,
    "null": None,
    # activation / cache dims
    "batch": ("pod", "data"),
    "kv_seq": None,
})


def _model_specs(cfg) -> Tree:
    from repro.train import steps as steps_lib   # lazy: steps imports models
    return steps_lib.model_specs(cfg)


def _spec_shardings(spec_tree: Tree, mesh,
                    rules: ShardingRules) -> Tree:
    return jax.tree.map(lambda s: rules.sharding_for(s, mesh),
                        spec_tree, is_leaf=P.is_spec)


def param_shardings(cfg, mesh, rules: Optional[ShardingRules] = None) -> Tree:
    """NamedSharding tree matching ``model_specs(cfg)`` / the params tree."""
    return _spec_shardings(_model_specs(cfg), mesh, rules or DEFAULT_RULES)


def stage_param_shardings(specs: Tree, mesh,
                          rules: Optional[ShardingRules] = None) -> Tree:
    """NamedSharding tree for an arbitrary ParamSpec tree — e.g. one
    pipeline stage's ``StageProgram.specs``, which is how
    :class:`repro.runtime.mesh.MeshExecutor` places a stage's parameters
    on its peer-local mesh by their logical axes."""
    return _spec_shardings(specs, mesh, rules or DEFAULT_RULES)


def state_shardings(cfg, mesh, *, pipeline: bool = False,
                    rules: Optional[ShardingRules] = None) -> Tree:
    """Shardings for the ``{"params", "opt", "step"}`` adamw train state.

    ``pipeline=True`` additionally maps the stacked ``layers`` dim onto
    ``pod`` so each pipeline stage owns exactly its slice of every
    layer-stacked weight (and of the matching optimizer moments).
    """
    rules = rules or DEFAULT_RULES
    if pipeline:
        rules = rules.with_rules(layers="pod", stage="pod")
    psh = param_shardings(cfg, mesh, rules)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return {"params": psh,
            "opt": {"m": psh, "v": psh, "count": repl},
            "step": repl}


def batch_shardings(cfg, mesh, specs: Tree,
                    batch_axis: AxisSpec = ("pod", "data")) -> Tree:
    """Shardings for an input-batch tree: batch dim over ``batch_axis``.

    mrope ``positions`` are ``[3, B, S]`` — the batch dim is dim 1 there
    (mirrors ``steps._split_microbatches``); everything else is batch-major.
    """
    del cfg

    def one(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes: list[AxisSpec] = [batch_axis] + [None] * (s.ndim - 1)
        if name == "positions" and s.ndim >= 2:
            axes = [None, batch_axis] + [None] * (s.ndim - 2)
        return jax.sharding.NamedSharding(
            mesh, resolve_spec(axes, s.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, specs)


def cache_shardings_from_specs(cfg, mesh, specs: Tree,
                               batch_axis: AxisSpec = ("pod", "data"),
                               rules: Optional[ShardingRules] = None) -> Tree:
    """Shardings for decode-cache ParamSpec trees (logical axes intact).

    Caches follow the param rules (kv-heads over ``model`` etc.) except
    that their ``batch`` dim tracks the cell's batch axis — inference
    cells fold ``pod`` into data parallelism, so the caller decides.
    """
    del cfg
    rules = (rules or DEFAULT_RULES).with_rules(batch=batch_axis)
    return _spec_shardings(specs, mesh, rules)
