"""Static intra-stage parallel execution layer (GSPMD).

SWARM's elastic scheduling layer (``repro.core``) decides *which* peers
hold *which* pipeline stage; this package is the other half of the
Varuna-style split — the static parallel execution of one configuration
once chosen:

* :mod:`repro.dist.constrain` — ``with_sharding_constraint`` wrapper that
  degrades to a no-op off-mesh, so single-device tests and the 512-device
  dry-run share one model code path.
* :mod:`repro.dist.sharding`  — logical-axis -> mesh-axis rules and the
  NamedSharding builders for params / train state / batches / caches.
* :mod:`repro.dist.pipeline`  — the GSPMD shifting-buffer pipeline train
  step over the ``pod`` mesh axis, with all four boundary-compression
  modes — int8 and the learned bottleneck/maxout codecs, whose ``w_c`` /
  ``w_d`` train jointly with the model (paper §3.1, App. J).

Submodules are imported explicitly (``from repro.dist import sharding``)
rather than re-exported here: ``repro.models`` imports
``repro.dist.constrain`` while ``repro.dist.sharding`` imports model
specs, and an eager re-export would turn that into an import cycle.
"""
