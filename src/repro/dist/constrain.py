"""Mesh-aware ``with_sharding_constraint`` that degrades to identity.

Model code annotates activations with the mesh axes they *would* occupy
on the production mesh, e.g.::

    x = constrain(x, ("pod", "data"), None, None)     # [B, S, d]

and the same line is correct everywhere:

* single-device smoke tests — no mesh installed, ``constrain`` is a no-op;
* the 2x2x2 CPU equivalence mesh — ``pod``/``data`` exist and divide, the
  hint is applied;
* the 512-device dry-run — full constraint.

Axes named in a spec but absent from the ambient mesh are dropped (a
``("pod", "data")`` spec on a single-pod ``("data", "model")`` mesh
becomes ``("data",)``), and any dim whose size does not divide the
product of its surviving mesh axes falls back to replication — the same
two rules :mod:`repro.dist.sharding` applies to parameters, so
activation hints can never contradict GSPMD's divisibility requirement.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax

from repro import _compat  # noqa: F401  (AxisType shim for older jax)

AxisSpec = Union[None, str, Sequence[str]]


def current_mesh() -> Optional[jax.sharding.Mesh]:
    """The ambient ``with mesh:`` context's mesh, or None off-mesh."""
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except (ImportError, AttributeError):
        pass
    try:  # newer jax: explicit-sharding world
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except AttributeError:
        pass
    return None


def _names(spec: AxisSpec) -> tuple[str, ...]:
    if spec is None:
        return ()
    if isinstance(spec, str):
        return (spec,)
    return tuple(spec)


def resolve_spec(axis_specs: Sequence[AxisSpec], shape: Sequence[int],
                 mesh) -> jax.sharding.PartitionSpec:
    """Apply the drop-absent / drop-indivisible / first-use-wins rules."""
    entries: list[AxisSpec] = []
    used: set[str] = set()
    for spec, size in zip(axis_specs, shape):
        axes = tuple(n for n in _names(spec)
                     if n in mesh.axis_names and n not in used)
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        if not axes or n_shards == 1 or size % n_shards:
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes[0] if len(axes) == 1 else axes)
    while entries and entries[-1] is None:
        entries.pop()
    return jax.sharding.PartitionSpec(*entries)


def constrain(x: jax.Array, *axis_specs: AxisSpec) -> jax.Array:
    """Constrain ``x`` onto the ambient mesh; identity when off-mesh."""
    if len(axis_specs) != x.ndim:
        raise ValueError(f"{len(axis_specs)} axis specs for rank-{x.ndim} "
                         f"array of shape {x.shape}")
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(axis_specs, x.shape, mesh)
    if not len(spec):                       # fully replicated: nothing to say
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
