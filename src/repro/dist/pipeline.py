"""GSPMD shifting-buffer SWARM pipeline over the ``pod`` mesh axis.

The elastic layer (``repro.core``) simulates SWARM's stochastic wiring;
this module is the *compiled* counterpart for one static configuration:
all pipeline stages live in one jitted step, stage-stacked parameters are
sharded over ``pod``, and microbatch activations travel between stages
through a shifting buffer — ``jnp.roll`` on the stage dim, which GSPMD
lowers to a collective-permute (Xu et al., 2021; the same construction
Praxis calls a layerwise-shardable pipeline).

Schedule: with S stages and M microbatches the loop runs ``T = M + S - 1``
ticks.  At tick ``t`` slot ``s`` holds microbatch ``t - s``; slot 0
ingests microbatch ``t`` (embedded on the fly), slot ``S-1`` emits
microbatch ``t - (S-1)`` into the loss.  Slots outside ``[0, M)`` compute
garbage that is never read — the cost of the classic ``(S-1)/T`` bubble.

Autodiff gives the reverse schedule for free: the transpose of ``roll``
is the opposite rotation, so gradients pipeline backwards through the
same buffer.  With ``compress="int8"`` every stage-boundary crossing is
blockwise-quantized in BOTH directions (activations forward, cotangents
backward) via :func:`repro.compression.quant8.compress_boundary` —
exactly what SWARM puts on the wire (paper §4.3, App. J).

Equivalence to ``repro.train.steps.make_train_step`` (same loss, same
gradients, within f32 tolerance) is enforced by
``tests/test_distribution.py`` on a 2x2x2 host-device mesh.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import _compat  # noqa: F401  (AxisType shim for older jax)
from repro.compression import quant8
from repro.dist.constrain import constrain
from repro.models import model as model_lib
from repro.models.blocks import REGISTRY
from repro.models.config import ArchConfig
from repro.optim.adamw import Optimizer

Tree = Any


def stage_periodic(cfg: ArchConfig, n_stages: int) -> bool:
    """Can this layer stack split into ``n_stages`` *identical* stages?

    The shifting-buffer pipeline vmaps ONE stage program over the stage
    dim, so every stage must run the same block-kind sequence:

    * encoder-decoder models (whisper) are never periodic — the two
      streams are structurally different;
    * ALBERT-style shared stacks are periodic iff the parameter groups
      split evenly (``share_groups % n_stages == 0``);
    * otherwise the block-kind pattern must tile: ``n_layers % n_stages
      == 0`` and each stage's slice of ``block_kinds`` identical (the
      xlstm (5 mLSTM, 1 sLSTM) x 2 arrangement is periodic at 2 stages;
      a 32-layer dense stack is not at 7).
    """
    if n_stages < 1:
        return False
    if cfg.family == "audio" or cfg.encoder_layers:
        return False
    if cfg.share_groups:
        return cfg.share_groups % n_stages == 0
    if cfg.n_layers % n_stages:
        return False
    per = cfg.n_layers // n_stages
    return cfg.block_kinds == cfg.block_kinds[:per] * n_stages


def _period_runs(cfg: ArchConfig, n_stages: int) -> list[tuple[str, int]]:
    """(kind, count) runs of ONE stage's slice of the layer pattern."""
    if cfg.share_groups:
        return [(cfg.block_kinds[0], cfg.share_groups // n_stages)]
    per = cfg.n_layers // n_stages
    return model_lib.segments(cfg.block_kinds[:per])


def _restack(per_stage: list) -> jax.Array:
    """Stack per-stage arrays along a new leading (pod-sharded) dim.

    Written as zeros + ``.at[s].set`` instead of ``jnp.stack``: the XLA
    0.4.x SPMD partitioner miscompiles a concatenate whose concat dim is
    sharded (here: over ``pod``) — stage s > 0 silently computes with
    corrupted weights, ~3e-2 loss error on the 2x2x2 equivalence mesh.
    Static-index dynamic-update-slices partition correctly (verified by
    the mixed-kind equivalence test in tests/test_distribution.py).
    """
    out = jnp.zeros((len(per_stage),) + per_stage[0].shape,
                    per_stage[0].dtype)
    for s, a in enumerate(per_stage):
        out = out.at[s].set(a)
    return out


def _stage_blocks(cfg: ArchConfig, blocks: Tree, n_stages: int) -> Tree:
    """Regroup ``params['blocks']`` (global layer stacks) into per-stage
    stacks: one tree per period run, leaves ``[n_stages, count, ...]``.

    Pure reshape for the common homogeneous cases.  For mixed-kind
    periodic patterns each (stage, period-run) segment is a contiguous
    same-kind layer range, so it sits inside exactly one maximal global
    run: a static slice of that run's stack, restacked across stages
    (differentiable, so gradients land back on the original stacks).
    """
    if cfg.share_groups:
        g = cfg.share_groups // n_stages
        return [jax.tree.map(
            lambda a: a.reshape(n_stages, g, *a.shape[1:]), blocks[0])]
    g_runs = model_lib.segments(cfg.block_kinds)
    per = cfg.n_layers // n_stages
    if len(g_runs) == 1:
        return [jax.tree.map(
            lambda a: a.reshape(n_stages, per, *a.shape[1:]), blocks[0])]
    starts = [0]
    for _, c in g_runs:
        starts.append(starts[-1] + c)
    out, off = [], 0
    for _, c in _period_runs(cfg, n_stages):
        stages = []
        for s in range(n_stages):
            lo_g = s * per + off                 # global start of the range
            ri = max(i for i in range(len(g_runs)) if starts[i] <= lo_g)
            lo = lo_g - starts[ri]
            stages.append(jax.tree.map(
                lambda a, _lo=lo: a[_lo:_lo + c], blocks[ri]))
        out.append(jax.tree.map(lambda *xs: _restack(list(xs)), *stages))
        off += c
    return out


def _make_stage_fn(cfg: ArchConfig, n_stages: int, remat: bool):
    """One stage's program: scan this stage's layer runs over (x, aux)."""
    period = _period_runs(cfg, n_stages)
    reps = cfg.n_layers // cfg.share_groups if cfg.share_groups else 1

    def stage_fn(blocks_s: Tree, x: jax.Array, aux: jax.Array, positions):
        for (kind, _), seg in zip(period, blocks_s):
            apply_fn = REGISTRY[kind][1]

            def body(carry, p_l, _apply=apply_fn):
                x, aux = carry
                for _ in range(reps):          # reps > 1: ALBERT sharing
                    x, a = _apply(cfg, p_l, x, positions)
                    aux = aux + a
                return (x, aux), None

            if remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), _ = jax.lax.scan(body, (x, aux), seg)
        return x, aux

    return stage_fn


def make_pipeline_train_step(cfg: ArchConfig, optimizer: Optimizer,
                             n_stages: int, n_microbatches: int, *,
                             remat: bool | str = True,
                             compress: Optional[str] = None):
    """Build ``(state, batch) -> (state, {"loss", "ce"})`` — the pipelined
    twin of ``steps.make_train_step``.

    ``compress=None`` defers to ``cfg.boundary_compression``; ``"none"``
    and ``"int8"`` are supported (the learned bottleneck/maxout codecs
    live on the elastic path only).
    """
    if not stage_periodic(cfg, n_stages):
        raise ValueError(f"{cfg.name}: layer stack is not periodic at "
                         f"{n_stages} stages (see stage_periodic)")
    comp = cfg.boundary_compression if compress is None else compress
    if comp not in ("none", "int8"):
        raise ValueError(f"unsupported boundary compression {comp!r} for "
                         "the GSPMD pipeline (use 'none' or 'int8')")
    do_remat = (remat != "none") if isinstance(remat, str) else bool(remat)
    stage_fn = _make_stage_fn(cfg, n_stages, do_remat)
    S_, M = n_stages, n_microbatches

    from repro.train import steps as steps_lib   # lazy: steps imports models

    def loss_fn(params: Tree, batch: Tree):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        if B % M:
            raise ValueError(f"batch {B} not divisible by {M} microbatches")
        mb = B // M
        tok_mb = tokens.reshape(M, mb, S)
        lab_mb = labels.reshape(M, mb, S)
        if "positions" in batch:                       # mrope: [3, B, S]
            p = batch["positions"]
            pos_mb = p.reshape(p.shape[0], M, mb, S).swapaxes(0, 1)
            pos_axis = 0
        else:
            pos_mb = model_lib.default_positions(cfg, mb, S)
            pos_axis = None                            # shared by all slots
        stage_blocks = [jax.tree.map(
            lambda a: constrain(a, "pod", *([None] * (a.ndim - 1))), t)
            for t in _stage_blocks(cfg, params["blocks"], S_)]
        v_stage = jax.vmap(stage_fn, in_axes=(0, 0, 0, pos_axis))

        def ingest(t):
            """Embed the microbatch entering slot 0 at tick ``t``."""
            x = model_lib.embed(cfg, params, tok_mb[jnp.clip(t, 0, M - 1)],
                                batch_axes=("data",))
            return constrain(x, "data", None, None)

        def tick(carry, t):
            buf, aux_buf, ces, auxs = carry
            buf = constrain(buf, "pod", "data", None, None)
            pos = (pos_mb if pos_axis is None
                   else pos_mb[jnp.clip(t - jnp.arange(S_), 0, M - 1)])
            out, aux_out = v_stage(stage_blocks, buf, aux_buf, pos)
            # the final stage owns the head: no boundary crossing here
            idx = jnp.clip(t - (S_ - 1), 0, M - 1)
            logits = model_lib.head(cfg, params, out[-1],
                                    batch_axes=("data",))
            ces = ces.at[idx].set(steps_lib.cross_entropy(
                logits, lab_mb[idx]))
            auxs = auxs.at[idx].set(aux_out[-1])
            # warm-up ticks (t < S-1) write garbage into slot 0 of ces/auxs;
            # the true microbatch-0 write at t == S-1 overwrites it, and the
            # scatter's transpose zeroes the dead cotangents.
            if comp == "int8":
                out = jax.vmap(quant8.compress_boundary)(out)
            buf = jnp.roll(out, 1, axis=0).at[0].set(ingest(t + 1))
            aux_buf = jnp.roll(aux_out, 1, 0).at[0].set(0.0)
            buf = constrain(buf, "pod", "data", None, None)
            return (buf, aux_buf, ces, auxs), None

        if do_remat:
            tick = jax.checkpoint(
                tick, policy=jax.checkpoint_policies.nothing_saveable)

        buf0 = jnp.zeros((S_, mb, S, cfg.d_model), cfg.compute_jdtype)
        buf0 = buf0.at[0].set(ingest(jnp.zeros((), jnp.int32)))
        carry0 = (buf0, jnp.zeros((S_,), jnp.float32),
                  jnp.zeros((M,), jnp.float32), jnp.zeros((M,), jnp.float32))
        (_, _, ces, auxs), _ = jax.lax.scan(
            tick, carry0, jnp.arange(M + S_ - 1))
        ce = ces.mean()
        return ce + auxs.mean(), ce

    def train_step(state: Tree, batch: Tree):
        params = state["params"]
        (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        updates, opt = optimizer.update(grads, state["opt"], params)
        new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
        return ({"params": new_params, "opt": opt,
                 "step": state["step"] + 1},
                {"loss": loss, "ce": ce})

    return train_step
