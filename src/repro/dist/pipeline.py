"""GSPMD shifting-buffer SWARM pipeline over the ``pod`` mesh axis.

The elastic layer (``repro.core``) simulates SWARM's stochastic wiring;
this module is the *compiled* counterpart for one static configuration:
all pipeline stages live in one jitted step, stage-stacked parameters are
sharded over ``pod``, and microbatch activations travel between stages
through a shifting buffer — ``jnp.roll`` on the stage dim, which GSPMD
lowers to a collective-permute (Xu et al., 2021; the same construction
Praxis calls a layerwise-shardable pipeline).

Schedule: with S stages and M microbatches the loop runs ``T = M + S - 1``
ticks.  At tick ``t`` slot ``s`` holds microbatch ``t - s``; slot 0
ingests microbatch ``t`` (embedded on the fly), slot ``S-1`` emits
microbatch ``t - (S-1)`` into the loss.  Slots outside ``[0, M)`` compute
garbage that is never read — the cost of the classic ``(S-1)/T`` bubble.

Autodiff gives the reverse schedule for free: the transpose of the
buffer shift is the opposite shift, so gradients pipeline backwards
through the same buffer.  All four boundary-compression modes of
``cfg.boundary_compression`` run here (paper §4.3, App. J):

* ``int8`` — every live boundary crossing is blockwise-quantized in BOTH
  directions (activations forward, cotangents backward) via
  :func:`repro.compression.quant8.compress_boundary`;
* ``bottleneck`` / ``maxout`` — the learned codecs: the buffer itself is
  the wire, so it carries the compressed ``c``-dim tensor; sending stage
  ``b`` compresses with ``w_c[b]``, receiving stage ``b+1`` decompresses
  with ``w_d[b]`` (``params["boundary"]``, attached by
  ``repro.train.steps.model_specs`` when ``cfg.pipeline_stages > 1``).
  Both are ordinary trainable params: gradients flow into them through
  the shifted buffer and the optimizer updates them with everything else.

Equivalence to the plain step / to :func:`make_reference_loss_fn` (same
loss, same gradients, within f32 tolerance) is enforced by
``tests/test_distribution.py`` and ``tests/test_codecs.py`` on a 2x2x2
host-device mesh.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import _compat  # noqa: F401  (AxisType shim for older jax)
from repro.compression import codecs
from repro.compression import quant8
from repro.dist.constrain import constrain
from repro.models import model as model_lib
from repro.models.blocks import REGISTRY
from repro.models.config import ArchConfig
from repro.models.stage_plan import StagePlan, get_stage_plan
from repro.optim.adamw import Optimizer

Tree = Any

# The jax version this repo's XLA workarounds are valid below.  Two
# shims are tied to the requirements.txt pin ``jax<0.5``:
#   * :func:`_restack` — the XLA 0.4.x SPMD partitioner miscompiles a
#     concatenate whose concat dim is sharded (see its docstring);
#   * ``repro._compat.AxisType`` — jax < 0.5 lacks
#     ``jax.sharding.AxisType`` / ``make_mesh(axis_types=...)``.
# tests/test_pins.py fails the moment the pin (or the installed jax)
# crosses this ceiling, flagging both for re-evaluation/removal.
JAX_PIN_CEILING = (0, 5)


def stage_periodic(cfg: ArchConfig, n_stages: int) -> bool:
    """Can this layer stack split into ``n_stages`` *identical* stages?

    The shifting-buffer pipeline vmaps ONE stage program over the stage
    dim, so every stage must run the same block-kind sequence:

    * encoder-decoder models (whisper) are never periodic — the two
      streams are structurally different;
    * ALBERT-style shared stacks are periodic iff the parameter groups
      split evenly (``share_groups % n_stages == 0``);
    * otherwise the block-kind pattern must tile: ``n_layers % n_stages
      == 0`` and each stage's slice of ``block_kinds`` identical (the
      xlstm (5 mLSTM, 1 sLSTM) x 2 arrangement is periodic at 2 stages;
      a 32-layer dense stack is not at 7).
    """
    if n_stages < 1:
        return False
    if cfg.family == "audio" or cfg.encoder_layers:
        return False
    try:
        return get_stage_plan(cfg, n_stages).periodic
    except ValueError:       # stack cannot split at this stage count
        return False


def _period_runs(cfg: ArchConfig, n_stages: int) -> list[tuple[str, int]]:
    """(kind, count) runs of ONE stage's slice of the layer pattern
    (periodic stacks: every stage's runs equal stage 0's)."""
    return list(get_stage_plan(cfg, n_stages).stages[0].runs)


def restack(per_stage: list) -> jax.Array:
    """Stack per-stage arrays along a new leading (pod-sharded) dim.

    Written as zeros + ``.at[s].set`` instead of ``jnp.stack``: the XLA
    0.4.x SPMD partitioner miscompiles a concatenate whose concat dim is
    sharded (here: over ``pod``) — stage s > 0 silently computes with
    corrupted weights, ~3e-2 loss error on the 2x2x2 equivalence mesh.
    Static-index dynamic-update-slices partition correctly (verified by
    the mixed-kind equivalence tests in tests/test_distribution.py, on
    BOTH call sites: the GSPMD tick below and the span-program stage
    scan of ``repro.runtime.stage_model.build_span_program``).
    """
    out = jnp.zeros((len(per_stage),) + per_stage[0].shape,
                    per_stage[0].dtype)
    for s, a in enumerate(per_stage):
        out = out.at[s].set(a)
    return out


_restack = restack          # historical (pre-span-builder) private name


def _stage_blocks(cfg: ArchConfig, blocks: Tree, n_stages: int) -> Tree:
    """Regroup ``params['blocks']`` (global layer stacks) into per-stage
    stacks: one tree per period run, leaves ``[n_stages, count, ...]``.

    Pure reshape for the common homogeneous cases.  For mixed-kind
    periodic patterns each (stage, period-run) segment is a contiguous
    same-kind layer range, so it sits inside exactly one maximal global
    run: a static slice of that run's stack, restacked across stages
    (differentiable, so gradients land back on the original stacks).
    """
    if cfg.share_groups:
        g = cfg.share_groups // n_stages
        return [jax.tree.map(
            lambda a: a.reshape(n_stages, g, *a.shape[1:]), blocks[0])]
    g_runs = model_lib.segments(cfg.block_kinds)
    per = cfg.n_layers // n_stages
    if len(g_runs) == 1:
        return [jax.tree.map(
            lambda a: a.reshape(n_stages, per, *a.shape[1:]), blocks[0])]
    starts = [0]
    for _, c in g_runs:
        starts.append(starts[-1] + c)
    out, off = [], 0
    for _, c in _period_runs(cfg, n_stages):
        stages = []
        for s in range(n_stages):
            lo_g = s * per + off                 # global start of the range
            ri = max(i for i in range(len(g_runs)) if starts[i] <= lo_g)
            lo = lo_g - starts[ri]
            stages.append(jax.tree.map(
                lambda a, _lo=lo: a[_lo:_lo + c], blocks[ri]))
        out.append(jax.tree.map(lambda *xs: restack(list(xs)), *stages))
        off += c
    return out


def make_block_core(cfg: ArchConfig, runs: list[tuple[str, int]],
                    reps: int = 1, *, remat: bool = False):
    """The span-parameterized stage core: scan ``runs`` of stacked layer
    params over ``(x, aux)``.  ONE implementation shared by every
    execution path — the GSPMD tick below (via :func:`_make_stage_fn`),
    the sequential reference, and the per-stage / span programs of
    ``repro.runtime.stage_model`` — so a stage computes identical math
    whether it runs vmapped in the shifting buffer, alone on a peer, or
    fused inside a span.

    ``blocks_s`` is one stage's ``[tree-per-run]`` list (leaves stacked
    ``[count, ...]``); ``reps > 1`` re-applies each layer (ALBERT-style
    sharing, paper §4.3).
    """
    def block_fn(blocks_s: Tree, x: jax.Array, aux: jax.Array, positions):
        for (kind, _), seg in zip(runs, blocks_s):
            apply_fn = REGISTRY[kind][1]

            def body(carry, p_l, _apply=apply_fn):
                x, aux = carry
                for _ in range(reps):          # reps > 1: ALBERT sharing
                    x, a = _apply(cfg, p_l, x, positions)
                    aux = aux + a
                return (x, aux), None

            if remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), _ = jax.lax.scan(body, (x, aux), seg)
        return x, aux

    return block_fn


def _make_stage_fn(cfg: ArchConfig, n_stages: int, remat: bool):
    """One (periodic) stage's program for the vmapped shifting buffer."""
    spec = get_stage_plan(cfg, n_stages).stages[0]
    return make_block_core(cfg, list(spec.runs), spec.reps, remat=remat)


def _resolve_codec(cfg: ArchConfig, n_stages: int,
                   compress: Optional[str]) -> str:
    """Validated boundary-compression mode for an ``n_stages`` pipeline."""
    comp = codecs.resolve_mode(cfg, compress)
    if n_stages == 1:
        return "none"                    # no boundaries to compress
    if comp in codecs.LEARNED and cfg.pipeline_stages != n_stages:
        raise ValueError(
            f"{cfg.name}: compress={comp!r} needs one learned codec pair "
            f"per boundary — set cfg.pipeline_stages={n_stages} (got "
            f"{cfg.pipeline_stages}) so model_specs attaches "
            "params['boundary']")
    return comp


def boundary_crossing(cfg: ArchConfig, comp: str, bparams: Optional[Tree],
                      b: int, x: jax.Array) -> jax.Array:
    """What boundary ``b`` (stage b -> b+1) does to the activation, given
    the stage-stacked codec tree (``bparams`` leading dim = boundary
    index).  The codec-boundary core shared by the sequential reference
    and the span programs of ``repro.runtime.stage_model`` — on-device
    when the boundary is fused inside a span, on the wire otherwise.
    Routed through the ``cfg.kernels``-aware codec helpers, so under
    ``"pallas"`` the encode(+QDQ) and dequantize+decode sides each
    collapse to one fused kernel launch."""
    if comp == "int8":
        return codecs.int8_boundary(cfg, x)
    if comp in codecs.LEARNED:
        pb = jax.tree.map(lambda a: a[b], bparams)
        return codecs.decode_wire(
            cfg, comp, pb, codecs.encode_wire(cfg, comp, pb, x))
    return x


def _boundary_params(params: Tree, comp: str, n_stages: int) -> Tree:
    bparams = params.get("boundary")
    if bparams is None:
        raise ValueError(
            f"compress={comp!r} but params carry no 'boundary' codec tree "
            "— build the state from repro.train.steps.model_specs with "
            "cfg.pipeline_stages set")
    nb = jax.tree.leaves(bparams)[0].shape[0]
    if nb != n_stages - 1:
        raise ValueError(f"params['boundary'] holds {nb} codec pairs, "
                         f"need {n_stages - 1} (one per boundary)")
    return bparams


def make_pipeline_train_step(cfg: ArchConfig, optimizer: Optimizer,
                             n_stages: int, n_microbatches: int, *,
                             remat: bool | str = True,
                             compress: Optional[str] = None):
    """Build ``(state, batch) -> (state, {"loss", "ce"})`` — the pipelined
    twin of ``steps.make_train_step``.

    ``compress=None`` defers to ``cfg.boundary_compression``; all four
    modes run here — ``"none"``, ``"int8"``, and the learned
    ``"bottleneck"`` / ``"maxout"`` codecs (which require
    ``cfg.pipeline_stages == n_stages`` so the state carries
    ``params["boundary"]``).
    """
    if not stage_periodic(cfg, n_stages):
        raise ValueError(f"{cfg.name}: layer stack is not periodic at "
                         f"{n_stages} stages (see stage_periodic)")
    comp = _resolve_codec(cfg, n_stages, compress)
    do_remat = (remat != "none") if isinstance(remat, str) else bool(remat)
    stage_fn = _make_stage_fn(cfg, n_stages, do_remat)
    S_, M = n_stages, n_microbatches

    from repro.train import steps as steps_lib   # lazy: steps imports models

    def loss_fn(params: Tree, batch: Tree):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        if B % M:
            raise ValueError(f"batch {B} not divisible by {M} microbatches")
        mb = B // M
        tok_mb = tokens.reshape(M, mb, S)
        lab_mb = labels.reshape(M, mb, S)
        if "positions" in batch:                       # mrope: [3, B, S]
            p = batch["positions"]
            pos_mb = p.reshape(p.shape[0], M, mb, S).swapaxes(0, 1)
            pos_axis = 0
        else:
            pos_mb = model_lib.default_positions(cfg, mb, S)
            pos_axis = None                            # shared by all slots
        stage_blocks = [jax.tree.map(
            lambda a: constrain(a, "pod", *([None] * (a.ndim - 1))), t)
            for t in _stage_blocks(cfg, params["blocks"], S_)]
        v_stage = jax.vmap(stage_fn, in_axes=(0, 0, 0, pos_axis))
        bparams = (_boundary_params(params, comp, S_)
                   if comp in codecs.LEARNED else None)
        wdim = codecs.wire_dim(cfg, comp)

        def encode(outs):
            """LIVE stage outputs [S-1, mb, S, d] -> wire [S-1, mb, S, c].

            Only ``out[:S-1]`` is encoded: the last stage's output would
            land in slot 0 and be overwritten by ``ingest`` — compressing
            that dead slot is pure waste (and would double-compress under
            the learned codecs)."""
            if comp == "int8":
                return jax.vmap(lambda x: codecs.int8_boundary(cfg, x))(
                    outs)
            if comp in codecs.LEARNED:       # boundary b uses w_c[b]
                return jax.vmap(
                    lambda p, x: codecs.encode_wire(cfg, comp, p, x))(
                        bparams, outs)
            return outs

        def decode(wire):
            """Wire [S, mb, S, c] -> stage inputs [S, mb, S, d].  Slot 0
            is dead (overwritten by ``ingest`` right after); slot ``s >=
            1`` decompresses boundary ``s-1`` with ``w_d[s-1]``."""
            if comp not in codecs.LEARNED:
                return wire                  # none/int8: wire is d-dim
            x = jax.vmap(lambda p, z: codecs.decode_wire(cfg, comp, p, z))(
                bparams, wire[1:])
            full = jnp.zeros(wire.shape[:-1] + (cfg.d_model,), wire.dtype)
            return full.at[1:].set(x)

        def ingest(t):
            """Embed the microbatch entering slot 0 at tick ``t``."""
            x = model_lib.embed(cfg, params, tok_mb[jnp.clip(t, 0, M - 1)],
                                batch_axes=("data",))
            return constrain(x, "data", None, None)

        def tick(carry, t):
            wire, aux_buf, ces, auxs = carry
            wire = constrain(wire, "pod", "data", None, None)
            x = decode(wire).at[0].set(ingest(t))
            x = constrain(x, "pod", "data", None, None)
            pos = (pos_mb if pos_axis is None
                   else pos_mb[jnp.clip(t - jnp.arange(S_), 0, M - 1)])
            out, aux_out = v_stage(stage_blocks, x, aux_buf, pos)
            # the final stage owns the head: no boundary crossing here
            idx = jnp.clip(t - (S_ - 1), 0, M - 1)
            logits = model_lib.head(cfg, params, out[-1],
                                    batch_axes=("data",))
            ces = ces.at[idx].set(steps_lib.cross_entropy(
                logits, lab_mb[idx]))
            auxs = auxs.at[idx].set(aux_out[-1])
            # warm-up ticks (t < S-1) write garbage into slot 0 of ces/auxs;
            # the true microbatch-0 write at t == S-1 overwrites it, and the
            # scatter's transpose zeroes the dead cotangents.
            #
            # Shift out[s] -> slot s+1 as a static-index update-slice (the
            # same construction _restack uses; a roll of the full buffer
            # would drag the dead last-stage output along for the ride).
            wire = jnp.zeros((S_, mb, S, wdim), out.dtype)
            wire = wire.at[1:].set(encode(out[:S_ - 1]))
            aux_buf = jnp.roll(aux_out, 1, 0).at[0].set(0.0)
            wire = constrain(wire, "pod", "data", None, None)
            return (wire, aux_buf, ces, auxs), None

        if do_remat:
            tick = jax.checkpoint(
                tick, policy=jax.checkpoint_policies.nothing_saveable)

        wire0 = jnp.zeros((S_, mb, S, wdim), cfg.compute_jdtype)
        carry0 = (wire0, jnp.zeros((S_,), jnp.float32),
                  jnp.zeros((M,), jnp.float32), jnp.zeros((M,), jnp.float32))
        (_, _, ces, auxs), _ = jax.lax.scan(
            tick, carry0, jnp.arange(M + S_ - 1))
        ce = ces.mean()
        return ce + auxs.mean(), ce

    def train_step(state: Tree, batch: Tree):
        params = state["params"]
        (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        updates, opt = optimizer.update(grads, state["opt"], params)
        new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
        return ({"params": new_params, "opt": opt,
                 "step": state["step"] + 1},
                {"loss": loss, "ce": ce})

    return train_step


def _plan_stage_blocks(cfg: ArchConfig, plan: StagePlan,
                       blocks: Tree) -> list[list[Tree]]:
    """Per-stage ``[tree-per-run]`` lists sliced from the global layer
    stacks — the non-periodic twin of :func:`_stage_blocks`.  Every
    plan run is a contiguous same-kind layer range, so it sits inside
    exactly one maximal global run: a static differentiable slice."""
    g_runs = model_lib.segments(cfg.block_kinds)
    starts = [0]
    for _, c in g_runs:
        starts.append(starts[-1] + c)
    per = cfg.n_layers // plan.n_stages
    out: list[list[Tree]] = []
    for s, spec in enumerate(plan.stages):
        off = s * per
        run_trees = []
        for _, c in spec.runs:
            ri = max(i for i in range(len(g_runs)) if starts[i] <= off)
            lo = off - starts[ri]
            run_trees.append(jax.tree.map(
                lambda a, _lo=lo, _c=c: a[_lo:_lo + _c], blocks[ri]))
            off += c
        out.append(run_trees)
    return out


def _make_whisper_reference_loss_fn(cfg: ArchConfig, n_stages: int,
                                    n_microbatches: int, comp: str):
    """Sequential staged whisper reference: encoder pod, then the
    decoder slice chain, with the tree-aware int8 boundary crossings the
    elastic path applies (boundary 0 quantizes the encoder output;
    interior boundaries quantize hidden + encoder state; token ids ride
    uncompressed).  ``batch["tokens"]`` is the composite
    ``{"audio", "tok"}`` payload the swarm feeds stage 0."""
    from repro.models import whisper as W
    from repro.train import steps as steps_lib   # lazy: steps imports models
    if comp in codecs.LEARNED:
        raise NotImplementedError(
            "learned boundary codecs are unsupported for encoder-decoder "
            "stacks (tree-valued boundaries)")
    M = n_microbatches
    per = cfg.n_layers // (n_stages - 1)

    def cross(x):
        return codecs.int8_boundary(cfg, x) if comp == "int8" else x

    def loss_fn(params: Tree, batch: Tree):
        audio, tok = batch["tokens"]["audio"], batch["tokens"]["tok"]
        labels = batch["labels"]
        B, S = tok.shape
        if B % M:
            raise ValueError(f"batch {B} not divisible by {M} microbatches")
        mb = B // M
        ces = []
        for m in range(M):
            au = audio.reshape(M, mb, *audio.shape[1:])[m]
            tk = tok.reshape(M, mb, S)[m]
            lab = labels.reshape(M, mb, S)[m]
            enc = cross(W.encode(cfg, params, au))        # boundary 0
            x = W.embed_tokens(cfg, params["embed"], tk)
            for s in range(1, n_stages):
                lo = (s - 1) * per
                blocks_s = jax.tree.map(
                    lambda a, _lo=lo: a[_lo:_lo + per],
                    params["dec_blocks"])
                x = W.dec_scan(cfg, blocks_s, x, enc, jnp.arange(S))
                if s < n_stages - 1:   # interior boundary: whole tree
                    x, enc = cross(x), cross(enc)
            logits = model_lib.head(cfg, params, x, batch_axes=("data",))
            ces.append(steps_lib.cross_entropy(logits, lab))
        ce = jnp.mean(jnp.stack(ces))
        return ce, ce

    return loss_fn


def make_reference_loss_fn(cfg: ArchConfig, n_stages: int,
                           n_microbatches: int, *,
                           compress: Optional[str] = None):
    """Sequential single-device twin of the pipelined loss: the SAME staged
    computation — per-microbatch stage chain with the identical boundary
    codec applied between consecutive stages — but with no vmap, no buffer
    shift and no bubble.  This is the equivalence oracle the codec tests
    compare :func:`make_pipeline_train_step` against (and the math the
    elastic path in ``repro.core`` executes peer-by-peer).

    Periodic stacks run the vmappable stage fn per stage (bit-identical
    to the historical behavior).  Non-periodic mixed-kind stacks and
    encoder-decoder stacks run their plan-driven stage chain — those
    have no GSPMD twin (``make_pipeline_train_step`` still requires
    periodicity) but serve as the elastic path's oracle."""
    try:
        plan = get_stage_plan(cfg, n_stages)
    except ValueError as e:
        raise ValueError(
            f"{cfg.name}: layer stack cannot split at {n_stages} stages "
            f"({e})") from e
    comp = _resolve_codec(cfg, n_stages, compress)
    if plan.is_encdec:
        return _make_whisper_reference_loss_fn(cfg, n_stages,
                                               n_microbatches, comp)
    periodic = plan.periodic
    stage_fn = _make_stage_fn(cfg, n_stages, remat=False) if periodic \
        else None
    M = n_microbatches

    from repro.train import steps as steps_lib   # lazy: steps imports models

    def loss_fn(params: Tree, batch: Tree):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        if B % M:
            raise ValueError(f"batch {B} not divisible by {M} microbatches")
        mb = B // M
        if periodic:
            stage_blocks = _stage_blocks(cfg, params["blocks"], n_stages)
        else:
            plan_blocks = _plan_stage_blocks(cfg, plan, params["blocks"])
            cores = [make_block_core(cfg, list(spec.runs), spec.reps)
                     for spec in plan.stages]
        bparams = (_boundary_params(params, comp, n_stages)
                   if comp in codecs.LEARNED else None)
        ces, auxs = [], []
        for m in range(M):
            tok = tokens.reshape(M, mb, S)[m]
            lab = labels.reshape(M, mb, S)[m]
            if "positions" in batch:                   # mrope: [3, B, S]
                p = batch["positions"]
                pos = p.reshape(p.shape[0], M, mb, S)[:, m]
            else:
                pos = model_lib.default_positions(cfg, mb, S)
            x = model_lib.embed(cfg, params, tok, batch_axes=("data",))
            aux = jnp.zeros((), jnp.float32)
            for s in range(n_stages):
                if periodic:
                    blocks_s = [jax.tree.map(lambda a: a[s], t)
                                for t in stage_blocks]
                    x, aux = stage_fn(blocks_s, x, aux, pos)
                else:
                    x, aux = cores[s](plan_blocks[s], x, aux, pos)
                if s < n_stages - 1:
                    x = boundary_crossing(cfg, comp, bparams, s, x)
            logits = model_lib.head(cfg, params, x, batch_axes=("data",))
            ces.append(steps_lib.cross_entropy(logits, lab))
            auxs.append(aux)
        ce = jnp.mean(jnp.stack(ces))
        return ce + jnp.mean(jnp.stack(auxs)), ce

    return loss_fn
