from repro.ckpt.checkpoint import (save_checkpoint, restore_checkpoint,
                                   available_steps, latest_step,
                                   prune_checkpoints, stage_dir)

__all__ = ["save_checkpoint", "restore_checkpoint", "available_steps",
           "latest_step", "prune_checkpoints", "stage_dir"]
