"""Checkpointing: atomic save/restore of arbitrary pytrees.

This is the restart half of SWARM's fault-tolerance story on TPU
(DESIGN.md §3): any surviving replica can serve the state, and a restarted
job may load onto a *different* topology — arrays are stored unsharded, so
re-sharding on restore is just pjit placement with new shardings.
Peer-to-peer "download state from neighbors" (paper Fig. 2) is modelled by
``repro.core.peer.PeerStore`` on top of the same serialization.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

Tree = Any

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Tree) -> str:
    """Atomically write ``{directory}/step_{step}`` and return its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        paths, leaves, _ = _flatten_with_paths(tree)
        arrays, dtypes = {}, []
        for i, x in enumerate(leaves):
            a = np.asarray(x)
            dtypes.append(str(a.dtype))
            if a.dtype.name == "bfloat16":      # npz has no bf16 cast
                a = a.astype(np.float32)
            arrays[f"a{i}"] = a
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump({"step": step, "paths": paths, "dtypes": dtypes}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def stage_dir(root: str, stage: int) -> str:
    """Per-pipeline-stage checkpoint directory: the elastic runner saves
    each stage's executor snapshot independently (stages fail — and
    resume — independently)."""
    return os.path.join(root, f"stage_{stage:03d}")


def _step_entries(directory: str) -> list[tuple[int, str]]:
    """``(step, entry_name)`` pairs of the checkpoint dirs under
    ``directory``, sorted by step.  The entry name is carried alongside
    the parsed number so callers never rebuild it (a hand-copied
    ``step_3`` without zero padding is still found and pruned)."""
    if not os.path.isdir(directory):
        return []
    return sorted((int(d.split("_")[1]), d) for d in os.listdir(directory)
                  if d.startswith("step_") and
                  d.split("_")[1].isdigit())


def latest_step(directory: str) -> Optional[int]:
    entries = _step_entries(directory)
    return entries[-1][0] if entries else None


def available_steps(directory: str) -> list[int]:
    """All checkpointed steps under ``directory``, ascending.  Multi-dir
    consumers (one dir per pipeline stage) intersect these to find the
    newest step every stage can actually serve — a process killed
    between per-stage saves leaves a torn cut that must not resume."""
    return [s for s, _ in _step_entries(directory)]


def _step_path(directory: str, step: int) -> str:
    for s, name in _step_entries(directory):
        if s == step:
            return os.path.join(directory, name)
    raise FileNotFoundError(f"no step_{step} checkpoint under {directory}")


def prune_checkpoints(directory: str, keep: int = 1) -> None:
    """Delete all but the newest ``keep`` step directories.  Restores
    only ever read the latest step, so per-step savers (the elastic
    runner checkpoints every ``ckpt_period`` steps) call this to bound
    disk growth.  Saves are atomic (rename), so keep=1 is safe."""
    if keep < 1:
        return
    for _, name in _step_entries(directory)[:-keep]:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def restore_checkpoint(directory: str, like: Tree,
                       step: Optional[int] = None) -> tuple[Tree, int]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = _step_path(directory, step)
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, leaves, treedef = _flatten_with_paths(like)
    if paths != manifest["paths"]:
        raise ValueError("checkpoint tree structure mismatch")
    new_leaves = []
    for i, leaf in enumerate(leaves):
        arr = data[f"a{i}"]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch at {paths[i]}: {arr.shape} vs "
                f"{np.shape(leaf)}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype)
                          if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
