"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

import jax

from repro import _compat  # noqa: F401  (AxisType shim for older jax)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Axes: ``pod`` carries SWARM pipeline stages (training) or folds into
    data parallelism (inference cells); ``data`` is FSDP/batch; ``model``
    is TP/EP.  See DESIGN.md §4.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_peer_mesh(n_devices: int = 0, axes=("data",)):
    """Peer-local mesh for a mesh-backed SWARM peer
    (:class:`repro.runtime.mesh.MeshExecutor`): the first ``n_devices``
    local devices (0 => all) on a 1-D ``data`` axis — the peer runs its
    stage data-parallel across them.  Works down to a single device, so
    mixed numeric/mesh swarms run anywhere (CPU tests included)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(
        shape, axes, devices=devs[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
