"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

import os

import jax

from repro import _compat  # noqa: F401  (AxisType shim for older jax)

# XLA latency-hiding flags for the async tick on real accelerators: let
# the scheduler move collectives (the boundary all-gathers, the ring
# All-Reduce) behind stage compute — the hardware analogue of the sim's
# in-flight Link transfers.  Spellings valid for the 0.4.x pin (older
# --xla_gpu_enable_async_collectives was removed upstream).
ASYNC_XLA_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_pipelined_collectives=true",
    "--xla_gpu_enable_while_loop_double_buffering=true",
)


def enable_async_xla_flags(force: bool = False) -> bool:
    """Append the latency-hiding/async-collective flags to ``XLA_FLAGS``,
    gated on ``REPRO_XLA_ASYNC=1`` (or ``force=True``) so plain imports
    never change compiler behavior.  Must run before the first jax
    initialization (same contract as the dry-run's flag handling);
    already-present flags are left alone.  Returns whether the env var
    now carries all async flags."""
    gate = os.environ.get("REPRO_XLA_ASYNC", "0").lower()
    if not force and gate not in ("1", "true", "yes"):
        return False
    current = os.environ.get("XLA_FLAGS", "")
    have = current.split()
    missing = [f for f in ASYNC_XLA_FLAGS
               if f.split("=")[0] not in
               {h.split("=")[0] for h in have}]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(have + missing)
    return True


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Axes: ``pod`` carries SWARM pipeline stages (training) or folds into
    data parallelism (inference cells); ``data`` is FSDP/batch; ``model``
    is TP/EP.  See DESIGN.md §4.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_peer_mesh(n_devices: int = 0, axes=("data",)):
    """Peer-local mesh for a mesh-backed SWARM peer
    (:class:`repro.runtime.mesh.MeshExecutor`): the first ``n_devices``
    local devices (0 => all) on a 1-D ``data`` axis — the peer runs its
    stage data-parallel across them.  Works down to a single device, so
    mixed numeric/mesh swarms run anywhere (CPU tests included)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(
        shape, axes, devices=devs[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
