"""Elastic re-meshing policy for the TPU launcher (DESIGN.md §3).

SWARM's control-plane ideas, re-used at slice granularity: when pods (or
slices) join/leave, the launcher recomputes the layers-per-pod partition
with the same load-balance objective as Algorithm 2 and restarts from the
latest checkpoint onto the new mesh.  This module is the *policy* (pure,
unit-tested); `repro.launch.train` + `repro.ckpt` are the mechanism
(resharding-capable checkpoint restore).

Balance objective: minimize the maximum per-pod stage cost (the pipeline
weakest-link law, §3.2), where a stage's cost is the sum of its layers'
per-token FLOPs — heterogeneous pods (e.g. mixed v5e/v5p fleets) divide by
their relative speed, exactly like IWRR weights peers by throughput.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.models.config import ArchConfig
from repro.models import flops as F


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_pods: int
    layer_splits: tuple[int, ...]      # layers per stage, one per pod
    microbatches: int
    bubble_fraction: float

    @property
    def stage_bounds(self) -> list[tuple[int, int]]:
        out, lo = [], 0
        for n in self.layer_splits:
            out.append((lo, lo + n))
            lo += n
        return out


def layer_costs(cfg: ArchConfig, seq: int) -> list[float]:
    ctx = F._ctx_for(cfg, seq, causal_avg=True)
    return [F.per_token_layer_flops(cfg, k, ctx) for k in cfg.block_kinds]


def balanced_splits(costs: Sequence[float], n_stages: int,
                    speeds: Optional[Sequence[float]] = None
                    ) -> tuple[int, ...]:
    """Contiguous partition of layers into n_stages minimizing the max
    stage cost/speed (DP over prefix sums; L, S are tiny)."""
    L = len(costs)
    speeds = list(speeds or [1.0] * n_stages)
    assert L >= n_stages >= 1
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    INF = float("inf")
    # best[s][i] = minimal max-cost partitioning first i layers into s
    best = [[INF] * (L + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (L + 1) for _ in range(n_stages + 1)]
    best[0][0] = 0.0
    for s in range(1, n_stages + 1):
        for i in range(s, L + 1):
            for j in range(s - 1, i):
                seg = (prefix[i] - prefix[j]) / speeds[s - 1]
                v = max(best[s - 1][j], seg)
                if v < best[s][i]:
                    best[s][i] = v
                    cut[s][i] = j
    splits, i = [], L
    for s in range(n_stages, 0, -1):
        j = cut[s][i]
        splits.append(i - j)
        i = j
    return tuple(reversed(splits))


def plan_mesh(cfg: ArchConfig, n_pods: int, seq: int = 4096,
              microbatches: int = 8,
              pod_speeds: Optional[Sequence[float]] = None) -> MeshPlan:
    if n_pods <= 1 or cfg.n_layers < n_pods:
        return MeshPlan(max(n_pods, 1), (cfg.n_layers,), microbatches, 0.0)
    splits = balanced_splits(layer_costs(cfg, seq), n_pods, pod_speeds)
    bubble = (n_pods - 1) / (microbatches + n_pods - 1)
    return MeshPlan(n_pods, splits, microbatches, bubble)


def replan_on_failure(cfg: ArchConfig, plan: MeshPlan,
                      surviving_pods: int, seq: int = 4096) -> MeshPlan:
    """A pod died: shrink the pipeline (Alg. 2's migration collapses to
    re-partitioning at slice granularity) and restart from checkpoint.
    Survives down to a single pod — SWARM's '>= 1 peer per stage'
    invariant maps to '>= 1 pod total'."""
    assert surviving_pods >= 1
    return plan_mesh(cfg, surviving_pods, seq, plan.microbatches)


def replan_on_join(cfg: ArchConfig, plan: MeshPlan, new_total: int,
                   seq: int = 4096) -> MeshPlan:
    return plan_mesh(cfg, new_total, seq, plan.microbatches)
