"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 100 \
        --batch 16 --seq 256 --ckpt-dir /tmp/ckpt [--reduced] [--accum 2] \
        [--remat 2level] [--dpu]

On this CPU container use ``--reduced`` (same-family tiny config); on a
real TPU fleet the full config shards over ``make_production_mesh()``.
Fault tolerance: the driver checkpoints every ``--ckpt-every`` steps and
resumes from the latest checkpoint on restart — combined with an external
supervisor (restart-on-failure), this is the slice-granular half of
SWARM's fault-tolerance story (DESIGN.md §3); the peer-granular half lives
in the simulator (`repro.core.swarm`).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.synthetic import SyntheticLM
from repro.optim import adamw, lamb, delayed_parameter_updates
from repro.train.steps import make_train_step, make_state
from repro.ckpt import save_checkpoint, restore_checkpoint, latest_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", choices=["adamw", "lamb"],
                    default="adamw")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="block",
                    choices=["block", "2level", "none"])
    ap.add_argument("--dpu", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    opt = (adamw(lr=args.lr) if args.optimizer == "adamw"
           else lamb(lr=args.lr))
    if args.dpu:
        opt = delayed_parameter_updates(opt)

    state = make_state(cfg, opt, jax.random.PRNGKey(args.seed))
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt, remat=args.remat,
                                      accum=args.accum))
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=17)
    n_hosts = jax.process_count()

    t0 = time.time()
    for i in range(start, args.steps):
        batch = ds.batch(i, host_index=jax.process_index(),
                         host_count=n_hosts)
        if cfg.rope == "mrope":
            import jax.numpy as jnp
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq), (3, batch["tokens"].shape[0],
                                       args.seq))
        if cfg.family == "audio":
            import jax.numpy as jnp
            batch["audio_embed"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(7), i),
                (batch["tokens"].shape[0], cfg.encoder_max_len,
                 cfg.d_model), cfg.compute_jdtype)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), f"loss diverged at step {i}"
        if i % 5 == 0 or i == args.steps - 1:
            dt = (time.time() - t0) / max(i - start + 1, 1)
            print(f"step {i:5d}  loss {loss:8.4f}  {dt:6.2f}s/step")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, state)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    print("done")


if __name__ == "__main__":
    main()
