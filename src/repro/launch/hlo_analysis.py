"""HLO post-processing for the roofline: collective-byte accounting and the
layer FLOP probe.

Collective bytes: ``compiled.as_text()`` is the *partitioned* module, so
tensor shapes are per-device.  We sum the payload bytes of every
``all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute`` op; ops inside ``while`` bodies are multiplied by the
loop trip count, recovered from the largest integer constant compared
against the induction variable in the loop's condition computation (scan
lowers to exactly that pattern).

FLOP probe: see :mod:`repro.models.probe` — XLA counts a while body once,
so the per-layer body is lowered standalone (inner chunk loops collapsed)
and totals are reconstructed as ``graph + (n-1) x layer``.
"""
from __future__ import annotations

import re
from typing import Optional

import numpy as np

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> int:
    """'bf16[4096,512]{1,0}' -> byte size; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if m and ("{" in line):
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = m.group(1), [line]
        elif cur_name is not None:
            cur_lines.append(line)
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name, cur_lines = None, []
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _while_trip_counts(hlo: str, comps: dict[str, str]) -> dict[str, int]:
    """while body computation name -> estimated trip count."""
    trip: dict[str, int] = {}
    for m in re.finditer(
            r"while\([^)]*\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)",
            hlo):
        cond, body = m.group(1), m.group(2)
        ctext = comps.get(cond, "")
        consts = [int(c) for c in
                  re.findall(r"constant\((\d+)\)", ctext)]
        trip[body] = max(consts) if consts else 1
    return trip


def _comp_of_line_index(hlo: str) -> list[tuple[str, str]]:
    """[(computation_name, line), ...] for every op line."""
    out = []
    cur = "entry"
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if m and "{" in line:
            cur = m.group(1)
        out.append((cur, line))
    return out


def collective_bytes(hlo: str) -> dict:
    """Per-device payload bytes by collective kind, trip-count scaled."""
    comps = _split_computations(hlo)
    trips = _while_trip_counts(hlo, comps)
    # nested whiles: body of outer loop may contain inner while; approximate
    # by single-level scaling (scan-of-scan multiplies below).
    parents: dict[str, int] = dict(trips)

    def total_trip(comp: str, depth=0) -> int:
        # find enclosing loops: any body that calls this computation
        if depth > 4:
            return parents.get(comp, 1)
        t = parents.get(comp, 1)
        for body, bt in parents.items():
            if body == comp:
                continue
            btext = comps.get(body, "")
            if re.search(r"(condition|body)=%?" + re.escape(comp) + r"\b",
                         btext):
                t *= total_trip(body, depth + 1)
                break
        return t

    counts = {k: 0 for k in COLLECTIVES}
    bytes_ = {k: 0.0 for k in COLLECTIVES}
    ops = []
    for comp, line in _comp_of_line_index(hlo):
        for kind in COLLECTIVES:
            if re.search(r"=\s*\S*\s*" + kind + r"(\.\d+)?\(", line) or \
               re.search(r"\b" + kind + r"(-start|-done)?\(", line):
                # result type precedes '=' on the lhs:  %x = bf16[...] kind(
                mt = re.search(r"=\s*(\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s*"
                               + kind, line)
                payload = shape_bytes(mt.group(1)) if mt else 0
                scale = total_trip(comp)
                counts[kind] += 1
                bytes_[kind] += payload * scale
                ops.append({"kind": kind, "comp": comp, "bytes": payload,
                            "trip": scale})
                break
    return {"counts": counts, "bytes": bytes_,
            "total_bytes": float(sum(bytes_.values())),
            "n_ops": len(ops)}


# -------------------------------------------------------------- FLOP probe
def layer_flop_probe(cfg, shape) -> dict:
    """Lower one layer of each distinct block kind (inner loops collapsed,
    single device, global batch) and return per-kind fwd/train FLOPs +
    reconstruction constants. See repro/models/probe.py."""
    import jax
    import jax.numpy as jnp
    from repro.models import probe as probe_lib
    from repro.models import model as model_lib
    from repro.models import params as Pm
    from repro.models.blocks import REGISTRY
    from repro.models import flops as F

    B, S = shape.global_batch, shape.seq_len
    runs = model_lib.segments(cfg.block_kinds)
    kinds = sorted({k for k, _ in runs})
    out = {"kinds": {}, "runs": [[k, n] for k, n in runs],
           "n_layers": cfg.n_layers}
    decode = shape.kind == "decode"

    with probe_lib.probe_mode():
        for kind in kinds:
            specs = REGISTRY[kind][0](cfg)
            aspecs = Pm.abstract(specs)
            if decode:
                cache_sp = Pm.abstract(REGISTRY[kind][3](cfg, B, S))
                x_sp = jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                            cfg.compute_jdtype)

                def f(p, c, x):
                    pos = jnp.zeros((B, 1), jnp.int32) if cfg.rope != \
                        "mrope" else jnp.zeros((3, B, 1), jnp.int32)
                    y, _ = REGISTRY[kind][2](cfg, p, x, c,
                                             jnp.int32(S - 1), pos)
                    return jnp.sum(y.astype(jnp.float32))
                flops = _flops_of(jax.jit(f).lower(aspecs, cache_sp, x_sp))
            else:
                x_sp = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                            cfg.compute_jdtype)
                pos = (jnp.zeros((3, B, S), jnp.int32) if cfg.rope == "mrope"
                       else jnp.arange(S))

                def f(p, x):
                    y, aux = REGISTRY[kind][1](cfg, p, x, pos)
                    return jnp.sum(y.astype(jnp.float32)) + aux
                if shape.kind == "train":
                    g = jax.grad(lambda p, x: f(p, x), argnums=(0, 1))
                    flops = _flops_of(jax.jit(g).lower(aspecs, x_sp))
                else:
                    flops = _flops_of(jax.jit(f).lower(aspecs, x_sp))
            out["kinds"][kind] = flops
            if kind == "slstm":   # time recurrence stays a loop: analytic
                per_tok = F._slstm_flops(cfg)
                mult = 3.0 if shape.kind == "train" else 1.0
                out["kinds"][kind] = per_tok * B * (1 if decode else S) \
                    * mult
    # whisper encoder layers (probe the generic attn encoder block cost)
    if cfg.encoder_layers:
        out["encoder_note"] = "enc layers approximated by attn kind"
    return out


def _flops_of(lowered) -> float:
    c = lowered.compile().cost_analysis() or {}
    if isinstance(c, (list, tuple)):        # jax < 0.5: one dict per program
        c = c[0] if c else {}
    return float(c.get("flops", 0.0))


def corrected_flops(record: dict, chips: int) -> Optional[float]:
    """Reconstruct total per-device FLOPs: graph + (n_r - 1) x layer_kind
    for every run (probe FLOPs are global -> divide by chips)."""
    probe = record.get("probe")
    if not probe:
        return None
    total = float(record["hlo_flops_per_device_raw"])
    for kind, n in probe["runs"]:
        if n > 1:
            total += (n - 1) * probe["kinds"][kind] / chips
    return total
