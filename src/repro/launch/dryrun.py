import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing module (device count locks on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with full configs as ShapeDtypeStructs (no allocation), record
memory/cost analysis + the collective schedule, and emit one JSON artifact
per cell for the roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 8]
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (REGISTRY, ASSIGNED, SHAPES, get_config,
                           cell_supported, ShapeSpec)
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis
from repro.models.config import ArchConfig
from repro.models import probe as probe_lib
from repro.optim import adamw
from repro.train import steps as steps_lib
from repro.dist import sharding as sh
from repro.dist import pipeline as pipe_lib

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")

PIPELINE_MICROBATCHES = 8


def _pod_axes(mesh) -> bool:
    return "pod" in mesh.axis_names


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, remat="block",
               accum=1, opt_bf16=False, full_logits=False,
               strategy="auto"):
    """Returns (fn, args, in_shardings, donate) for this cell."""
    multipod = _pod_axes(mesh)
    batch_axis = ("pod", "data") if multipod else "data"
    specs = steps_lib.input_specs(cfg, shape)
    if strategy == "dp":
        # small-model strategy: replicate parameters, shard the batch over
        # BOTH axes — kills every TP psum/all-gather; the only collective
        # left is one gradient all-reduce (EXPERIMENTS.md §Perf, xlstm)
        batch_axis = (("pod", "data", "model") if multipod
                      else ("data", "model"))
        # keep the vocab shard: a replicated LM head re-multiplies the
        # full [T,d]x[d,V] on every chip (xlstm iter-1 lesson: +2.3x flops)
        dp_rules = {k: None for k in sh.DEFAULT_RULES.rules}
        dp_rules["vocab"] = "model"
        sh_kw = dict(rules=sh.ShardingRules(rules=dp_rules))
    else:
        sh_kw = {}

    if shape.kind == "train":
        opt = adamw(state_dtype=jnp.bfloat16 if opt_bf16 else jnp.float32)
        if opt_bf16:
            st = specs["state"]
            st["opt"]["m"] = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
                st["opt"]["m"])
            st["opt"]["v"] = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
                st["opt"]["v"])
        pipeline = multipod and pipe_lib.stage_periodic(cfg, mesh.shape["pod"])
        if pipeline:
            step = pipe_lib.make_pipeline_train_step(
                cfg, opt, mesh.shape["pod"], PIPELINE_MICROBATCHES)
            st_sh = sh.state_shardings(cfg, mesh, pipeline=True)
            b_axis = "data"      # microbatching consumes the pod axis
        else:
            step = steps_lib.make_train_step(cfg, opt, remat=remat,
                                             accum=accum)
            st_sh = sh.state_shardings(cfg, mesh, **sh_kw)
            b_axis = batch_axis
        in_sh = (st_sh, sh.batch_shardings(cfg, mesh, specs["batch"],
                                           batch_axis=b_axis))
        scalar = jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec())
        out_sh = (st_sh, {"loss": scalar, "ce": scalar})
        return (step, (specs["state"], specs["batch"]), in_sh, (0,),
                pipeline, out_sh)

    if shape.kind == "prefill":
        step = steps_lib.make_prefill_step(cfg, last_only=not full_logits)
        p_sh = sh.param_shardings(cfg, mesh)
        in_sh = (p_sh, sh.batch_shardings(cfg, mesh, specs["batch"],
                                          batch_axis=batch_axis))
        # emitted decode caches must land sharded, not replicated
        cache_sh = sh.cache_shardings_from_specs(
            cfg, mesh, steps_lib.decode_cache_param_specs(cfg, shape),
            batch_axis=batch_axis)
        tok_sh = sh.batch_shardings(
            cfg, mesh,
            {"t": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)},
            batch_axis=batch_axis)["t"]
        out_sh = (tok_sh, cache_sh)
        return (step, (specs["params"], specs["batch"]), in_sh, (), False,
                out_sh)

    # decode
    step = steps_lib.make_serve_step(cfg)
    p_sh = sh.param_shardings(cfg, mesh)
    cache_param_specs = steps_lib.decode_cache_param_specs(cfg, shape)
    c_sh = sh.cache_shardings_from_specs(cfg, mesh, cache_param_specs,
                                         batch_axis=batch_axis)
    tok_sh = sh.batch_shardings(
        cfg, mesh, {"tokens": specs["token"]}, batch_axis=batch_axis
    )["tokens"]
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    in_sh = (p_sh, c_sh, tok_sh, scalar)
    args = (specs["params"], specs["caches"], specs["token"], specs["pos"])
    return step, args, in_sh, (1,), False, (tok_sh, c_sh)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             skip_probe: bool = False, remat: str = "block",
             accum: int = 1, cf: float = 0.0,
             opt_bf16: bool = False, full_logits: bool = False,
             strategy: str = "auto") -> dict:
    cfg = get_config(arch)
    if cf and cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.with_overrides(
            moe=_dc.replace(cfg.moe, capacity_factor=cf))
    shape = SHAPES[shape_name]
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "time": time.strftime("%Y-%m-%d %H:%M:%S")}
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    step, args, in_sh, donate, pipeline, out_sh = build_cell(
        cfg, shape, mesh, remat=remat, accum=accum, opt_bf16=opt_bf16,
        full_logits=full_logits, strategy=strategy)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax < 0.5: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = hlo_analysis.collective_bytes(hlo)
    record.update({
        "status": "ok",
        "remat": remat,
        "accum": accum,
        "capacity_factor": cf or None,
        "pipeline": bool(pipeline),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "hlo_flops_per_device_raw": cost.get("flops", 0.0),
        "hlo_bytes_per_device_raw": cost.get("bytes accessed", 0.0),
        "collectives": colls,
    })

    if shape.kind != "train" or not skip_probe:
        try:
            probe = hlo_analysis.layer_flop_probe(cfg, shape)
            record["probe"] = probe
        except Exception as e:           # probe is best-effort
            record["probe_error"] = f"{type(e).__name__}: {e}"
    return record


def artifact_path(arch: str, shape: str, mesh: str) -> str:
    d = os.path.abspath(ARTIFACT_DIR)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{mesh}__{arch}__{shape}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel worker processes for --all")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="block",
                    choices=["block", "2level", "none"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--opt-bf16", action="store_true")
    ap.add_argument("--full-logits", action="store_true",
                    help="paper-naive prefill emitting [B,S,V] logits")
    ap.add_argument("--strategy", default="auto", choices=["auto", "dp"])
    ap.add_argument("--cf", type=float, default=0.0)
    ap.add_argument("--tag", default="",
                    help="artifact name suffix (hillclimb iterations)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s, m) for m in meshes for a in ASSIGNED
                 for s in SHAPES]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, m) for m in meshes]

    if args.jobs > 1 and len(cells) > 1:
        pending = [(a, s, m) for (a, s, m) in cells
                   if args.force or not os.path.exists(artifact_path(a, s, m))]
        print(f"{len(pending)} cells to run, {args.jobs} workers")
        procs: list = []
        n_fail = 0
        while pending or procs:
            while pending and len(procs) < args.jobs:
                a, s, m = pending.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--mesh", m]
                procs.append(((a, s, m), subprocess.Popen(
                    cmd, stdout=subprocess.DEVNULL,
                    stderr=subprocess.PIPE)))
            done = []
            for i, (cell, p) in enumerate(procs):
                if p.poll() is not None:
                    done.append(i)
                    tag = "OK" if p.returncode == 0 else "FAIL"
                    print(f"[{tag}] {cell}")
                    if p.returncode != 0:
                        n_fail += 1
                        sys.stderr.write(p.stderr.read().decode()[-2000:])
            for i in reversed(done):
                procs.pop(i)
            time.sleep(0.5)
        # propagate worker failures so CI lanes (the weekly --all sweep)
        # actually gate on the sweep, mirroring the sequential branch below
        sys.exit(1 if n_fail else 0)

    n_fail = 0
    for a, s, m in cells:
        path = artifact_path(a, s, m + args.tag if args.tag else m)
        if not args.force and os.path.exists(path) and args.all:
            print(f"[cached] {m}/{a}/{s}")
            continue
        try:
            rec = run_cell(a, s, m, remat=args.remat, accum=args.accum,
                           cf=args.cf, opt_bf16=args.opt_bf16,
                           full_logits=args.full_logits,
                           strategy=args.strategy)
        except Exception as e:
            rec = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            n_fail += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        stat = rec["status"]
        extra = ""
        if stat == "ok":
            extra = (f" compile={rec['compile_s']}s "
                     f"peak/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB"
                     f" flops/dev={rec['hlo_flops_per_device_raw']:.3g}")
        elif stat == "error":
            extra = " " + rec["error"][:160]
        print(f"[{stat}] {m}/{a}/{s}{extra}")
        # memory_analysis + cost_analysis proof lines (spec step 3)
        sys.stdout.flush()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
