"""Adaptive swarm rebalancing (paper §3.2 + Appendix D, Algorithm 2).

Every ``T`` seconds each peer writes its local queue size under
``DHT[load/<stage>]``; the peer with the smallest queue in the
minimum-load stage migrates to the maximum-load stage, downloading the
target stage's parameters + optimizer state from its new neighbors.
Complexity O(M·S) per round (App. D); only the single migrating peer stops
serving during the download.

``plan_migration`` is the pure decision function (unit-tested directly and
reused by the TPU launcher's stage->pod rebalancing, DESIGN.md §3); the
coroutine that executes it lives in :mod:`repro.core.swarm`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Hashable, Optional


@dataclasses.dataclass(frozen=True)
class Migration:
    peer: Hashable
    src_stage: int
    dst_stage: int


def stage_loads(dht, n_stages: int) -> list[float]:
    """Sum the per-peer queue sizes announced for every stage (lines 7-18)."""
    loads = []
    for s in range(n_stages):
        recs = dht.get(dht.load_key(s))
        loads.append(float(sum(r.value for r in recs.values())))
    return loads


def plan_migration(dht, n_stages: int,
                   peers_per_stage: dict[int, list[Hashable]]
                   ) -> Optional[Migration]:
    """Algorithm 2, lines 5-31, computed from the DHT snapshot.

    Never empties a stage (SWARM requires >= 1 peer per stage, App. A).
    Returns None when the swarm is already balanced or the min stage has a
    single peer.
    """
    loads = stage_loads(dht, n_stages)
    s_min = min(range(n_stages), key=lambda s: loads[s])
    s_max = max(range(n_stages), key=lambda s: loads[s])
    if s_min == s_max or loads[s_max] <= loads[s_min]:
        return None
    donors = peers_per_stage.get(s_min, [])
    if len(donors) <= 1:
        return None

    recs = dht.get(dht.load_key(s_min))
    q_min, peer_min = math.inf, None
    for peer in donors:
        q = recs.get(peer)
        qv = q.value if q is not None else math.inf
        if qv < q_min:
            q_min, peer_min = qv, peer
    if peer_min is None:
        return None
    return Migration(peer_min, s_min, s_max)


def optimal_assignment(n_peers: int, n_stages: int,
                       stage_costs: Optional[list[float]] = None
                       ) -> list[int]:
    """Throughput-optimal peer counts per stage (the 'always optimal'
    baseline of Table 5): proportional to per-stage compute cost, each
    stage >= 1."""
    costs = stage_costs or [1.0] * n_stages
    total = sum(costs)
    alloc = [max(1, round(n_peers * c / total)) for c in costs]
    # fix rounding to sum exactly n_peers, never dropping below 1
    while sum(alloc) > n_peers:
        i = max(range(n_stages), key=lambda j: alloc[j])
        if alloc[i] > 1:
            alloc[i] -= 1
        else:
            break
    while sum(alloc) < n_peers:
        i = min(range(n_stages),
                key=lambda j: alloc[j] / max(costs[j], 1e-9))
        alloc[i] += 1
    return alloc


def pipeline_throughput(alloc: list[int], peer_speed: float = 1.0,
                        stage_costs: Optional[list[float]] = None) -> float:
    """Steady-state pipeline throughput = min over stages of aggregate
    stage speed (the weakest-link law, §3.2)."""
    costs = stage_costs or [1.0] * len(alloc)
    if any(a <= 0 for a in alloc):
        return 0.0
    return min(a * peer_speed / c for a, c in zip(alloc, costs))
