"""Adaptive swarm rebalancing (paper §3.2 + Appendix D, Algorithm 2).

Every ``T`` seconds each peer writes its local queue size under
``DHT[load/<stage>]``; the peer with the smallest queue in the
minimum-load stage migrates to the maximum-load stage, downloading the
target stage's parameters + optimizer state from its new neighbors.
Complexity O(M·S) per round (App. D); only the single migrating peer stops
serving during the download.

Assignments are *spans*: a peer may serve a contiguous ``[lo, hi)`` range
of stages fused in one jit (the square-cube lever, §3.1 — strong peers
hold more of the model, and every fused boundary saves its host wire
bytes).  :func:`optimal_assignment` with ``spans=True`` therefore
partitions the pipeline into per-peer spans (never worse than the best
single-stage placement — the width-1 assignment is always a candidate),
:func:`pipeline_throughput` prices span assignments with an explicit
per-host-boundary cost, and :func:`plan_span_change` proposes the
split/merge moves the runner executes via ``SwarmRunner._resize_span``.

``plan_migration`` is the pure decision function (unit-tested directly and
reused by the TPU launcher's stage->pod rebalancing, DESIGN.md §3); the
coroutines that execute the plans live in :mod:`repro.core.swarm`.

Scale: one planning round is driven by a :class:`ControlSnapshot` — the
per-stage load tables read from the DHT exactly ONCE per key — and the
decision functions run in O(P·S + P log P) for P peers over S stages
(incremental coverage / span-multiset maps instead of per-candidate DHT
re-reads and layout rebuilds, a heap over chunk rates instead of
re-deriving every stage's aggregate rate per surplus peer).  The paper's
target fleet is ~1000 preemptible T4s (§4.3, App. I); at that scale the
pre-snapshot planners were the hot path (tens of seconds per
``optimal_assignment(spans=True)`` call — see
``benchmarks/bench_control.py`` for the recorded baseline).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Hashable, Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Migration:
    peer: Hashable
    src_stage: int
    dst_stage: int


@dataclasses.dataclass(frozen=True)
class SpanChange:
    """Resize ``peer``'s span in place (Varuna-style re-partitioning):
    ``new_span`` ⊂ ``old_span`` is a split/shrink (concentrate on the
    bottleneck stage), ``new_span`` ⊃ ``old_span`` a merge/grow (absorb
    an adjacent well-covered stage, saving its host boundary)."""
    peer: Hashable
    old_span: tuple[int, int]
    new_span: tuple[int, int]


@dataclasses.dataclass(frozen=True)
class ControlSnapshot:
    """One planning round's frozen view of the control-plane DHT.

    Captured with exactly ONE ``DHT.get`` per load key (S gets per
    round) and shared by every decision function of the round —
    pre-snapshot, ``plan_migration``/``plan_span_change`` re-read the
    DHT per (peer, stage) candidate, which made a round O(P²·S) at
    1000-peer fleets.  All planner entry points accept either a DHT or
    a ControlSnapshot; the :class:`~repro.core.swarm.SwarmRunner`'s
    rebalance loop captures one per round.
    """
    n_stages: int
    #: per stage: {peer id -> announced queue size}
    queues: tuple[dict, ...]
    #: per stage: sum of announced queue sizes (Alg. 2 lines 7-18)
    loads: tuple[float, ...]

    @classmethod
    def capture(cls, dht, n_stages: int) -> "ControlSnapshot":
        # get_values is the single-pass {subkey: value} read; a span-
        # fused 1000-peer fleet announces ~50k records per round, so the
        # per-record cost here IS the capture cost
        read = getattr(dht, "get_values", None)
        if read is None:                         # DHT-alike without it
            read = lambda key: {pid: r.value
                                for pid, r in dht.get(key).items()}
        queues = tuple(read(dht.load_key(s)) for s in range(n_stages))
        return cls(n_stages, queues,
                   tuple(float(sum(q.values())) for q in queues))

    def queue_of(self, pid: Hashable, stage: int,
                 default: float = 0.0) -> float:
        return float(self.queues[stage].get(pid, default))


def _as_snapshot(dht, n_stages: int) -> ControlSnapshot:
    """Planner entry points take a DHT (historical contract, one capture
    per call) or a pre-captured :class:`ControlSnapshot` (one capture
    per ROUND, shared across decisions)."""
    if isinstance(dht, ControlSnapshot):
        if dht.n_stages != n_stages:
            raise ValueError(f"snapshot captured for {dht.n_stages} "
                             f"stages, planner asked about {n_stages}")
        return dht
    return ControlSnapshot.capture(dht, n_stages)


def stage_loads(dht, n_stages: int) -> list[float]:
    """Sum the per-peer queue sizes announced for every stage (lines 7-18).
    ``dht`` may be a live DHT or a :class:`ControlSnapshot`."""
    return list(_as_snapshot(dht, n_stages).loads)


def plan_migration(dht, n_stages: int,
                   peers_per_stage: dict[int, list[Hashable]]
                   ) -> Optional[Migration]:
    """Algorithm 2, lines 5-31, computed from the DHT snapshot.

    Never empties a stage (SWARM requires >= 1 peer per stage, App. A).
    Returns None when the swarm is already balanced or the min stage has a
    single peer.
    """
    snap = _as_snapshot(dht, n_stages)
    loads = snap.loads
    s_min = min(range(n_stages), key=lambda s: loads[s])
    s_max = max(range(n_stages), key=lambda s: loads[s])
    if s_min == s_max or loads[s_max] <= loads[s_min]:
        return None
    donors = peers_per_stage.get(s_min, [])
    if len(donors) <= 1:
        return None

    q_min, peer_min = math.inf, None
    for peer in donors:
        qv = snap.queue_of(peer, s_min, default=math.inf)
        if qv < q_min:
            q_min, peer_min = qv, peer
    if peer_min is None:
        return None
    return Migration(peer_min, s_min, s_max)


def spans_route(n_stages: int,
                spans: Iterable[tuple[int, int]]) -> bool:
    """Can a trainer tile ``[0, n_stages)`` out of these spans?

    Per-stage *coverage* is necessary but not sufficient: a hop enters a
    span only at its START, so the layout must admit a chain of spans
    ``0 -> ... -> n_stages``.  (``{(0,2), (1,2)}`` covers both stages of
    a 2-stage pipe and routes; ``{(0,2), (1,3)}`` covers all of a
    3-stage pipe but strands boundary 2 — no span starts there.)
    Every span-layout mutation must preserve this, or routing stalls
    forever.  Only the SET of spans matters, so any iterable of ``(lo,
    hi)`` works — including a span-multiset dict's keys, which is how
    :func:`plan_span_change` calls it at 1000-peer scale (O(U + S) on U
    unique spans instead of O(P))."""
    starts: dict[int, set[int]] = {}
    for lo, hi in spans:
        starts.setdefault(lo, set()).add(hi)
    seen: set[int] = set()
    frontier = {0}
    while frontier:
        s = frontier.pop()
        if s == n_stages:
            return True
        if s in seen:
            continue
        seen.add(s)
        frontier |= starts.get(s, set())
    return n_stages == 0


def _edge_cost(boundary_cost, b: int) -> float:
    """Cost of crossing boundary ``b`` (between stages b and b+1).
    ``boundary_cost`` may be a uniform scalar (historical) or a
    per-boundary sequence of length ``n_stages - 1`` — e.g. the stage
    plan's ``boundary_costs``, where a whisper boundary carries encoder
    state + token ids and an expert-sharded MoE boundary pays top_k
    routed token copies."""
    if isinstance(boundary_cost, (list, tuple)):
        return float(boundary_cost[b])
    return float(boundary_cost)


def _span_cost(span: tuple[int, int], costs: list[float],
               boundary_cost, n_stages: int,
               overlap_wire: bool = False) -> float:
    """Per-microbatch service cost of one peer running ``span`` fused:
    the covered stages' compute plus the boundary cost per *host* edge
    (scalar or per-boundary, see :func:`_edge_cost`) — fused intra-span
    boundaries are free, which is exactly the saved wire bytes the span
    backend realizes.  ``overlap_wire`` prices the async tick: boundary
    transfers ride the NIC concurrently with the next microbatch's
    compute, so the steady-state cost is the MAX of compute and wire
    (the busier of the two pipelines), not their sum — never more than
    the serial price, equal when either side is zero."""
    lo, hi = span
    wire = (_edge_cost(boundary_cost, lo - 1) if lo > 0 else 0.0) \
        + (_edge_cost(boundary_cost, hi - 1) if hi < n_stages else 0.0)
    compute = sum(costs[lo:hi])
    if overlap_wire:
        return max(compute, wire)
    return compute + wire


def span_stage_rates(spans: Sequence[tuple[int, int]],
                     speeds: Sequence[float], n_stages: int,
                     stage_costs: Optional[list[float]] = None,
                     boundary_cost: float = 0.0,
                     overlap_wire: bool = False) -> list[float]:
    """Aggregate service rate per stage under a span assignment: a peer
    of speed ``v`` serving span σ contributes ``v / cost(σ)`` to every
    stage of σ (it pushes each microbatch through the whole span).

    The span cost is memoized per unique ``(lo, hi)`` — planner output
    reuses a handful of chunk shapes across hundreds of peers, so the
    accumulation is O(P + U·S̄) rather than O(P·S̄) cost re-derivations
    (and bitwise-identical to the unmemoized sum: same divisor, same
    peer-order accumulation)."""
    costs = stage_costs or [1.0] * n_stages
    rate = [0.0] * n_stages
    ccache: dict[tuple[int, int], float] = {}
    for span, v in zip(spans, speeds):
        if span is None:
            continue
        key = (span[0], span[1])
        c = ccache.get(key)
        if c is None:
            c = ccache[key] = max(
                _span_cost(key, costs, boundary_cost, n_stages,
                           overlap_wire), 1e-12)
        for s in range(key[0], key[1]):
            rate[s] += v / c
    return rate


def _contiguous_partition(n_chunks: int, costs: list[float]
                          ) -> list[tuple[int, int]]:
    """Split stages into ``n_chunks`` contiguous spans with near-equal
    cost (greedy cumulative walk; every chunk non-empty)."""
    S = len(costs)
    n_chunks = max(1, min(n_chunks, S))
    total = sum(costs)
    spans, lo, acc = [], 0, 0.0
    for s in range(S):
        acc += costs[s]
        chunks_left = n_chunks - len(spans)          # incl. the open one
        stages_left = S - (s + 1)
        # close when the cost target is met — or when every remaining
        # chunk needs exactly one of the remaining stages — but never so
        # early that a later chunk would come up empty
        must = stages_left == chunks_left - 1
        want = acc >= total / n_chunks
        if chunks_left > 1 and (want or must) \
                and stages_left >= chunks_left - 1:
            spans.append((lo, s + 1))
            lo, acc = s + 1, 0.0
    spans.append((lo, S))
    return spans


def _greedy_single_assignment(speeds: list[float], n_stages: int,
                              costs: list[float], boundary_cost: float,
                              overlap_wire: bool = False
                              ) -> Optional[list[tuple[int, int]]]:
    """Best-effort width-1 placement (the span-free baseline): fastest
    peers first, each onto the currently weakest stage.  None when
    ``n_peers < n_stages`` — no single-stage placement can cover.

    The weakest stage lives at the top of a heap keyed ``(rate, -cost,
    stage)`` — the same lexicographic order the original O(P·S) argmin
    scan used (uncovered stages always win, costlier stages break rate
    ties, lowest index breaks exact ties), so placements are
    bitwise-identical at O(P log S)."""
    if len(speeds) < n_stages:
        return None
    order = sorted(range(len(speeds)), key=lambda i: -speeds[i])
    spans: list[Optional[tuple[int, int]]] = [None] * len(speeds)
    denom = [max(_span_cost((s, s + 1), costs, boundary_cost, n_stages,
                            overlap_wire), 1e-12) for s in range(n_stages)]
    heap = [(0.0, -costs[s], s) for s in range(n_stages)]
    heapq.heapify(heap)
    for i in order:
        # only the top entry is ever updated, so every entry is current
        rate, negc, s = heap[0]
        spans[i] = (s, s + 1)
        heapq.heapreplace(heap, (rate + speeds[i] / denom[s], negc, s))
    return spans


#: Fleets up to this size run the original exhaustive candidate search
#: (every chunk count priced with a from-scratch ``span_stage_rates``
#: per surplus peer) so the 4-8 peer fixtures' decisions stay
#: bitwise-stable; larger fleets take :func:`_best_span_candidate_fast`,
#: the heap-bounded scale path of ISSUE 10.
_EXACT_PEER_LIMIT = 64


def _best_span_candidate_fast(v: list[float], order: list[int],
                              n_stages: int, costs: list[float],
                              boundary_cost, max_span: Optional[int],
                              overlap_wire: bool, single, thr):
    """Heap-bounded span-candidate search for large fleets.

    Two facts make this cheap.  Every candidate assigns whole *chunks*
    of one contiguous partition, so all stages of a chunk share one
    aggregate rate — the surplus-reinforcement step only needs a heap
    over ``(chunk rate, chunk lo)`` (the exact tie-break the per-stage
    argmin used, since the weakest stage is the lowest-indexed stage of
    the weakest chunk), one ``heapreplace`` per surplus peer instead of
    a from-scratch ``span_stage_rates``.  And a chunk count whose
    fractional upper bound ``Σv / Σ chunk_cost`` cannot strictly beat
    the incumbent throughput is skipped outright — min-rate is never
    above the speed-mass / cost-mass ratio, and a tie would lose to the
    earlier candidate anyway (``max`` keeps the first maximum).

    O(S·(S + P' log S) + P log P) per call for P' surplus peers, vs the
    original O(P²·S²): the 99-second ``optimal_assignment`` at 1000
    peers × 48 stages (see benchmarks/bench_control.py) drops under the
    50 ms round budget."""
    n_peers = len(v)
    total_v = sum(v)
    best = single
    best_thr = thr(single) if single is not None else -math.inf
    for k in range(1, min(n_peers, n_stages) + 1):
        chunks = _contiguous_partition(k, costs)
        if max_span is not None and any(
                hi - lo > max_span for lo, hi in chunks):
            continue
        ccost = [max(_span_cost(c, costs, boundary_cost, n_stages,
                                overlap_wire), 1e-12) for c in chunks]
        if total_v / sum(ccost) <= best_thr:
            continue
        by_cost = sorted(range(k), key=lambda c: -ccost[c])
        assign: list[Optional[tuple[int, int]]] = [None] * n_peers
        heap = []
        for rank, c in enumerate(by_cost):
            i = order[rank]
            assign[i] = chunks[c]
            heap.append((v[i] / ccost[c], chunks[c][0], c))
        heapq.heapify(heap)
        for i in order[k:]:                  # surplus: reinforce weakest
            # only the top entry is ever updated -> all entries current
            rate, lo_c, c = heap[0]
            assign[i] = chunks[c]
            heapq.heapreplace(heap, (rate + v[i] / ccost[c], lo_c, c))
        cand_thr = heap[0][0]                # min chunk rate == min stage
        if cand_thr > best_thr:
            best_thr, best = cand_thr, assign
    if best is None:
        raise ValueError(
            f"max_span={max_span} cannot cover {n_stages} stages with "
            f"{n_peers} peers (need n_peers * max_span >= n_stages)")
    return best


def optimal_assignment(n_peers: int, n_stages: int,
                       stage_costs: Optional[list[float]] = None, *,
                       speeds: Optional[Sequence[float]] = None,
                       spans: bool = False, boundary_cost: float = 0.0,
                       max_span: Optional[int] = None,
                       overlap_wire: bool = False):
    """Throughput-optimal placement (the 'always optimal' baseline of
    Table 5).

    ``spans=False`` (default): peer *counts* per stage, proportional to
    per-stage compute cost, each stage >= 1 — the historical contract.
    Raises ``ValueError`` when ``n_peers < n_stages``: one peer per
    stage is the floor of this form, so a smaller fleet cannot cover
    the pipeline (historically this silently returned an alloc summing
    to ``n_stages`` — more peers than exist).

    ``spans=True``: one contiguous ``(lo, hi)`` span per peer.  Strong
    peers may hold several stages fused (square-cube, §3.1), pricing
    each host boundary at ``boundary_cost``; the width-1 greedy
    placement is always among the candidates, so the result's
    :func:`pipeline_throughput` is never below the span-free
    assignment's.  Guarantees full stage coverage for any ``n_peers >=
    1`` (a single peer serves the whole pipeline as one span).
    ``max_span=1`` forces the width-1 baseline itself.  Fleets beyond
    :data:`_EXACT_PEER_LIMIT` peers take the heap-bounded
    :func:`_best_span_candidate_fast` path."""
    costs = list(stage_costs or [1.0] * n_stages)
    if not spans:
        if n_peers < n_stages:
            raise ValueError(
                f"{n_peers} peers cannot cover {n_stages} stages one "
                f"stage per peer (the counts form needs n_peers >= "
                f"n_stages) — use spans=True, which fuses contiguous "
                f"stages so any n_peers >= 1 covers the pipeline")
        total = sum(costs)
        alloc = [max(1, round(n_peers * c / total)) for c in costs]
        # fix rounding to sum exactly n_peers, never dropping below 1
        while sum(alloc) > n_peers:
            i = max(range(n_stages), key=lambda j: alloc[j])
            if alloc[i] > 1:
                alloc[i] -= 1
            else:
                break
        while sum(alloc) < n_peers:
            i = min(range(n_stages),
                    key=lambda j: alloc[j] / max(costs[j], 1e-9))
            alloc[i] += 1
        return alloc

    v = list(speeds) if speeds is not None else [1.0] * n_peers
    assert len(v) == n_peers

    def thr(assign):
        return pipeline_throughput(assign, v, stage_costs=costs,
                                   boundary_cost=boundary_cost,
                                   overlap_wire=overlap_wire)

    single = _greedy_single_assignment(v, n_stages, costs, boundary_cost,
                                       overlap_wire)
    if max_span == 1:
        if single is None:
            raise ValueError(f"max_span=1 cannot cover {n_stages} stages "
                             f"with {n_peers} peers")
        return single

    order = sorted(range(n_peers), key=lambda i: -v[i])
    if n_peers > _EXACT_PEER_LIMIT:
        return _best_span_candidate_fast(v, order, n_stages, costs,
                                         boundary_cost, max_span,
                                         overlap_wire, single, thr)

    candidates = [] if single is None else [single]
    # contiguous partitions into k chunks, fastest peers on the
    # costliest chunks, surplus peers reinforcing the weakest chunk
    for k in range(1, min(n_peers, n_stages) + 1):
        chunks = _contiguous_partition(k, costs)
        if max_span is not None and any(
                hi - lo > max_span for lo, hi in chunks):
            continue
        by_cost = sorted(range(k), key=lambda c: -_span_cost(
            chunks[c], costs, boundary_cost, n_stages, overlap_wire))
        assign: list[Optional[tuple[int, int]]] = [None] * n_peers
        for rank, c in enumerate(by_cost):
            assign[order[rank]] = chunks[c]
        for i in order[k:]:                  # surplus: reinforce weakest
            rate = span_stage_rates(
                [a for a in assign if a is not None],
                [v[j] for j, a in enumerate(assign) if a is not None],
                n_stages, costs, boundary_cost, overlap_wire)
            weakest = min(range(n_stages), key=lambda s: rate[s])
            assign[i] = next(c for c in chunks
                             if c[0] <= weakest < c[1])
        candidates.append(assign)
    if not candidates:
        raise ValueError(
            f"max_span={max_span} cannot cover {n_stages} stages with "
            f"{n_peers} peers (need n_peers * max_span >= n_stages)")
    return max(candidates, key=thr)


def serve_assignment(n_prefill: int, n_decode: int, n_stages: int,
                     stage_costs: Optional[list[float]] = None, *,
                     prefill_speeds: Optional[Sequence[float]] = None,
                     decode_speeds: Optional[Sequence[float]] = None,
                     boundary_cost: float = 0.0
                     ) -> dict[str, list[tuple[int, int]]]:
    """Disaggregated serving layout: one span pool per phase.

    Prefill is throughput-bound like the training forward — a host
    boundary costs one activation transfer amortized over the whole
    prompt, so narrow spans placed compute-optimal are fine.  Decode
    moves a single token per hop, so per-hop latency dominates: the
    decode pool prices each host edge at the whole pipe's compute,
    pushing the partition toward maximally fused (wide) spans.

    The prefill layout *refines* the decode layout: every decode-span
    start is also a prefill hop boundary.  The serve runner records the
    wire tensor entering each hop, and recovery re-prefills a dead decode
    peer's span from that recorded history — which only exists at
    boundaries where the prefill chain actually hopped.

    Returns ``{"prefill": [(lo, hi), ...], "decode": [(lo, hi), ...]}``
    (one span per pool peer; both layouts tile, hence route).  With
    ``n_prefill == 0`` the prefill pool is empty and prefill runs on the
    decode chain itself (no disaggregation)."""
    costs = list(stage_costs or [1.0] * n_stages)
    dv = list(decode_speeds) if decode_speeds is not None \
        else [1.0] * n_decode
    pv = list(prefill_speeds) if prefill_speeds is not None \
        else [1.0] * n_prefill
    assert len(dv) == n_decode and len(pv) == n_prefill

    floor = sum(costs)                 # per-hop latency dominates decode
    decode_bc = ([max(float(b), floor) for b in boundary_cost]
                 if isinstance(boundary_cost, (list, tuple))
                 else max(float(boundary_cost), floor))
    decode = [tuple(sp) for sp in optimal_assignment(
        n_decode, n_stages, costs, speeds=dv, spans=True,
        boundary_cost=decode_bc)]
    if n_prefill == 0:
        return {"prefill": [], "decode": decode}

    # decode-aligned chunks: every decode-span edge is a cut point
    cuts = sorted({0, n_stages} | {lo for lo, _ in decode}
                  | {hi for _, hi in decode})
    chunks = list(zip(cuts[:-1], cuts[1:]))
    if n_prefill < len(chunks):
        raise ValueError(
            f"prefill pool of {n_prefill} cannot tile the {len(chunks)} "
            f"decode-aligned chunks — grow the pool or pass n_prefill=0 "
            f"to prefill on the decode chain")

    # spread the pool over the chunks by compute cost (counts form),
    # then refine each chunk into near-equal sub-spans; surplus peers
    # reinforce their chunk's sub-spans round-robin
    alloc = optimal_assignment(n_prefill, len(chunks),
                               [sum(costs[lo:hi]) for lo, hi in chunks])
    slots: list[tuple[int, int]] = []
    for (lo, hi), k in zip(chunks, alloc):
        subs = _contiguous_partition(min(k, hi - lo), costs[lo:hi])
        subs = [(lo + a, lo + b) for a, b in subs]
        slots.extend(subs[j % len(subs)] for j in range(k))
    # fastest prefill peers onto the costliest sub-spans
    slots.sort(key=lambda sp: -sum(costs[sp[0]:sp[1]]))
    prefill: list[Optional[tuple[int, int]]] = [None] * n_prefill
    for rank, i in enumerate(
            sorted(range(n_prefill), key=lambda i: -pv[i])):
        prefill[i] = slots[rank]
    assert spans_route(n_stages, prefill) and spans_route(n_stages, decode)
    return {"prefill": prefill, "decode": decode}


def pipeline_throughput(alloc, peer_speed=1.0,
                        stage_costs: Optional[list[float]] = None,
                        boundary_cost: float = 0.0,
                        overlap_wire: bool = False) -> float:
    """Steady-state pipeline throughput = min over stages of aggregate
    stage speed (the weakest-link law, §3.2).

    Two forms: per-stage peer *counts* (``[2, 1, 2]``, historical), or a
    per-peer *span assignment* (``[(0, 2), (2, 3), ...]``) with
    ``peer_speed`` a scalar or per-peer sequence — where each host
    boundary a peer's span touches costs ``boundary_cost`` on top of the
    covered stages' compute, so fused boundaries visibly buy
    throughput.  ``overlap_wire=True`` prices the async tick instead:
    wire rides concurrently with compute, so each peer's cost is
    ``max(compute, wire)`` — overlapped throughput is never below the
    serial figure, and equals it at ``boundary_cost=0``."""
    if alloc and not isinstance(alloc[0], (int, float)):
        spans = [tuple(a) for a in alloc]
        n_stages = len(stage_costs) if stage_costs else \
            max(hi for _, hi in spans)
        speeds = (list(peer_speed) if isinstance(peer_speed, (list, tuple))
                  else [float(peer_speed)] * len(spans))
        rate = span_stage_rates(spans, speeds, n_stages, stage_costs,
                                boundary_cost, overlap_wire)
        return min(rate) if rate else 0.0
    costs = stage_costs or [1.0] * len(alloc)
    if any(a <= 0 for a in alloc):
        return 0.0
    n_stages = len(alloc)
    return min(
        a * peer_speed / max(_span_cost((s, s + 1), costs, boundary_cost,
                                        n_stages, overlap_wire), 1e-12)
        for s, (a, c) in enumerate(zip(alloc, costs)))


def plan_span_change(dht, n_stages: int,
                     spans: dict[Hashable, tuple[int, int]],
                     imbalance: float = 1.25,
                     boundary_costs: Optional[Sequence[float]] = None
                     ) -> Optional[SpanChange]:
    """Span-aware Alg.-2 step, from the DHT load snapshot.

    * SPLIT/shrink: the max-load stage is genuinely hotter than the
      min-load stage (beyond the ``imbalance`` ratio — raw queue sums
      jitter, so exact comparison would misread noise as imbalance) and
      sits inside a multi-stage span — concentrate the most backlogged
      such peer on the bottleneck stage alone, provided every stage it
      drops keeps another cover (the runner hands the dropped stages'
      state to those peers).
    * MERGE/grow: loads are within the tolerance band — let the
      least-loaded peer absorb an adjacent stage that is covered by >= 2
      peers, deleting one host boundary crossing for its traffic at no
      coverage risk.  (A hot pipe with nothing to split proposes
      nothing: growing it would only slow the bottleneck.)

    ``boundary_costs`` (per-boundary wire prices, e.g. the stage plan's
    ``boundary_costs``) ranks merge candidates by the NET wire saving of
    the fused boundary — absorbing the stage behind an expensive edge
    (a routed-MoE or whisper boundary) wins over a cheap one; without it
    the historical least-loaded-first order applies.

    Never proposes a change that would strand a stage — or break span
    *routability* (:func:`spans_route`): coverage alone is too weak,
    a layout like ``{(0,2), (1,2), (1,3)}`` covers every stage of a
    3-stage pipe yet no span starts at boundary 2, so every microbatch
    would stall.

    ``dht`` may be a live DHT or a per-round :class:`ControlSnapshot`;
    the candidate scan itself is O(P·S̄ + C·(U + S)) for C candidate
    moves over U unique spans — per-candidate work is an O(1) coverage
    lookup (difference-array) and a span-multiset routability probe,
    never a per-candidate DHT read or full-layout rebuild."""
    snap = _as_snapshot(dht, n_stages)
    loads = snap.loads
    s_max = max(range(n_stages), key=lambda s: loads[s])
    s_min = min(range(n_stages), key=lambda s: loads[s])

    cover = [0] * (n_stages + 1)
    span_count: dict[tuple[int, int], int] = {}
    for lo, hi in spans.values():
        cover[lo] += 1
        cover[hi] -= 1
        span_count[(lo, hi)] = span_count.get((lo, hi), 0) + 1
    for s in range(n_stages):
        cover[s + 1] += cover[s]
    base_routes = spans_route(n_stages, span_count)

    def covers(stage: int, but: Hashable) -> int:
        lo, hi = spans[but]
        return cover[stage] - (1 if lo <= stage < hi else 0)

    def routes_after(pid: Hashable, new: tuple[int, int]) -> bool:
        old = spans[pid]
        if base_routes and span_count.get(old, 0) >= 2:
            # another peer keeps old's routing edge, and adding an edge
            # never breaks reachability -> superset of a routing layout
            return True
        span_count[old] -= 1
        if not span_count[old]:
            del span_count[old]
        span_count[new] = span_count.get(new, 0) + 1
        ok = spans_route(n_stages, span_count)
        span_count[new] -= 1
        if not span_count[new]:
            del span_count[new]
        span_count[old] = span_count.get(old, 0) + 1
        return ok

    def queue_of(pid: Hashable, stage: int) -> float:
        return snap.queue_of(pid, stage)

    hot = loads[s_max] > imbalance * loads[s_min] + 0.05
    if hot:
        donors = sorted(
            (pid for pid, (lo, hi) in spans.items()
             if hi - lo > 1 and lo <= s_max < hi),
            key=lambda pid: (-queue_of(pid, s_max), str(pid)))
        for pid in donors:
            lo, hi = spans[pid]
            new = (s_max, s_max + 1)
            if all(covers(s, but=pid) >= 1
                   for s in range(lo, hi) if s != s_max) \
                    and routes_after(pid, new):
                return SpanChange(pid, (lo, hi), new)
        return None

    # balanced: grow toward fewer host boundaries
    def edge(b: int) -> float:
        if boundary_costs is None or not 0 <= b < n_stages - 1:
            return 0.0
        return float(boundary_costs[b])

    growers = sorted(spans, key=lambda pid: (queue_of(pid, spans[pid][0]),
                                             str(pid)))
    cands = []
    for pid in growers:
        lo, hi = spans[pid]
        for t, new in ((hi, (lo, hi + 1)), (lo - 1, (lo - 1, hi))):
            if 0 <= t < n_stages and covers(t, but=pid) >= 2 \
                    and routes_after(pid, new):
                # growing up fuses boundary hi-1 but exposes boundary
                # hi; growing down fuses lo-1 but exposes lo-2
                saved = (edge(hi - 1) - edge(hi) if t == hi
                         else edge(lo - 1) - edge(lo - 2))
                cands.append((saved, pid, (lo, hi), new))
    if not cands:
        return None
    if boundary_costs is not None:
        cands.sort(key=lambda c: -c[0])        # stable: ties keep the
        # least-loaded-first order from the grower scan above
    _, pid, old, new = cands[0]
    return SpanChange(pid, old, new)
