"""Trainer processes (paper §3.2 / App. C).

Trainers own no parameters and no GPU: they form microbatches and route
them through one peer per stage (forward), then back (backward), using
stochastic wiring.  On a peer failure anywhere along the path the trainer
bans the peer and re-routes — backward can go to a *different* peer than
forward because stages recompute activations from the boundary input
(activation checkpointing, App. A).

The trainer is backend- and codec-agnostic: stage execution and wire
handling (including the int8 round-trip that used to live here) go
through the peer's :class:`repro.runtime.StageExecutor`, so a path may
mix single-device and mesh-backed peers freely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim import Sim, Sleep
from repro.core.peer import Peer, PeerFailure
from repro.core.wiring import StochasticWiring

Tree = Any


@dataclasses.dataclass
class Microbatch:
    index: int
    tokens: Any = None          # numeric mode: jnp [b, S]
    labels: Any = None
    size: int = 1               # sequences
    n_tokens: int = 0
    attempt: int = 1            # provenance: ledger dispatch attempt


class Trainer:
    def __init__(self, sim: Sim, swarm, wiring: StochasticWiring,
                 name: str, *, max_retries: int = 50,
                 refresh_interval: float = 30.0):
        self.sim = sim
        self.swarm = swarm
        self.wiring = wiring
        self.name = name
        self.max_retries = max_retries
        self.refresh_interval = refresh_interval
        self._last_refresh = -1e9

    # ------------------------------------------------------------ helpers
    def _maybe_refresh(self):
        if self.sim.now - self._last_refresh >= self.refresh_interval:
            self.wiring.refresh_from_dht(
                self.swarm.dht, self.swarm.announced_stages())
            self._last_refresh = self.sim.now

    def _pick(self, stage: int):
        """Choose a live peer for a stage, waiting if none available."""
        self._maybe_refresh()
        peer_id = self.wiring.choose_server(stage)
        if peer_id is None:
            return None
        peer = self.swarm.peers.get(peer_id)
        if peer is None or not peer.alive or not peer.serving \
                or peer.stage != stage:
            self.wiring.ban_server(peer_id)
            return None
        return peer

    def _boundary_bytes(self, mb: Microbatch) -> float:
        return self.swarm.boundary_nbytes(mb)

    # ------------------------------------------------------------ core
    def run_microbatch(self, mb: Microbatch):
        """Generator process: one microbatch through fwd+bwd. Yields sim
        commands; returns (loss_sum, ok)."""
        swarm = self.swarm
        S = swarm.n_stages
        numeric = swarm.numeric
        acts: list[Any] = [None] * S        # boundary input of each stage
        path: list[Optional[Peer]] = [None] * S

        # ---------------- forward
        x = mb.tokens if numeric else None
        s = 0
        retries = 0
        while s < S:
            peer = self._pick(s)
            if peer is None:
                retries += 1
                if retries > self.max_retries:
                    return None, False
                yield Sleep(1.0)
                continue
            nbytes = self._boundary_bytes(mb) if s > 0 else \
                mb.n_tokens * 4.0
            t0 = self.sim.now
            try:
                yield Sleep(peer.profile.recv_time(nbytes))
                inp = x

                if numeric:
                    # the executor runs the stage AND produces the wire
                    # tensor that crosses to the next peer (codec round
                    # trips, mesh host-gathers — all backend-owned)
                    if s == S - 1:
                        thunk = (lambda _p=peer, _i=inp:
                                 _p.executor.run_fwd(_p.state, _i,
                                                     mb.labels))
                    else:
                        thunk = (lambda _p=peer, _i=inp:
                                 _p.executor.wire_fwd(
                                     _p.executor.run_fwd(_p.state, _i)))
                else:
                    thunk = lambda: None
                ct = swarm.compute_time(peer, "fwd", s, mb)
                y = yield peer.submit("fwd", ct, thunk).wait()
                # response travels back / onward
                yield Sleep(peer.profile.send_time(
                    self._boundary_bytes(mb) if s < S - 1 else 64.0))
                self.wiring.observe(peer.id, self.sim.now - t0)
                acts[s] = inp
                path[s] = peer
                x = y
                s += 1
                retries = 0
            except PeerFailure:
                self.wiring.ban_server(peer.id)
                retries += 1
                if retries > self.max_retries:
                    return None, False

        # ---------------- backward (reverse, re-routable per stage)
        loss_sum = float(x) if numeric else 0.0
        dy = None
        s = S - 1
        retries = 0
        while s >= 0:
            peer = path[s]
            if peer is None or not peer.alive or not peer.serving \
                    or peer.stage != s:
                peer = self._pick(s)
            if peer is None:
                retries += 1
                if retries > self.max_retries:
                    return None, False
                yield Sleep(1.0)
                continue
            nbytes = self._boundary_bytes(mb)
            t0 = self.sim.now
            try:
                yield Sleep(peer.profile.recv_time(nbytes))
                if numeric:
                    if s == S - 1:
                        def thunk(_p=peer, _i=acts[s], _s=s):
                            loss, gx, gp = _p.executor.run_bwd(
                                _p.state, _i, labels=mb.labels)
                            # the ledger admits (stage, index) at most
                            # once per round — a re-issued attempt only
                            # recomputes gx for the stages that lost it
                            self.swarm.accumulate(_p, gp, mb, float(loss),
                                                  stage=_s)
                            # the cotangent crosses back as a wire tensor
                            # (int8 round-trip etc. — executor-owned)
                            return _p.executor.wire_bwd(gx)
                    else:
                        def thunk(_p=peer, _i=acts[s], _dy=dy, _s=s):
                            _, gx, gp = _p.executor.run_bwd(_p.state, _i,
                                                            dy=_dy)
                            self.swarm.accumulate(_p, gp, mb, None,
                                                  stage=_s)
                            return _p.executor.wire_bwd(gx)
                else:
                    def thunk(_p=peer, _s=s):
                        self.swarm.accumulate(_p, None, mb, None, stage=_s)
                        return None
                ct = swarm.compute_time(peer, "bwd", s, mb)
                gx = yield peer.submit("bwd", ct, thunk).wait()
                yield Sleep(peer.profile.send_time(nbytes if s > 0 else 64.0))
                self.wiring.observe(peer.id, self.sim.now - t0)
                dy = gx
                s -= 1
                retries = 0
            except PeerFailure:
                self.wiring.ban_server(peer.id)
                retries += 1
                if retries > self.max_retries:
                    return None, False

        return loss_sum, True

    def run(self):
        """Main trainer loop: pull microbatch indices until stopped."""
        swarm = self.swarm
        while not swarm.stopped:
            mb = swarm.next_microbatch()
            if mb is None:
                yield Sleep(0.5)
                continue
            result = yield from self.run_microbatch(mb)
            loss_sum, ok = result if result is not None else (None, False)
            swarm.microbatch_done(mb, ok)
