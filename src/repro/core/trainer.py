"""Trainer processes (paper §3.2 / App. C).

Trainers own no parameters and no GPU: they form microbatches and route
them through the pipeline as a chain of *hops* — one peer per contiguous
stage span — forward, then back, using stochastic wiring.  A hop may be a
single-stage peer or a span peer (``PipelineExecutor``) serving several
consecutive stages in one jitted step; either way the trainer only ever
enters a peer at its span START, and the activation bytes it moves are
charged per *hop edge* — fused intra-span boundaries cross nothing.  On a
peer failure anywhere along the path the trainer bans the peer and
re-routes — backward can go to a *different* peer than forward because
stages recompute activations from the boundary input (activation
checkpointing, App. A); a re-routed backward hop must cover the SAME span
(the cotangent in hand is pinned to that span's edges).

The trainer is backend- and codec-agnostic: stage execution and wire
handling (including the int8 round-trip that used to live here) go
through the peer's :class:`repro.runtime.StageExecutor`, so a path may
mix single-device, mesh-backed, and span peers freely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim import Sim, Sleep
from repro.core.peer import Peer, PeerFailure
from repro.core.wiring import StochasticWiring

Tree = Any


@dataclasses.dataclass
class Microbatch:
    index: int
    tokens: Any = None          # numeric mode: jnp [b, S]
    labels: Any = None
    size: int = 1               # sequences
    n_tokens: int = 0
    attempt: int = 1            # provenance: ledger dispatch attempt


@dataclasses.dataclass
class _Hop:
    """One completed forward hop: which peer ran which span on what."""
    peer: Peer
    span: range
    inp: Any                    # the hop's boundary input (for recompute)


class Trainer:
    def __init__(self, sim: Sim, swarm, wiring: StochasticWiring,
                 name: str, *, max_retries: int = 50,
                 refresh_interval: float = 30.0):
        self.sim = sim
        self.swarm = swarm
        self.wiring = wiring
        self.name = name
        self.max_retries = max_retries
        self.refresh_interval = refresh_interval
        self._last_refresh = -1e9

    # ------------------------------------------------------------ helpers
    def _maybe_refresh(self):
        if self.sim.now - self._last_refresh >= self.refresh_interval:
            self.wiring.refresh_from_dht(
                self.swarm.dht, self.swarm.announced_stages())
            self._last_refresh = self.sim.now

    def _pick(self, stage: int, span: Optional[range] = None):
        """Choose a live peer whose span STARTS at ``stage`` (optionally
        covering exactly ``span`` — the backward re-route constraint),
        or None when unavailable."""
        self._maybe_refresh()
        peer_id = self.wiring.choose_server(stage)
        if peer_id is None:
            return None
        peer = self.swarm.peers.get(peer_id)
        if peer is None or not peer.alive or not peer.serving \
                or peer.stage != stage:
            self.wiring.ban_server(peer_id)
            return None
        if span is not None and peer.stages != span:
            # a healthy peer with a different span: not bannable, just
            # unusable for this cotangent — the caller retries/fails
            return None
        return peer

    def _boundary_bytes(self, mb: Microbatch,
                        boundary: Optional[int] = None) -> float:
        """Wire bytes for one edge.  ``boundary`` indexes the pipeline
        boundary actually crossed (between stages b and b+1) so the
        swarm's stage plan can price it per kind — a whisper boundary
        carries encoder state + token ids besides the hidden states; an
        expert-sharded MoE boundary pays per routed token copy.  None
        (or an out-of-range index, e.g. the last hop's loss-side edge)
        falls back to the uniform hidden-state pricing."""
        return self.swarm.boundary_nbytes(mb, boundary)

    # ------------------------------------------------------------ core
    def run_microbatch(self, mb: Microbatch):
        """Generator process: one microbatch through fwd+bwd. Yields sim
        commands; returns (loss_sum, ok)."""
        swarm = self.swarm
        S = swarm.n_stages
        numeric = swarm.numeric
        # async tick: boundary tensors ride the peers' NIC links
        # (in-flight, priced end-to-end at the pair's bottleneck) instead
        # of two blocking Sleeps, and stage math goes through the
        # executors' dispatch/collect pair.  The sync path is untouched.
        overlap = bool(getattr(swarm, "overlap", False))
        hops: list[_Hop] = []

        # ---------------- forward (hop chain over spans)
        x = mb.tokens if numeric else None
        s = 0
        retries = 0
        while s < S:
            peer = self._pick(s)
            if peer is None:
                # dead end: NO live peer's span even starts at this
                # boundary (earlier hop choices walked into a gap of the
                # span layout, or a resize moved the entry away) — fail
                # the attempt NOW so the re-issue re-rolls the path,
                # instead of sleeping out max_retries seconds.  Only
                # past the first hop: its yields advanced the clock, so
                # the retry loop around re-issues cannot spin timeless
                # (at s == 0 the plain Sleep-retry path below waits for
                # a joiner the usual way).
                if s > 0 and not any(p.alive and p.stages.start == s
                                     for p in swarm.peers.values()):
                    return None, False
                retries += 1
                if retries > self.max_retries:
                    return None, False
                yield Sleep(1.0)
                continue
            span = peer.stages
            covers_last = span.stop == S
            nbytes = self._boundary_bytes(mb, s - 1) if s > 0 else \
                mb.n_tokens * 4.0
            t0 = self.sim.now
            try:
                if overlap:
                    # one in-flight transfer prices the whole edge at the
                    # pair's bottleneck (vs the serial send + recv pair);
                    # the sender's uplink is occupied, never its queue
                    prev = hops[-1].peer if hops else None
                    serial = peer.profile.recv_time(nbytes) + (
                        prev.profile.send_time(nbytes)
                        if prev is not None else 0.0)
                    tw = self.sim.now
                    yield peer.recv(nbytes, frm=prev).wait()
                    swarm.count_inflight_wire(
                        serial, self.sim.now - tw, nbytes)
                else:
                    yield Sleep(peer.profile.recv_time(nbytes))
                if s > 0:        # a real host boundary crossing
                    swarm.count_wire_bytes(nbytes)
                inp = x

                if numeric:
                    # the executor runs the whole span AND produces the
                    # wire tensor that crosses to the next hop (codec
                    # round trips, mesh host-gathers — all backend-owned;
                    # fused boundaries never surface here)
                    if overlap:
                        # dispatch/collect: the jit is issued the moment
                        # the thunk runs; collect() blocks on the futures
                        if covers_last:
                            thunk = (lambda _p=peer, _i=inp:
                                     _p.executor.dispatch_fwd(
                                         _p.state, _i, mb.labels)())
                        else:
                            thunk = (lambda _p=peer, _i=inp:
                                     _p.executor.wire_fwd(
                                         _p.executor.dispatch_fwd(
                                             _p.state, _i)()))
                    elif covers_last:
                        thunk = (lambda _p=peer, _i=inp:
                                 _p.executor.run_fwd(_p.state, _i,
                                                     mb.labels))
                    else:
                        thunk = (lambda _p=peer, _i=inp:
                                 _p.executor.wire_fwd(
                                     _p.executor.run_fwd(_p.state, _i)))
                else:
                    thunk = lambda: None
                ct = swarm.compute_time(peer, "fwd", s, mb)
                y = yield peer.submit("fwd", ct, thunk).wait()
                # response travels back / onward
                if overlap:
                    if covers_last:     # the scalar loss back to us
                        yield peer.send(64.0).wait()
                    # else: the next hop's recv prices this edge once,
                    # end-to-end — nothing to wait on here
                else:
                    yield Sleep(peer.profile.send_time(
                        self._boundary_bytes(mb, span.stop - 1)
                        if not covers_last else 64.0))
                self.wiring.observe(peer.id, self.sim.now - t0)
                hops.append(_Hop(peer, span, inp))
                x = y
                s = span.stop
                retries = 0
            except PeerFailure:
                self.wiring.ban_server(peer.id)
                retries += 1
                if retries > self.max_retries:
                    return None, False

        # ---------------- backward (reverse hop chain, re-routable)
        loss_sum = float(x) if numeric else 0.0
        dy = None
        bwd_prev: Optional[Peer] = None   # who produced the dy in hand
        h = len(hops) - 1
        retries = 0
        while h >= 0:
            hop = hops[h]
            peer = hop.peer
            if peer is None or not peer.alive or not peer.serving \
                    or peer.stages != hop.span:
                peer = self._pick(hop.span.start, span=hop.span)
            if peer is None:
                # the cotangent in hand is pinned to this hop's span
                # edges: if NO live peer still has that exact span (a
                # resize re-partitioned the pipeline; a mid-download
                # peer that will serve it again counts), fail the
                # attempt NOW — the ledger re-issues and the fresh
                # forward follows the new span layout, instead of
                # sleeping out max_retries against an impossible route
                if not any(p.alive and p.stages == hop.span
                           for p in swarm.peers.values()):
                    return None, False
                retries += 1
                if retries > self.max_retries:
                    return None, False
                yield Sleep(1.0)
                continue
            covers_last = hop.span.stop == S
            # the cotangent in hand crossed the boundary at the hop's
            # top edge (out-of-range for the last hop: uniform fallback)
            nbytes = self._boundary_bytes(mb, hop.span.stop - 1)
            t0 = self.sim.now
            try:
                if overlap:
                    serial = peer.profile.recv_time(nbytes) + (
                        bwd_prev.profile.send_time(nbytes)
                        if bwd_prev is not None else 0.0)
                    tw = self.sim.now
                    yield peer.recv(nbytes, frm=bwd_prev).wait()
                    swarm.count_inflight_wire(
                        serial, self.sim.now - tw, nbytes)
                else:
                    yield Sleep(peer.profile.recv_time(nbytes))
                if not covers_last:      # a cotangent really crossed
                    swarm.count_wire_bytes(nbytes)
                if numeric:
                    if overlap:
                        if covers_last:
                            def thunk(_p=peer, _i=hop.inp):
                                collect = _p.executor.dispatch_bwd(
                                    _p.state, _i, labels=mb.labels)
                                loss, gx, gp = collect()
                                self.swarm.accumulate(_p, gp, mb,
                                                      float(loss))
                                return _p.executor.wire_bwd(gx)
                        else:
                            def thunk(_p=peer, _i=hop.inp, _dy=dy):
                                collect = _p.executor.dispatch_bwd(
                                    _p.state, _i, dy=_dy)
                                _, gx, gp = collect()
                                self.swarm.accumulate(_p, gp, mb, None)
                                return _p.executor.wire_bwd(gx)
                    elif covers_last:
                        def thunk(_p=peer, _i=hop.inp):
                            loss, gx, gp = _p.executor.run_bwd(
                                _p.state, _i, labels=mb.labels)
                            # the ledger admits each covered (stage,
                            # index) at most once per round — a re-issued
                            # attempt only folds the stages that lost it
                            self.swarm.accumulate(_p, gp, mb, float(loss))
                            # the cotangent crosses back as a wire tensor
                            # (int8 round-trip etc. — executor-owned)
                            return _p.executor.wire_bwd(gx)
                    else:
                        def thunk(_p=peer, _i=hop.inp, _dy=dy):
                            _, gx, gp = _p.executor.run_bwd(_p.state, _i,
                                                            dy=_dy)
                            self.swarm.accumulate(_p, gp, mb, None)
                            return _p.executor.wire_bwd(gx)
                else:
                    def thunk(_p=peer):
                        self.swarm.accumulate(_p, None, mb, None)
                        return None
                ct = swarm.compute_time(peer, "bwd", hop.span.start, mb)
                gx = yield peer.submit("bwd", ct, thunk).wait()
                if overlap:
                    if hop.span.start == 0:   # grads landed: tiny ack
                        yield peer.send(64.0).wait()
                    # else: the next hop's recv prices this edge
                else:
                    yield Sleep(peer.profile.send_time(
                        self._boundary_bytes(mb, hop.span.start - 1)
                        if hop.span.start > 0 else 64.0))
                self.wiring.observe(peer.id, self.sim.now - t0)
                dy = gx
                bwd_prev = peer
                h -= 1
                retries = 0
            except PeerFailure:
                self.wiring.ban_server(peer.id)
                retries += 1
                if retries > self.max_retries:
                    return None, False

        return loss_sum, True

    def run(self):
        """Main trainer loop: pull microbatch indices until stopped."""
        swarm = self.swarm
        while not swarm.stopped:
            mb = swarm.next_microbatch()
            if mb is None:
                yield Sleep(0.5)
                continue
            result = yield from self.run_microbatch(mb)
            loss_sum, ok = result if result is not None else (None, False)
            swarm.microbatch_done(mb, ok)
