"""SwarmRunner — the full SWARM parallelism system on the virtual clock.

Composition (paper Fig. 2): consecutive swarms of peers serve pipeline
stages; trainer processes route microbatches via stochastic wiring; a DHT
carries liveness + load; adaptive rebalancing migrates peers between
stages; once the microbatch ledger (repro.core.ledger) shows the global
batch accumulated exactly once at every stage, each stage All-Reduces its
gradients and applies the (optionally delayed, DPU) optimizer step.
Gradients lost to dead or migrating peers are recomputed by survivors
under the same microbatch indices, so an optimizer step under churn
averages the identical sample set as fault-free training (App. A).

Two modes:
  numeric=True   — real JAX math per stage (convergence experiments,
                   equivalence tests; Fig. 4 / App. E analogues).
  numeric=False  — timing only (Tables 2-5, Figs. 5-7 analogues: 400-peer,
                   32-hour traces run in seconds of wall time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import codecs
from repro.core.sim import Sim, Sleep, Spawn
from repro.core.dht import DHT
from repro.core.ledger import MicrobatchLedger
from repro.core.peer import Peer, DeviceProfile, PeerFailure, T4
from repro.core.wiring import StochasticWiring
from repro.core.trainer import Trainer, Microbatch
from repro.core import rebalance as rb
from repro.core.faults import TraceEvent
from repro.models.config import ArchConfig
from repro.models import flops as F
from repro.optim.adamw import Optimizer
from repro.runtime import StageExecutor, StageProgram, \
    build_numeric_executors, init_stage_params

Tree = Any


@dataclasses.dataclass
class SwarmConfig:
    n_stages: int = 3
    microbatch_size: int = 1
    seq_len: int = 128
    global_batch: int = 8                # sequences per optimizer step
    n_trainers: int = 4
    rebalance_period: float = 300.0      # T (paper §4.3)
    announce_interval: float = 120.0
    announce_ttl: float = 300.0
    wiring_gamma: float = 0.1            # EMA alpha (paper §4.3)
    # boundary compression: False -> "none", True -> "int8" (back-compat
    # booleans), or an explicit mode string incl. the learned codecs
    # ("none" | "int8" | "bottleneck" | "maxout", paper App. J)
    compress: bool | str = True
    quant_block: int = 64
    dpu: bool = False
    max_steps: Optional[int] = None
    allreduce_bw: float = 50e6           # bytes/s effective per peer
    trainer_max_retries: int = 50        # per-attempt routing retries
    # elastic checkpointing (ROADMAP): persist a pipeline-consistent cut
    # of every stage's state each ``ckpt_period`` completed steps via
    # the executors' snapshot() — a stage that loses ALL its peers
    # resumes from the latest completed step instead of the step-0
    # reference params, and a runner constructed over a non-empty
    # ``ckpt_dir`` RESUMES that run (step counter + data cursor adopt
    # the latest cut)
    ckpt_dir: Optional[str] = None
    ckpt_period: int = 1


class SwarmRunner:
    def __init__(self, cfg: ArchConfig, scfg: SwarmConfig,
                 optimizer: Optimizer, *, numeric: bool = True,
                 seed: int = 0,
                 profile_fn: Optional[Callable[[int], DeviceProfile]] = None,
                 data_fn: Optional[Callable[[int], dict]] = None,
                 programs: Optional[list[StageProgram]] = None,
                 record_accumulation: bool = False):
        self.cfg = cfg
        self.scfg = scfg
        self.optimizer = optimizer
        self.numeric = numeric
        self.sim = Sim()
        self.dht = DHT(lambda: self.sim.now)
        self.n_stages = scfg.n_stages
        self.compress = scfg.compress
        if isinstance(scfg.compress, bool):
            self.compress_mode = "int8" if scfg.compress else "none"
        else:
            self.compress_mode = codecs.resolve_mode(cfg, scfg.compress)
        self.quant_block = scfg.quant_block
        self.rng = np.random.default_rng(seed)
        self.profile_fn = profile_fn or (lambda i: T4)
        self.data_fn = data_fn

        # stage execution goes through the runtime layer: one executor
        # per stage, shared by all that stage's peers (the process-wide
        # compile cache means the seed matrix of the churn tests and
        # repeated benchmark runs never re-trace either).  ``programs``
        # may still be injected (pre-jitted) for back-compat.
        if numeric:
            if programs is not None:
                assert len(programs) == scfg.n_stages
            self.executors: list[Optional[StageExecutor]] = \
                build_numeric_executors(
                    cfg, scfg.n_stages, scfg.seq_len,
                    compress=self.compress_mode,
                    quant_block=scfg.quant_block, programs=programs)
            self.programs: list[StageProgram] = \
                [e.prog for e in self.executors]
        else:
            self.executors = [None] * scfg.n_stages
            self.programs = [None] * scfg.n_stages
        self._ref_params: Optional[list[Tree]] = None
        if numeric:
            self._ref_params = init_stage_params(
                self.programs, jax.random.PRNGKey(seed))
            self._ref_opt = [optimizer.init(p) for p in self._ref_params]

        self.peers: dict[str, Peer] = {}
        self.wirings: list[StochasticWiring] = []
        self.trainers: list[Trainer] = []

        # training progress
        self.stopped = False
        self._mb_counter = 0
        self._inflight = 0
        self._dispatch_paused = False
        self.step = 0
        # exactly-once accounting (App. A): which (stage, microbatch)
        # pairs of the current round are held, and by whom
        self.ledger = MicrobatchLedger(scfg.n_stages)
        # optional audit trail, as (kind, step, stage, index, attempt,
        # peer_id) with kind in {"acc", "rel", "step"}: every applied
        # accumulation, every release (grads dying with a failed or
        # migrating peer), and an All-Reduce barrier marker — the churn
        # tests replay it to assert the exactly-once invariant
        self.record_accumulation = record_accumulation
        self.ledger_log: list[tuple[str, int, int, int, int, str]] = []
        self.metrics: dict[str, list] = {
            "loss": [], "step_time": [], "samples_done": [],
            "throughput_t": [], "throughput_v": [], "migrations": 0,
            "failures": 0, "joins": 0, "recomputed_microbatches": 0,
            "ckpt_restores": [],     # (stage, restored-from step)
            "rollbacks": [],         # (step rolled back from, to)
        }
        self._samples_done_total = 0
        self._flops_per_sample_total = 0.0
        self._default_ds = None      # built once, on first use
        # cold-start resume: a non-empty ckpt_dir means this runner
        # CONTINUES that run — adopt the latest consistent cut's step
        # and data cursor, so peers restore step-k params AND training
        # replays the same sample indices fault-free training would use
        # from step k (otherwise later saves would also be pruned in
        # favor of the stale higher-numbered ones)
        self._resume_step = self._common_ckpt_step() if numeric else 0
        if self._resume_step:
            K = scfg.global_batch // max(scfg.microbatch_size, 1)
            self.step = self._resume_step
            self._mb_counter = self._resume_step * K
        self._open_round()

    # ================================================== setup
    def add_peer(self, stage: int, profile: Optional[DeviceProfile] = None,
                 executor: Optional[StageExecutor] = None) -> Peer:
        """Cold-start a peer (initial ``build``): at step 0 the reference
        params ARE current, so announcing immediately is safe.  Mid-run
        joins go through ``_join_new_peer``, which downloads the stage
        state *before* announcing (warm join).

        ``executor`` backs the peer with a custom runtime (e.g. a
        :class:`repro.runtime.MeshExecutor` over a device mesh); by
        default the peer shares the stage's numeric executor."""
        if executor is not None:
            assert executor.stage == stage, (executor.stage, stage)
        peer = Peer(self.sim, profile or self.profile_fn(len(self.peers)),
                    stage, executor=executor or self.executors[stage])
        self.peers[peer.id] = peer
        if self.numeric:
            # _resume_step == 0 pins the step-0 reference: stale entries
            # in a torn/leftover ckpt_dir with no common step must not
            # leak differing per-stage "latest" params into a fresh run
            self._restore_from_checkpoint(peer, stage,
                                          step=self._resume_step)
        self._announce(peer)
        for w in self.wirings:
            w.add_server(peer.id, [stage])
        self.sim.spawn(self._announcer(peer))
        return peer

    def build(self, peers_per_stage: int | list[int]):
        if isinstance(peers_per_stage, int):
            peers_per_stage = [peers_per_stage] * self.n_stages
        for s, n in enumerate(peers_per_stage):
            for _ in range(n):
                self.add_peer(s)
        for i in range(self.scfg.n_trainers):
            w = StochasticWiring(self.n_stages,
                                 gamma=self.scfg.wiring_gamma,
                                 seed=1000 + i)
            for pid, p in self.peers.items():
                if p.alive:
                    w.add_server(pid, [p.stage])
            self.wirings.append(w)
            t = Trainer(self.sim, self, w, f"trainer{i}",
                        max_retries=self.scfg.trainer_max_retries)
            self.trainers.append(t)
            self.sim.spawn(t.run())
        self.sim.spawn(self._sync_loop())
        if self.scfg.rebalance_period > 0:
            self.sim.spawn(self._rebalance_loop())

    # ================================================== DHT liveness
    def _announce(self, peer: Peer):
        self.dht.store(self.dht.stage_key(peer.stage), peer.id, peer.stage,
                       self.scfg.announce_ttl)

    def _announcer(self, peer: Peer):
        gen = peer._generation
        while peer.alive and peer._generation == gen and not self.stopped:
            if peer.serving:          # no announcements mid-download
                self._announce(peer)
            yield Sleep(self.scfg.announce_interval)

    def announced_stages(self) -> dict[str, int]:
        out = {}
        for s in range(self.n_stages):
            for pid, rec in self.dht.get(self.dht.stage_key(s)).items():
                peer = self.peers.get(pid)
                if peer is not None and peer.alive and peer.serving \
                        and peer.stage == s:
                    out[pid] = s
        return out

    # ================================================== data / dispatch
    def _open_round(self):
        """Fix the next round's sample set: exactly ``global_batch``
        samples (App. E synchronous semantics).  Lost samples re-issue
        under the *same* index, so the per-step sample set is identical
        to fault-free training."""
        K = self.scfg.global_batch // max(self.scfg.microbatch_size, 1)
        self.ledger.open_round(
            range(self._mb_counter, self._mb_counter + K))
        self._mb_counter += K

    def next_microbatch(self) -> Optional[Microbatch]:
        """Hand out work while some stage of the current round is short —
        the ledger re-issues exactly the indices whose gradients died
        with failed or migrated peers (App. A)."""
        if self.stopped or self._dispatch_paused:
            return None
        nxt = self.ledger.next_index()
        if nxt is None:
            return None
        idx, attempt = nxt
        if attempt > 1:
            self.metrics["recomputed_microbatches"] += 1
        self._inflight += 1
        b, S = self.scfg.microbatch_size, self.scfg.seq_len
        mb = Microbatch(index=idx, size=b, n_tokens=b * S, attempt=attempt)
        if self.numeric:
            batch = (self.data_fn(idx) if self.data_fn else
                     self._default_data(idx))
            mb.tokens, mb.labels = batch["tokens"], batch["labels"]
        return mb

    def _default_data(self, idx: int) -> dict:
        if self._default_ds is None:    # one dataset per runner, reused
            from repro.data.synthetic import SyntheticLM
            self._default_ds = SyntheticLM(
                self.cfg.vocab_size, self.scfg.seq_len,
                self.scfg.microbatch_size, seed=17)
        return self._default_ds.batch(idx)

    def microbatch_done(self, mb: Microbatch, ok: bool):
        self._inflight -= 1
        # the ledger re-queues the index iff some stage still lacks it
        # (failed attempt, or a holder died mid-flight)
        self.ledger.settle(mb.index)
        if ok:
            self._samples_done_total += mb.size
            self.metrics["throughput_t"].append(self.sim.now)
            self.metrics["throughput_v"].append(self._samples_done_total)

    # ================================================== cost model
    def compute_time(self, peer: Peer, kind: str, stage: int,
                     mb: Microbatch) -> float:
        ex = (peer.executor if peer.executor is not None
              and peer.executor.stage == stage else self.executors[stage])
        if ex is not None:
            fpt = (ex.fwd_flops_per_token if kind == "fwd"
                   else ex.bwd_flops_per_token)
            # a mesh-backed peer splits the microbatch over its data
            # axis (data-parallel within the peer); dp_shards reports
            # the ACTUAL split — 1 when divisibility forces replication
            speedup = max(1, ex.dp_shards(mb.size))
            return peer.profile.compute_time(fpt * mb.n_tokens) / speedup
        else:
            ctx = F._ctx_for(self.cfg, self.scfg.seq_len, causal_avg=True)
            per = self.cfg.n_layers // self.n_stages
            kinds = self.cfg.block_kinds[stage * per:(stage + 1) * per]
            fpt = sum(F.per_token_layer_flops(self.cfg, k, ctx)
                      for k in kinds)
            if stage == self.n_stages - 1:
                fpt += 2 * self.cfg.d_model * self.cfg.vocab_size
            if kind == "bwd":
                fpt *= 3.0
        return peer.profile.compute_time(fpt * mb.n_tokens)

    def boundary_nbytes(self, mb: Microbatch) -> float:
        # one mode string end-to-end: the sim charges exactly the bytes the
        # active codec puts on the wire (flops.boundary_bytes is the same
        # formula bench_compression measures against the real tensors)
        return F.boundary_bytes(
            self.cfg, mb.size, self.scfg.seq_len, self.compress_mode)

    # ================================================== gradient sync
    def accumulate(self, peer: Peer, gp: Optional[Tree], mb: Microbatch,
                   loss: Optional[float], stage: Optional[int] = None
                   ) -> bool:
        """Fold a microbatch gradient into ``peer``'s accumulator —
        exactly once per (stage, index) per round.  A re-issued attempt
        falls through for the stages that already hold the gradient
        (re-running backward with unchanged params reproduces it
        bit-for-bit, so skipping is exact)."""
        s = peer.stage if stage is None else stage
        if not self.ledger.record(s, mb.index, peer.id):
            return False
        if self.record_accumulation:
            self.ledger_log.append(
                ("acc", self.step, s, mb.index, mb.attempt, peer.id))
        if peer.executor is not None:
            # executor-owned fold (donated accumulator buffer)
            peer.executor.accumulate(peer.state, gp, loss, mb.n_tokens)
        else:                               # timing-only simulation
            peer.state.token_count += mb.n_tokens
            if loss is not None:
                peer.state.loss_sum += loss
        return True

    def _sync_loop(self):
        """Trigger All-Reduce + optimizer step when the ledger shows the
        full global batch accumulated at every stage.  Lost indices are
        re-issued by ``next_microbatch`` (via the ledger) concurrently —
        there is no separate recompute budget to over- or under-open."""
        while not self.stopped:
            # barrier: every stage holds every index AND nothing is in
            # flight (an in-flight re-issue may still run stale thunks
            # whose accumulations must land in *this* round)
            if not self.ledger.complete() or self._inflight > 0:
                yield Sleep(0.2)
                continue
            self._dispatch_paused = True
            t0 = self.sim.now
            yield from self._all_reduce_and_step()
            self.metrics["step_time"].append(self.sim.now - t0)
            self._open_round()
            self._dispatch_paused = False
            if (self.scfg.max_steps is not None
                    and self.step >= self.scfg.max_steps):
                self.stopped = True

    def _log_releases(self, lost: list[tuple[int, int]], peer_id: str):
        if self.record_accumulation:
            for s, i in lost:
                self.ledger_log.append(("rel", self.step, s, i, 0, peer_id))

    def _all_reduce_and_step(self):
        """Per-stage ring All-Reduce (time) + optimizer step (numerics).

        All numerics are computed at the barrier instant (no yields in
        the snapshot loop): failures landing inside the All-Reduce
        window cannot retroactively remove gradients from a step that
        already observed the complete global batch.  Migrations and
        state adoptions defer until the window closes (see ``_migrate``
        / ``_download_state``)."""
        if self.record_accumulation:
            self.ledger_log.append(("step", self.step, -1, -1, 0, ""))
        plan = []
        for s in range(self.n_stages):
            # non-serving peers are mid-download: stale params, drained
            # grads — they adopt the stepped state when the download ends
            group = [p for p in self.peers.values()
                     if p.alive and p.serving and p.stage == s]
            if not group:
                continue
            k = len(group)
            nbytes = group[0].state_nbytes() / 3.0   # grads only
            if nbytes == 0.0:                        # throughput mode
                nbytes = 2.0 * F.total_params(self.cfg) / self.n_stages
            ar_time = (2 * (k - 1) / max(k, 1)) * nbytes \
                / self.scfg.allreduce_bw + 0.01 * k
            new_params = new_opt = None
            if self.numeric:
                # average gradients over the stage (token-weighted);
                # export_grads yields scheduler-local trees, so the sum
                # mixes numeric and mesh-backed peers freely
                total_tokens = sum(p.state.token_count for p in group)
                gsum = group[0].executor.export_grads(group[0].state)
                for p in group[1:]:
                    gsum = jax.tree.map(lambda a, b: a + b, gsum,
                                        p.executor.export_grads(p.state))
                gmean = jax.tree.map(lambda g: g / max(total_tokens, 1),
                                     gsum)
                params, opt = group[0].executor.export_state(
                    group[0].state)
                updates, new_opt = self.optimizer.update(gmean, opt, params)
                new_params = jax.tree.map(
                    lambda p, u: p + u.astype(p.dtype), params, updates)
                loss_sum = sum(p.state.loss_sum for p in group)
                if s == self.n_stages - 1 and total_tokens:
                    self.metrics["loss"].append(loss_sum / total_tokens)
            plan.append((group, ar_time, new_params, new_opt))
        for group, ar_time, new_params, new_opt in plan:
            yield Sleep(ar_time)
            for p in group:
                if not p.alive:      # died inside the ring: state is dead
                    continue
                if self.numeric:
                    # install + re-place on the peer's backend, bump the
                    # version, zero the accumulator
                    p.executor.adopt_step(p.state, new_params, new_opt)
                else:
                    p.state.zero_grads()
        self.step += 1
        self._maybe_checkpoint()

    # ================================================== rebalancing
    def _rebalance_loop(self):
        T = self.scfg.rebalance_period
        while not self.stopped:
            yield Sleep(T)
            # peers report queue sizes (Alg. 2 line 4); mid-download
            # peers neither report nor qualify as migration donors
            for p in self.peers.values():
                if p.alive and p.serving:
                    self.dht.store(self.dht.load_key(p.stage), p.id,
                                   p.queue_size() + 1e-3, T * 1.5)
            pps = {s: [p.id for p in self.peers.values()
                       if p.alive and p.serving and p.stage == s]
                   for s in range(self.n_stages)}
            mig = rb.plan_migration(self.dht, self.n_stages, pps)
            if mig is None:
                continue
            yield from self._migrate(self.peers[mig.peer], mig.dst_stage)

    def _maybe_checkpoint(self):
        """Persist every stage's state (executor ``snapshot()`` →
        ``repro.ckpt``) after a completed optimizer step, so a stage that
        later loses ALL its peers resumes from here instead of step 0.

        A checkpoint is a *pipeline-consistent cut*: either every stage
        is saved at this step or none is (a stranded stage skips the
        whole save), so every stage directory always holds the same step
        numbers — which is what lets ``_rollback_to`` restore one
        uniform parameter version and ``prune_checkpoints`` keep only
        the latest cut."""
        if (not self.numeric or not self.scfg.ckpt_dir
                or self.step % max(self.scfg.ckpt_period, 1)):
            return
        holders = []
        for s in range(self.n_stages):
            holder = next((p for p in self.peers.values()
                           if p.alive and p.serving and p.stage == s
                           and p.state.params is not None), None)
            if holder is None:
                return                 # no consistent cut exists right now
            holders.append(holder)
        from repro.ckpt import prune_checkpoints, save_checkpoint, \
            stage_dir
        for s, holder in enumerate(holders):
            d = stage_dir(self.scfg.ckpt_dir, s)
            save_checkpoint(d, self.step,
                            holder.executor.snapshot(holder.state))
            # keep 2 cuts: if a process dies between per-stage saves the
            # torn newest cut is excluded by _common_ckpt_step's
            # intersection and resume falls back to the previous one
            prune_checkpoints(d, keep=2)

    def _common_ckpt_step(self) -> int:
        """Newest checkpointed step EVERY stage can serve (0 if none).
        A torn cut — a process killed between per-stage saves leaves
        stage dirs at different steps — is excluded by the intersection,
        never resumed at mixed versions."""
        if not self.scfg.ckpt_dir:
            return 0
        from repro.ckpt import available_steps, stage_dir
        common = None
        for s in range(self.n_stages):
            steps = set(available_steps(
                stage_dir(self.scfg.ckpt_dir, s)))
            common = steps if common is None else common & steps
        return max(common) if common else 0

    def _rollback_to(self, step_k: int):
        """A stage must resume from checkpoint step ``step_k`` < the
        pipeline's current step: rewind EVERY stage to it (Varuna-style
        global rollback), so the pipeline trains one consistent version.
        Rewinds the step counter, the data cursor, and the loss
        trajectory — the replayed steps consume the same sample indices
        fault-free training used after ``step_k``, so the final
        trajectory still matches the reference."""
        self._dispatch_paused = True
        # drain in-flight microbatches: their accumulations belong to
        # the aborted round (attempts against the stranded stage fail
        # once trainer retries exhaust)
        while self._inflight > 0 and not self.stopped:
            yield Sleep(0.1)
        if self.stopped:
            return
        for s in range(self.n_stages):
            group = [p for p in self.peers.values()
                     if p.alive and p.serving and p.stage == s
                     and p.executor is not None]
            if not group:
                continue
            # one disk read per stage, fanned out to all its peers:
            # explicitly the target step (not "latest"), so every stage
            # rewinds to the SAME consistent cut (0 = step-0 reference)
            snap = self._ckpt_snapshot(s, step=step_k)
            for p in group:
                p.executor.restore(p.state, snap)
        self.metrics["rollbacks"].append((self.step, step_k))
        K = self.scfg.global_batch // max(self.scfg.microbatch_size, 1)
        self.step = step_k
        self._mb_counter = step_k * K
        # the loss list is relative to the step this RUNNER started at
        # (a cold-resumed runner begins with an empty list at step
        # _resume_step), so truncate by offset, not absolute step
        del self.metrics["loss"][max(step_k - self._resume_step, 0):]
        self._open_round()
        self._dispatch_paused = False

    def _restore_from_checkpoint(self, peer: Peer, stage: int,
                                 step: Optional[int] = None):
        """Stage died entirely (or a cold start): restore the persisted
        checkpoint (``step``; None = the latest; 0 = explicitly the
        step-0 reference params, bypassing the directory) through the
        peer's executor, falling back to the reference when nothing is
        saved."""
        if self._ref_params is None:         # timing-only: no state
            return
        peer.executor.restore(peer.state,
                              self._ckpt_snapshot(stage, step=step))

    def _ckpt_snapshot(self, stage: int, step: Optional[int] = None):
        """Host snapshot tree for ``stage`` (see
        ``_restore_from_checkpoint`` for the ``step`` semantics)."""
        snap = {"params": self._ref_params[stage],
                "opt": self._ref_opt[stage], "version": 0}
        if self.scfg.ckpt_dir and step != 0:
            from repro.ckpt import (available_steps, restore_checkpoint,
                                    stage_dir)
            d = stage_dir(self.scfg.ckpt_dir, stage)
            try:
                snap, got = restore_checkpoint(d, like=snap, step=step)
                self.metrics["ckpt_restores"].append((stage, got))
            except FileNotFoundError:
                # only an EMPTY stage dir may fall back to the step-0
                # reference; a present-but-missing explicitly requested
                # step means the directory is inconsistent with its
                # siblings — restoring anything else would silently mix
                # parameter versions across stages
                if step is not None and available_steps(d):
                    raise RuntimeError(
                        f"checkpoint dir {d} has steps "
                        f"{available_steps(d)} but not the requested "
                        f"step {step} — stage dirs are inconsistent")
        return snap

    def _download_state(self, peer: Peer, dst: int):
        """Warm-state download: copy ``dst``'s replicated state from a
        live serving neighbor (retrying if the donor dies mid-transfer),
        falling back to the checkpoint when the stage has no survivors.
        Returns with ``peer.state`` current for ``dst`` — or early if
        the peer itself dies."""
        if not self.numeric:           # timing-only state transfer
            yield Sleep(1.0)
            return

        def live_donors():
            return [p for p in self.peers.values()
                    if p.alive and p.serving and p.stage == dst
                    and p is not peer]

        while True:
            donors = live_donors()
            if not donors:
                yield Sleep(1.0)
                # same discipline as the donor path below: never adopt
                # (or get snapshotted serving stale state) inside an
                # All-Reduce window — the stage would re-checkpoint the
                # pre-step params under the post-step number
                while self._dispatch_paused and not self.stopped:
                    yield Sleep(0.05)
                if not peer.alive or self.stopped:
                    return
                if live_donors():
                    continue           # a peer recovered during the wait
                if self._ref_params is None:
                    return
                # truly stranded: resume from the latest persisted
                # checkpoint.  If that checkpoint is older than the
                # pipeline's current step (ckpt_period > 1, or no
                # ckpt_dir at all), first rewind the WHOLE pipeline to
                # it (Varuna-style global rollback) — a lone stage must
                # never serve params from an older step than its
                # neighbors.
                k = self._common_ckpt_step()
                if k < self.step:
                    yield from self._rollback_to(k)
                if peer.alive:
                    self._restore_from_checkpoint(peer, dst, step=k)
                return
            donor = donors[0]
            yield Sleep(peer.profile.recv_time(donor.state_nbytes()))
            # adopt outside the All-Reduce window, or the joiner would
            # capture pre-step params while the stage steps past it
            while self._dispatch_paused and not self.stopped:
                yield Sleep(0.05)
            if not peer.alive:
                return
            if donor.alive and donor.serving and donor.stage == dst:
                peer.adopt_state_from(donor)
                return

    def _complete_warm_join(self, peer: Peer, dst: int):
        """Warm-join tail shared by migrations and joins: the state
        download completes BEFORE the peer is announced or entered into
        any wiring — a (re)joining peer must never serve stale params.
        Returns False if the peer died mid-download."""
        peer.serving = False
        yield from self._download_state(peer, dst)
        if not peer.alive:                     # preempted mid-download
            return False
        peer.serving = True
        self._announce(peer)
        for w in self.wirings:
            w.move_server(peer.id, [dst])
        return True

    def _migrate(self, peer: Peer, dst: int):
        """Stage switch, in exactly-once order: stop serving, drain the
        queued src-stage thunks (they must never execute against the
        adopted dst params), release the ledger entries the peer's
        gradients backed (survivors recompute those indices), download
        the dst state — and only then re-announce and re-enter wirings."""
        # never yank accumulated grads out of an in-progress All-Reduce
        while self._dispatch_paused and not self.stopped:
            yield Sleep(0.05)
        if self.stopped or not peer.alive or not peer.serving:
            return
        # re-check after the deferral: the plan was made from an older
        # snapshot, and leaving must not strand the source stage
        if not any(q.alive and q.serving and q.stage == peer.stage
                   and q is not peer for q in self.peers.values()):
            return
        src = peer.stage
        peer.stage = dst                       # stops accepting src work
        if peer.executor is not None:          # same backend, dst stage
            peer.executor = peer.executor.for_stage(dst)
        peer.serving = False
        peer.drain()
        self._log_releases([(src, i) for i in
                            self.ledger.release_peer(src, peer.id)],
                           peer.id)
        peer.state.zero_grads()                # src grads die with the move
        self.dht.delete(self.dht.stage_key(src), peer.id)
        self.dht.delete(self.dht.load_key(src), peer.id)
        for w in self.wirings:
            w.ban_server(peer.id)
        ok = yield from self._complete_warm_join(peer, dst)
        if ok:
            self.metrics["migrations"] += 1

    # ================================================== fault injection
    def apply_trace(self, trace: list[TraceEvent]):
        self.sim.spawn(self._trace_proc(trace))

    def _trace_proc(self, trace: list[TraceEvent]):
        for ev in trace:
            dt = ev.time - self.sim.now
            if dt > 0:
                yield Sleep(dt)
            if self.stopped:
                return
            if ev.delta < 0:
                for _ in range(-ev.delta):
                    self._fail_random_peer()
            else:
                for _ in range(ev.delta):
                    yield from self._join_new_peer()

    def _fail_random_peer(self):
        live = [p for p in self.peers.values() if p.alive]

        def n_serving(s: int) -> int:
            return sum(1 for q in live if q.serving and q.stage == s)
        # never strand a stage: a serving peer may die only if a second
        # serving peer covers its stage; a mid-download peer may die
        # only if its target stage is still served by someone
        candidates = [p for p in live
                      if (p.serving and n_serving(p.stage) > 1)
                      or (not p.serving and n_serving(p.stage) >= 1)]
        if not candidates:
            return
        self._fail_peer(candidates[self.rng.integers(len(candidates))])

    def _fail_peer(self, victim: Peer):
        """Preempt ``victim`` NOW (no stage-coverage guard — callers that
        must not strand a stage check first, e.g. ``_fail_random_peer``;
        stranding a stage is legal and exercises the checkpoint
        fallback)."""
        victim.fail()
        self.metrics["failures"] += 1
        # the victim's accumulated gradients die with it: survivors
        # recompute exactly the indices it held (App. A)
        self._log_releases(self.ledger.release_all(victim.id), victim.id)
        for w in self.wirings:
            w.ban_server(victim.id)
        self.dht.delete(self.dht.stage_key(victim.stage), victim.id)
        self.dht.delete(self.dht.load_key(victim.stage), victim.id)

    def _join_new_peer(self):
        # new peers join the most loaded stage (§3.2 "assigned to the
        # optimal pipeline stage by following the same protocol")
        loads = []
        for s in range(self.n_stages):
            group = [p for p in self.peers.values()
                     if p.alive and p.serving and p.stage == s]
            q = sum(p.queue_size() for p in group)
            loads.append((q + 1) / max(len(group), 1e-9))
        dst = int(np.argmax(loads))
        # preemptible instances coming back reuse their peer object
        dead = [p for p in self.peers.values() if not p.alive]
        if dead:
            peer = dead[0]
            peer.revive(dst)
            # a revived peer keeps its backend (a mesh slice coming back
            # IS that mesh slice), re-targeted at the join stage
            peer.executor = (peer.executor.for_stage(dst)
                             if peer.executor is not None
                             else self.executors[dst])
        else:
            peer = Peer(self.sim, self.profile_fn(len(self.peers)), dst,
                        executor=self.executors[dst])
            self.peers[peer.id] = peer
        self.metrics["joins"] += 1
        ok = yield from self._complete_warm_join(peer, dst)
        if ok:
            self.sim.spawn(self._announcer(peer))

    # ================================================== run
    def run(self, until: Optional[float] = None,
            max_steps: Optional[int] = None):
        if max_steps is not None:
            self.scfg = dataclasses.replace(self.scfg, max_steps=max_steps)
            # _sync_loop reads scfg.max_steps each iteration via self.scfg
        self.sim.run(until=until)
        self.stopped = True
        return self.metrics

    def throughput(self, window: float = None) -> float:
        """Samples/s over the run (optionally trailing window)."""
        ts, vs = (self.metrics["throughput_t"],
                  self.metrics["throughput_v"])
        if len(ts) < 2:
            return 0.0
        if window:
            import bisect
            lo = bisect.bisect_left(ts, ts[-1] - window)
            lo = min(lo, len(ts) - 2)
            return (vs[-1] - vs[lo]) / max(ts[-1] - ts[lo], 1e-9)
        return vs[-1] / max(ts[-1], 1e-9)
