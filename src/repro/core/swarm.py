"""SwarmRunner — the full SWARM parallelism system on the virtual clock.

Composition (paper Fig. 2): consecutive swarms of peers serve pipeline
stages; trainer processes route microbatches via stochastic wiring; a DHT
carries liveness + load; adaptive rebalancing migrates peers between
stages; once the microbatch ledger (repro.core.ledger) shows the global
batch accumulated exactly once at every stage, each stage All-Reduces its
gradients and applies the (optionally delayed, DPU) optimizer step.
Gradients lost to dead or migrating peers are recomputed by survivors
under the same microbatch indices, so an optimizer step under churn
averages the identical sample set as fault-free training (App. A).

A peer's assignment is a contiguous *span* of stages (usually width 1).
Span peers (:class:`repro.runtime.PipelineExecutor`) occupy one DHT slot,
one All-Reduce group, and one ledger row per covered stage, but serve the
whole span in a single jitted step — only span-edge activations cross the
host (the square-cube lever, §3.1).  ``split_span``/``_resize_span``
re-partition spans on membership change, Varuna-style: a shrinking span
peer hands per-stage snapshots to single-stage peers, a merge pulls them
back.

Two modes:
  numeric=True   — real JAX math per stage (convergence experiments,
                   equivalence tests; Fig. 4 / App. E analogues).
  numeric=False  — timing only (Tables 2-5, Figs. 5-7 analogues: 400-peer,
                   32-hour traces run in seconds of wall time).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import codecs
from repro.core.sim import Sim, Sleep, Spawn
from repro.core.dht import DHT
from repro.core.ledger import MicrobatchLedger
from repro.core.peer import Peer, DeviceProfile, PeerFailure, T4
from repro.core.wiring import StochasticWiring
from repro.core.trainer import Trainer, Microbatch
from repro.core import rebalance as rb
from repro.core.faults import TraceEvent
from repro.models.config import ArchConfig
from repro.models import flops as F
from repro.models.stage_plan import StagePlan, get_stage_plan
from repro.optim.adamw import Optimizer
from repro.runtime import StageExecutor, StageProgram, \
    build_numeric_executors, init_stage_params

Tree = Any


def _as_span(stage: "int | range") -> range:
    return stage if isinstance(stage, range) else range(stage, stage + 1)


@dataclasses.dataclass
class SwarmConfig:
    """Swarm-level knobs (the architecture lives in ``ArchConfig``).

    The async tick is controlled by two fields:

    * ``overlap`` — boundary tensors ride the peers' NIC links as
      in-flight transfers (priced end-to-end at the sending/receiving
      pair's bottleneck) instead of two blocking serial sleeps, and
      stage math goes through the executors' dispatch/collect pair.
      Pure timing: the training trajectory is unchanged (bitwise under
      deterministic routing — the equivalence suite asserts it).
    * ``staleness`` — ATOM-style bounded staleness for the All-Reduce
      window: the optimizer step's numerics are applied at the barrier
      instant while the communication window runs *concurrently* with
      the next round's compute; at most ``staleness`` windows may be in
      flight before the next barrier blocks on the oldest.  Any value
      > 0 wraps the optimizer in ``delayed_parameter_updates`` (DPU,
      paper §3.2: fold step t's grads while t+1 computes), so the
      trajectory equals the sequential DPU(delay=1) reference; 0 keeps
      today's fully synchronous barrier bitwise.  ``dpu=True`` is the
      historical spelling of ``staleness=1``.

    Cost-model pricing is plan-driven: the runner computes a
    ``repro.models.stage_plan.StagePlan`` once from ``(ArchConfig,
    n_stages)`` and prices per-stage compute (``stage_flops`` — per
    kind, head on the owning stage) and per-boundary wire bytes
    (``boundary_bytes`` — whisper composite payloads, expert-sharded
    MoE top_k routing) from it; ``rebalance_period``-driven span merges
    rank candidate boundaries by those per-edge prices.

    Kernel backend: the hot path the peers execute is picked by the
    *architecture* config — ``ArchConfig.kernels`` (``"jnp"`` default,
    ``"pallas"`` for the fused flash/rmsnorm/boundary-codec kernels;
    pure backend switch, identical trajectories) and
    ``ArchConfig.wire_quant`` (blockwise-int8 QDQ of the learned
    codec's wire, priced by ``boundary_bytes``); the swarm itself needs
    no knob — runners with either backend share ledger, codec, and
    wire-byte accounting.
    """
    n_stages: int = 3
    microbatch_size: int = 1
    seq_len: int = 128
    global_batch: int = 8                # sequences per optimizer step
    n_trainers: int = 4
    rebalance_period: float = 300.0      # T (paper §4.3)
    announce_interval: float = 120.0
    announce_ttl: float = 300.0
    wiring_gamma: float = 0.1            # EMA alpha (paper §4.3)
    # boundary wire codec, canonical: "none" | "int8" | a learned mode
    # ("bottleneck" | "maxout", paper App. J) | "auto" (defer to
    # ``cfg.boundary_compression``).  Default "int8" is the historical
    # ``compress=True``.
    codec: Optional[str] = None
    # DEPRECATED spelling of ``codec`` (False -> "none", True -> "int8",
    # str passthrough); normalized away in ``__post_init__`` so
    # ``dataclasses.replace`` round-trips never re-warn
    compress: "bool | str | None" = None
    quant_block: int = 64
    dpu: bool = False
    # async tick (see class docstring): in-flight boundary transfers +
    # dispatch/collect execution, and the bounded-staleness All-Reduce
    overlap: bool = False
    staleness: int = 0
    max_steps: Optional[int] = None
    allreduce_bw: float = 50e6           # bytes/s effective per peer
    trainer_max_retries: int = 50        # per-attempt routing retries
    # elastic checkpointing (ROADMAP): persist a pipeline-consistent cut
    # of every stage's state each ``ckpt_period`` completed steps via
    # the executors' snapshot() — a stage that loses ALL its peers
    # resumes from the latest completed step instead of the step-0
    # reference params, and a runner constructed over a non-empty
    # ``ckpt_dir`` RESUMES that run (step counter + data cursor adopt
    # the latest cut)
    ckpt_dir: Optional[str] = None
    ckpt_period: int = 1
    # span rebalancing: let the Alg.-2 loop also propose span splits /
    # merges (repro.core.rebalance.plan_span_change) — a span peer
    # bottlenecked on one stage shrinks onto it, an underloaded peer
    # absorbs an adjacent well-covered stage (saving its host boundary)
    spans: bool = False
    # inter-region cost model (repro.core.square_cube.LinkTable): when
    # set, the rebalance loop prices each boundary over the link between
    # the regions serving its two stages (seconds, not bytes), so span
    # merges fuse across slow WAN pairs first.  Peers get regions from
    # the runner's ``region_fn`` and zone-tagged trace events.
    link_table: Optional[Any] = None

    def __post_init__(self):
        if self.compress is not None:
            resolved = ("int8" if self.compress is True else
                        "none" if self.compress is False else self.compress)
            warnings.warn(
                f"SwarmConfig(compress=...) is deprecated; use "
                f"codec={resolved!r}", DeprecationWarning, stacklevel=3)
            if self.codec is not None and self.codec != resolved:
                raise ValueError(
                    f"conflicting codecs: codec={self.codec!r} vs "
                    f"compress={self.compress!r}")
            self.codec = resolved
            self.compress = None
        if self.codec is None:
            self.codec = "int8"
        if self.codec != "auto" and self.codec not in codecs.MODES:
            raise ValueError(f"unknown codec {self.codec!r}; expected "
                             f"'auto' or one of {codecs.MODES}")
        if self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got "
                             f"{self.staleness}")
        if self.dpu:
            # historical spelling of the bounded-staleness knob
            self.staleness = max(self.staleness, 1)


class SwarmRunner:
    def __init__(self, cfg: ArchConfig, scfg: SwarmConfig,
                 optimizer: Optimizer, *, numeric: bool = True,
                 seed: int = 0,
                 profile_fn: Optional[Callable[[int], DeviceProfile]] = None,
                 data_fn: Optional[Callable[[int], dict]] = None,
                 programs: Optional[list[StageProgram]] = None,
                 record_accumulation: bool = False,
                 region_fn: Optional[Callable[[int], str]] = None):
        self.cfg = cfg
        self.scfg = scfg
        if scfg.staleness > 0:
            # bounded staleness implies DPU: the step applies the grads
            # banked one round ago while this round's fold rides the
            # concurrent All-Reduce window (paper §3.2; ATOM).  Wrapping
            # here keeps checkpoints, the reference init, and every
            # export/adopt consistent with the wrapped state shape.
            from repro.optim.dpu import delayed_parameter_updates
            optimizer = delayed_parameter_updates(optimizer, delay=1)
        self.optimizer = optimizer
        self.overlap = bool(scfg.overlap)
        self.numeric = numeric
        self.sim = Sim()
        self.dht = DHT(lambda: self.sim.now)
        self.n_stages = scfg.n_stages
        # the canonical per-stage structure: kind runs, per-stage flops,
        # per-boundary wire pricing.  Timing-only runs over splits the
        # plan rejects (e.g. indivisible layer counts) fall back to the
        # legacy uniform pricing (plan=None); numeric construction below
        # would raise on such splits anyway.
        try:
            self.plan: Optional[StagePlan] = get_stage_plan(
                cfg, scfg.n_stages)
        except ValueError:
            self.plan = None
        self.compress_mode = codecs.resolve_mode(
            cfg, None if scfg.codec == "auto" else scfg.codec)
        self.quant_block = scfg.quant_block
        self.rng = np.random.default_rng(seed)
        self.profile_fn = profile_fn or (lambda i: T4)
        # zone placement, like profile_fn keyed by join index; only
        # meaningful with scfg.link_table (region-aware edge pricing)
        self.region_fn = region_fn or (lambda i: "local")
        self.data_fn = data_fn

        # stage execution goes through the runtime layer: one executor
        # per stage, shared by all that stage's peers (the process-wide
        # compile cache means the seed matrix of the churn tests and
        # repeated benchmark runs never re-trace either).  ``programs``
        # may still be injected (pre-jitted) for back-compat.  Span
        # executors are built on demand (``_span_executor``) and share
        # the process-wide span-program cache.
        if numeric:
            if programs is not None:
                assert len(programs) == scfg.n_stages
            self.executors: list[Optional[StageExecutor]] = \
                build_numeric_executors(
                    cfg, scfg.n_stages, scfg.seq_len,
                    compress=self.compress_mode,
                    quant_block=scfg.quant_block, programs=programs)
            self.programs: list[StageProgram] = \
                [e.prog for e in self.executors]
        else:
            self.executors = [None] * scfg.n_stages
            self.programs = [None] * scfg.n_stages
        self._ref_params: Optional[list[Tree]] = None
        if numeric:
            self._ref_params = init_stage_params(
                self.programs, jax.random.PRNGKey(seed))
            self._ref_opt = [optimizer.init(p) for p in self._ref_params]

        self.peers: dict[str, Peer] = {}
        self.wirings: list[StochasticWiring] = []
        self.trainers: list[Trainer] = []
        # (lo, hi) -> shared default PipelineExecutor for that span
        self._span_execs: dict[tuple[int, int], StageExecutor] = {}

        # training progress
        self.stopped = False
        self._t_stopped: Optional[float] = None   # virtual stop instant
        self._mb_counter = 0
        self._inflight = 0
        self._dispatch_paused = False
        self.step = 0
        # exactly-once accounting (App. A): which (stage, microbatch)
        # pairs of the current round are held, and by whom.  A span peer
        # holds one row per covered stage.
        self.ledger = MicrobatchLedger(scfg.n_stages)
        # optional audit trail, as (kind, step, stage, index, attempt,
        # peer_id) with kind in {"acc", "rel", "step"}: every applied
        # accumulation, every release (grads dying with a failed or
        # migrating peer), and an All-Reduce barrier marker — the churn
        # tests replay it to assert the exactly-once invariant
        self.record_accumulation = record_accumulation
        self.ledger_log: list[tuple[str, int, int, int, int, str]] = []
        self.metrics: dict[str, list] = {
            "loss": [], "step_time": [], "samples_done": [],
            "throughput_t": [], "throughput_v": [], "migrations": 0,
            "failures": 0, "joins": 0, "recomputed_microbatches": 0,
            "span_changes": 0,       # split/merge/resize events applied
            "wire_bytes": 0.0,       # activation/cotangent bytes that
                                     # actually crossed the host (span-
                                     # fused boundaries charge nothing)
            "ckpt_restores": [],     # (stage, restored-from step)
            "rollbacks": [],         # (step rolled back from, to)
            # async-tick accounting (overlap mode): what the same edges
            # would have cost serially vs what the in-flight transfers
            # actually took; run() derives overlap_fraction/peer_idle_s
            "wire_serial_s": 0.0,
            "wire_inflight_s": 0.0,
            "inflight_bytes": 0.0,
        }
        self._ar_pending: list = []  # unfinished All-Reduce windows
        self._samples_done_total = 0
        self._flops_per_sample_total = 0.0
        self._default_ds = None      # built once, on first use
        # cold-start resume: a non-empty ckpt_dir means this runner
        # CONTINUES that run — adopt the latest consistent cut's step
        # and data cursor, so peers restore step-k params AND training
        # replays the same sample indices fault-free training would use
        # from step k (otherwise later saves would also be pruned in
        # favor of the stale higher-numbered ones)
        self._resume_step = self._common_ckpt_step() if numeric else 0
        if self._resume_step:
            K = scfg.global_batch // max(scfg.microbatch_size, 1)
            self.step = self._resume_step
            self._mb_counter = self._resume_step * K
        self._open_round()

    # ================================================== setup
    def _span_executor(self, span: range) -> Optional[StageExecutor]:
        """The default executor for a span assignment (None in timing
        mode): the stage family for width 1, a runner-cached
        PipelineExecutor otherwise — so ALL default-backed peers of one
        span share one executor object (which is what keeps
        ``adopt_state_from``'s zero-copy alias path hot and avoids
        re-building executor families on every split/merge)."""
        if self.executors[span.start] is None:
            return None
        if len(span) == 1:
            return self.executors[span.start]
        key = (span.start, span.stop)
        ex = self._span_execs.get(key)
        if ex is None:
            ex = self._span_execs[key] = \
                self.executors[span.start].for_span(span)
        return ex

    def _rebacked_executor(self, peer: Peer,
                           span: range) -> Optional[StageExecutor]:
        """``peer``'s backend re-targeted at ``span``: custom backends
        (mesh slices) keep themselves via ``for_span``; default-backed
        peers go back through the runner's shared executors."""
        if peer.executor is None:
            return None
        from repro.runtime import MeshExecutor, MeshSpanExecutor
        if isinstance(peer.executor, (MeshExecutor, MeshSpanExecutor)):
            return peer.executor.for_span(span)
        return self._span_executor(span)

    def _routes_without(self, peer: Peer,
                        new_span: Optional[range]) -> bool:
        """Would the serving layout still tile [0, n_stages) if ``peer``
        served ``new_span`` (None = left entirely)?  Coverage alone is
        not enough — a hop enters a span only at its start (see
        ``rebalance.spans_route``)."""
        layout = [(q.stages.start, q.stages.stop)
                  for q in self.peers.values()
                  if q.alive and q.serving and q is not peer]
        if new_span is not None:
            layout.append((new_span.start, new_span.stop))
        return rb.spans_route(self.n_stages, layout)

    def add_peer(self, stage: "int | range",
                 profile: Optional[DeviceProfile] = None,
                 executor: Optional[StageExecutor] = None) -> Peer:
        """Cold-start a peer (initial ``build``): at step 0 the reference
        params ARE current, so announcing immediately is safe.  Mid-run
        joins go through ``_join_new_peer``, which downloads the stage
        state *before* announcing (warm join).

        ``stage`` may be a single stage or a contiguous ``range(lo, hi)``
        span; ``executor`` backs the peer with a custom runtime (e.g. a
        :class:`repro.runtime.MeshExecutor` over a device mesh, or a
        :class:`repro.runtime.PipelineExecutor` for a span); by default
        the peer shares the span's cached executor."""
        span = _as_span(stage)
        if executor is not None:
            assert (executor.stages.start, executor.stages.stop) == \
                (span.start, span.stop), (executor.stages, span)
        else:
            executor = self._span_executor(span)
        peer = Peer(self.sim, profile or self.profile_fn(len(self.peers)),
                    span, executor=executor,
                    region=self.region_fn(len(self.peers)))
        self.peers[peer.id] = peer
        if self.numeric:
            # _resume_step == 0 pins the step-0 reference: stale entries
            # in a torn/leftover ckpt_dir with no common step must not
            # leak differing per-stage "latest" params into a fresh run
            for s in peer.stages:
                self._restore_from_checkpoint(peer, s,
                                              step=self._resume_step)
        self._announce(peer)
        for w in self.wirings:
            w.add_server(peer.id, [peer.stages.start])
        self.sim.spawn(self._announcer(peer))
        return peer

    def build(self, peers_per_stage: int | list[int]):
        if isinstance(peers_per_stage, int):
            peers_per_stage = [peers_per_stage] * self.n_stages
        for s, n in enumerate(peers_per_stage):
            for _ in range(n):
                self.add_peer(s)
        for i in range(self.scfg.n_trainers):
            w = StochasticWiring(self.n_stages,
                                 gamma=self.scfg.wiring_gamma,
                                 seed=1000 + i)
            for pid, p in self.peers.items():
                if p.alive:
                    w.add_server(pid, [p.stages.start])
            self.wirings.append(w)
            t = Trainer(self.sim, self, w, f"trainer{i}",
                        max_retries=self.scfg.trainer_max_retries)
            self.trainers.append(t)
            self.sim.spawn(t.run())
        self.sim.spawn(self._sync_loop())
        if self.scfg.rebalance_period > 0:
            self.sim.spawn(self._rebalance_loop())

    # ================================================== DHT liveness
    def _announce(self, peer: Peer):
        # a span peer occupies EVERY covered stage slot: liveness and
        # coverage are per stage, even though routing only enters the
        # span at its start
        for s in peer.stages:
            self.dht.store(self.dht.stage_key(s), peer.id, s,
                           self.scfg.announce_ttl)

    def _dht_forget(self, peer: Peer, span: Optional[range] = None):
        for s in (span if span is not None else peer.stages):
            self.dht.delete(self.dht.stage_key(s), peer.id)
            self.dht.delete(self.dht.load_key(s), peer.id)

    def _announcer(self, peer: Peer):
        gen = peer._generation
        while peer.alive and peer._generation == gen and not self.stopped:
            if peer.serving:          # no announcements mid-download
                self._announce(peer)
            yield Sleep(self.scfg.announce_interval)

    def announced_stages(self) -> dict[str, int]:
        """Live serving peers by their ROUTING slot (span start) — what
        the wirings refresh from.  Coverage queries go per stage via
        ``_covering``."""
        out = {}
        for s in range(self.n_stages):
            for pid, rec in self.dht.get(self.dht.stage_key(s)).items():
                peer = self.peers.get(pid)
                if peer is not None and peer.alive and peer.serving \
                        and s in peer.stages:
                    out[pid] = peer.stages.start
        return out

    def _covering(self, stage: int, but: Optional[Peer] = None
                  ) -> list[Peer]:
        """Live serving peers whose span covers ``stage``."""
        return [p for p in self.peers.values()
                if p.alive and p.serving and stage in p.stages
                and p is not but]

    def _stage_regions(self) -> list[str]:
        """Dominant region per stage: the most common zone among the
        live serving peers covering it (alphabetical tie-break; "local"
        when nobody covers).  This is the per-stage region vector the
        link table prices boundary edges with."""
        regions = []
        for s in range(self.n_stages):
            counts: dict[str, int] = {}
            for p in self._covering(s):
                r = getattr(p, "region", "local")
                counts[r] = counts.get(r, 0) + 1
            regions.append(max(sorted(counts), key=counts.get)
                           if counts else "local")
        return regions

    # ================================================== data / dispatch
    def _open_round(self):
        """Fix the next round's sample set: exactly ``global_batch``
        samples (App. E synchronous semantics).  Lost samples re-issue
        under the *same* index, so the per-step sample set is identical
        to fault-free training."""
        K = self.scfg.global_batch // max(self.scfg.microbatch_size, 1)
        self.ledger.open_round(
            range(self._mb_counter, self._mb_counter + K))
        self._mb_counter += K

    def next_microbatch(self) -> Optional[Microbatch]:
        """Hand out work while some stage of the current round is short —
        the ledger re-issues exactly the indices whose gradients died
        with failed or migrated peers (App. A)."""
        if self.stopped or self._dispatch_paused:
            return None
        nxt = self.ledger.next_index()
        if nxt is None:
            return None
        idx, attempt = nxt
        if attempt > 1:
            self.metrics["recomputed_microbatches"] += 1
        self._inflight += 1
        b, S = self.scfg.microbatch_size, self.scfg.seq_len
        mb = Microbatch(index=idx, size=b, n_tokens=b * S, attempt=attempt)
        if self.numeric:
            batch = (self.data_fn(idx) if self.data_fn else
                     self._default_data(idx))
            mb.tokens, mb.labels = batch["tokens"], batch["labels"]
        return mb

    def _default_data(self, idx: int) -> dict:
        if self._default_ds is None:    # one dataset per runner, reused
            from repro.data.synthetic import SyntheticLM
            self._default_ds = SyntheticLM(
                self.cfg.vocab_size, self.scfg.seq_len,
                self.scfg.microbatch_size, seed=17)
        return self._default_ds.batch(idx)

    def microbatch_done(self, mb: Microbatch, ok: bool):
        self._inflight -= 1
        # the ledger re-queues the index iff some stage still lacks it
        # (failed attempt, or a holder died mid-flight)
        self.ledger.settle(mb.index)
        if ok:
            self._samples_done_total += mb.size
            self.metrics["throughput_t"].append(self.sim.now)
            self.metrics["throughput_v"].append(self._samples_done_total)

    # ================================================== cost model
    def compute_time(self, peer: Peer, kind: str, stage: int,
                     mb: Microbatch) -> float:
        ex = (peer.executor if peer.executor is not None
              and stage in peer.executor.stages else self.executors[stage])
        if ex is not None:
            # span executors report whole-span totals: one hop runs the
            # entire fused span
            fpt = (ex.fwd_flops_per_token if kind == "fwd"
                   else ex.bwd_flops_per_token)
            # a mesh-backed peer splits the microbatch over its data
            # axis (data-parallel within the peer); dp_shards reports
            # the ACTUAL split — 1 when divisibility forces replication
            speedup = max(1, ex.dp_shards(mb.size))
            return peer.profile.compute_time(fpt * mb.n_tokens) / speedup
        # timing-only: analytic per-stage flops summed over the hop's
        # covered stages, priced per kind by the stage plan
        stages = peer.stages if stage in peer.stages \
            else range(stage, stage + 1)
        if self.plan is not None:
            fpt = sum(self.plan.stage_flops(s, self.scfg.seq_len)
                      for s in stages)
        else:                      # legacy fallback: uniform even split
            ctx = F._ctx_for(self.cfg, self.scfg.seq_len, causal_avg=True)
            per = self.cfg.n_layers // self.n_stages
            fpt = 0.0
            for s in stages:
                kinds = self.cfg.block_kinds[s * per:(s + 1) * per]
                fpt += sum(F.per_token_layer_flops(self.cfg, k, ctx)
                           for k in kinds)
                if s == self.n_stages - 1:
                    fpt += 2 * self.cfg.d_model * self.cfg.vocab_size
        if kind == "bwd":
            fpt *= 3.0
        return peer.profile.compute_time(fpt * mb.n_tokens)

    def boundary_nbytes(self, mb: Microbatch,
                        boundary: Optional[int] = None) -> float:
        # one mode string end-to-end: the sim charges exactly the bytes the
        # active codec puts on the wire (flops.boundary_bytes is the same
        # formula bench_compression measures against the real tensors).
        # With a boundary index the plan prices THAT boundary: uniform
        # hidden-state pricing for dense LM stacks (identical to the
        # legacy formula), but whisper boundaries add the encoder-state
        # + token payload and expert-sharded MoE boundaries pay the
        # per-token-routed top_k factor.
        if (self.plan is not None and boundary is not None
                and 0 <= boundary < self.n_stages - 1):
            return self.plan.boundary_bytes(
                boundary, mb.size, self.scfg.seq_len, self.compress_mode)
        return F.boundary_bytes(
            self.cfg, mb.size, self.scfg.seq_len, self.compress_mode)

    def count_wire_bytes(self, nbytes: float):
        """One boundary tensor actually crossed the host (trainers call
        this per hop edge — span-fused boundaries never do)."""
        self.metrics["wire_bytes"] += nbytes

    def count_inflight_wire(self, serial_s: float, actual_s: float,
                            nbytes: float):
        """One in-flight edge landed (overlap mode): ``serial_s`` is what
        the blocking send+recv pair would have cost, ``actual_s`` what
        the trainer really waited.  Clamped per edge: a wait beyond the
        serial estimate is FIFO queueing on a contended link (the sync
        path priced NICs as infinitely parallel), not negative overlap,
        so it must not cancel savings other edges genuinely hid."""
        self.metrics["wire_serial_s"] += serial_s
        self.metrics["wire_inflight_s"] += min(actual_s, serial_s)
        self.metrics["inflight_bytes"] += nbytes

    # ================================================== gradient sync
    def accumulate(self, peer: Peer, gp: Optional[Tree], mb: Microbatch,
                   loss: Optional[float], stage: Optional[int] = None
                   ) -> bool:
        """Fold a microbatch gradient into ``peer``'s accumulator —
        exactly once per (stage, index) per round, for EVERY stage the
        peer's span covers.  A re-issued attempt falls through for the
        stages that already hold the gradient (re-running backward with
        unchanged params reproduces it bit-for-bit, so skipping is
        exact) — so a span peer may fold a strict subset of its covered
        stages.  ``gp`` is the stage's tree for single-stage peers, a
        ``{global stage id: tree}`` dict for span peers."""
        stages = [stage] if stage is not None else list(peer.stages)
        span_keyed = isinstance(gp, dict) and gp and \
            all(isinstance(k, int) for k in gp)
        last = self.n_stages - 1
        any_folded = False
        for s in stages:
            if not self.ledger.record(s, mb.index, peer.id):
                continue
            if self.record_accumulation:
                self.ledger_log.append(
                    ("acc", self.step, s, mb.index, mb.attempt, peer.id))
            loss_s = loss if s == last else None
            if peer.executor is not None:
                # executor-owned fold (donated accumulator buffer)
                g_s = gp[s] if span_keyed else gp
                peer.executor.accumulate(peer.state, g_s, loss_s,
                                         mb.n_tokens, stage=s)
            else:                               # timing-only simulation
                view = peer.state.stage_view(s)
                view.token_count += mb.n_tokens
                if loss_s is not None:
                    view.loss_sum += loss_s
            any_folded = True
        return any_folded

    def _sync_loop(self):
        """Trigger All-Reduce + optimizer step when the ledger shows the
        full global batch accumulated at every stage.  Lost indices are
        re-issued by ``next_microbatch`` (via the ledger) concurrently —
        there is no separate recompute budget to over- or under-open."""
        if self.scfg.staleness > 0:
            yield from self._sync_loop_async()
            return
        while not self.stopped:
            # barrier: every stage holds every index AND nothing is in
            # flight (an in-flight re-issue may still run stale thunks
            # whose accumulations must land in *this* round)
            if not self.ledger.complete() or self._inflight > 0:
                yield Sleep(0.2)
                continue
            self._dispatch_paused = True
            t0 = self.sim.now
            yield from self._all_reduce_and_step()
            self.metrics["step_time"].append(self.sim.now - t0)
            self._open_round()
            self._dispatch_paused = False
            if (self.scfg.max_steps is not None
                    and self.step >= self.scfg.max_steps):
                self.stopped = True
                self._t_stopped = self.sim.now

    def _sync_loop_async(self):
        """Bounded-staleness barrier (ATOM-style; ``scfg.staleness`` > 0):
        the step's numerics apply ATOMICALLY at the barrier instant
        (identical gradients and install order to the sync path, so the
        trajectory equals the sequential DPU reference), while the
        All-Reduce *time* rides a concurrent window off the critical
        path — the next round's compute starts immediately.  At most
        ``staleness`` windows may be unfinished before the next barrier
        blocks on the oldest; dispatch never pauses (no yields between
        barrier detection and round reopen)."""
        last_barrier = 0.0
        while not self.stopped:
            if not self.ledger.complete() or self._inflight > 0:
                yield Sleep(0.2)
                continue
            self._ar_pending = [ev for ev in self._ar_pending
                                if not ev.fired]
            while len(self._ar_pending) >= self.scfg.staleness:
                yield self._ar_pending[0].wait()
                self._ar_pending = [ev for ev in self._ar_pending
                                    if not ev.fired]
            total = self._all_reduce_and_step_now()
            # step_time = inter-barrier interval: with the window off
            # the critical path this is the number to compare to sync
            self.metrics["step_time"].append(self.sim.now - last_barrier)
            last_barrier = self.sim.now
            ev = self.sim.event()
            self._ar_pending.append(ev)
            self.sim.spawn(self._ar_window(total, ev))
            self._open_round()
            if (self.scfg.max_steps is not None
                    and self.step >= self.scfg.max_steps):
                self.stopped = True
                self._t_stopped = self.sim.now

    def _ar_window(self, duration: float, ev):
        yield Sleep(duration)
        ev.fire()

    def _log_releases(self, lost: list[tuple[int, int]], peer_id: str):
        if self.record_accumulation:
            for s, i in lost:
                self.ledger_log.append(("rel", self.step, s, i, 0, peer_id))

    def _all_reduce_and_step(self):
        """Per-stage ring All-Reduce (time) + optimizer step (numerics).

        All numerics are computed at the barrier instant (no yields in
        the snapshot loop): failures landing inside the All-Reduce
        window cannot retroactively remove gradients from a step that
        already observed the complete global batch.  Migrations and
        state adoptions defer until the window closes (see ``_migrate``
        / ``_download_state``).  A span peer is a member of every
        covered stage's group, with per-stage grads/tokens/install."""
        plan = self._ar_plan()
        for s, group, ar_time, new_params, new_opt in plan:
            yield Sleep(ar_time)
            self._ar_install(s, group, new_params, new_opt)
        self.step += 1
        self._maybe_checkpoint()

    def _all_reduce_and_step_now(self) -> float:
        """Async-barrier variant: identical numerics, applied atomically
        at the barrier instant (no yields at all); returns the total
        All-Reduce time for the concurrent window."""
        plan = self._ar_plan()
        total = 0.0
        for s, group, ar_time, new_params, new_opt in plan:
            total += ar_time
            self._ar_install(s, group, new_params, new_opt)
        self.step += 1
        self._maybe_checkpoint()
        return total

    def _ar_install(self, s: int, group: list, new_params, new_opt):
        for p in group:
            if not p.alive:      # died inside the ring: state is dead
                continue
            if self.numeric:
                # install + re-place on the peer's backend, bump the
                # version, zero the accumulator — per covered stage
                p.executor.adopt_step(p.state, new_params, new_opt,
                                      stage=s)
            else:
                p.state.stage_view(s).zero_grads()

    def _ar_plan(self):
        """Gradient averaging + optimizer step per stage, computed with
        NO yields — shared by the sync and bounded-staleness barriers."""
        if self.record_accumulation:
            self.ledger_log.append(("step", self.step, -1, -1, 0, ""))
        plan = []
        for s in range(self.n_stages):
            # non-serving peers are mid-download: stale params, drained
            # grads — they adopt the stepped state when the download ends
            group = self._covering(s)
            if not group:
                continue
            k = len(group)
            nbytes = group[0].state_nbytes(stage=s) / 3.0   # grads only
            if nbytes == 0.0:                        # throughput mode
                nbytes = 2.0 * F.total_params(self.cfg) / self.n_stages
            ar_time = (2 * (k - 1) / max(k, 1)) * nbytes \
                / self.scfg.allreduce_bw + 0.01 * k
            new_params = new_opt = None
            if self.numeric:
                # average gradients over the stage (token-weighted);
                # export_grads yields scheduler-local trees, so the sum
                # mixes numeric, mesh-backed, and span peers freely
                total_tokens = sum(p.state.stage_view(s).token_count
                                   for p in group)
                gsum = group[0].executor.export_grads(group[0].state,
                                                      stage=s)
                for p in group[1:]:
                    gsum = jax.tree.map(
                        lambda a, b: a + b, gsum,
                        p.executor.export_grads(p.state, stage=s))
                gmean = jax.tree.map(lambda g: g / max(total_tokens, 1),
                                     gsum)
                params, opt = group[0].executor.export_state(
                    group[0].state, stage=s)
                updates, new_opt = self.optimizer.update(gmean, opt, params)
                new_params = jax.tree.map(
                    lambda p, u: p + u.astype(p.dtype), params, updates)
                loss_sum = sum(p.state.stage_view(s).loss_sum
                               for p in group)
                if s == self.n_stages - 1 and total_tokens:
                    self.metrics["loss"].append(loss_sum / total_tokens)
            plan.append((s, group, ar_time, new_params, new_opt))
        return plan

    # ================================================== rebalancing
    def _rebalance_loop(self):
        T = self.scfg.rebalance_period
        while not self.stopped:
            yield Sleep(T)
            # peers report queue sizes (Alg. 2 line 4) under EVERY stage
            # they cover; mid-download peers neither report nor qualify
            # as migration donors
            for p in self.peers.values():
                if p.alive and p.serving:
                    for s in p.stages:
                        self.dht.store(self.dht.load_key(s), p.id,
                                       p.queue_size() + 1e-3, T * 1.5)
            # single-stage moves consider only single-stage donors (a
            # span peer leaving would strand several stages at once);
            # span resizes go through plan_span_change
            pps = {s: [p.id for p in self.peers.values()
                       if p.alive and p.serving and p.stages ==
                       range(s, s + 1)]
                   for s in range(self.n_stages)}
            # ONE frozen control-plane view per round: every decision
            # below reads this capture (S DHT gets total), never the
            # live DHT per candidate — the O(P²·S) -> O(P·S + P log P)
            # restructure of ISSUE 10
            snap = rb.ControlSnapshot.capture(self.dht, self.n_stages)
            mig = rb.plan_migration(snap, self.n_stages, pps)
            if mig is not None:
                yield from self._migrate(self.peers[mig.peer],
                                         mig.dst_stage)
                continue
            if not self.scfg.spans:
                continue
            spans = {p.id: (p.stages.start, p.stages.stop)
                     for p in self.peers.values()
                     if p.alive and p.serving}
            # per-boundary wire prices from the stage plan: merges fuse
            # the most expensive edge first (routed-MoE / whisper
            # boundaries beat uniform hidden-state ones).  With a link
            # table the bytes become region-priced SECONDS — an edge
            # straddling a slow WAN pair ranks highest, so the swarm
            # fuses across slow links first.
            bcosts = (self.plan.boundary_costs(
                self.scfg.microbatch_size, self.scfg.seq_len,
                self.compress_mode) if self.plan is not None else None)
            if bcosts is not None and self.scfg.link_table is not None:
                bcosts = self.scfg.link_table.edge_costs(
                    list(bcosts), self._stage_regions())
            ch = rb.plan_span_change(snap, self.n_stages, spans,
                                     boundary_costs=bcosts)
            if ch is not None:
                yield from self._resize_span(self.peers[ch.peer],
                                             range(*ch.new_span))

    def _maybe_checkpoint(self):
        """Persist every stage's state (executor ``snapshot()`` →
        ``repro.ckpt``) after a completed optimizer step, so a stage that
        later loses ALL its peers resumes from here instead of step 0.

        A checkpoint is a *pipeline-consistent cut*: either every stage
        is saved at this step or none is (a stranded stage skips the
        whole save), so every stage directory always holds the same step
        numbers — which is what lets ``_rollback_to`` restore one
        uniform parameter version and ``prune_checkpoints`` keep only
        the latest cut.  Span peers serve as holders for each covered
        stage — the cut is single-stage snapshots regardless of spans."""
        if (not self.numeric or not self.scfg.ckpt_dir
                or self.step % max(self.scfg.ckpt_period, 1)):
            return
        holders = []
        for s in range(self.n_stages):
            holder = next(
                (p for p in self._covering(s)
                 if p.state.stage_view(s).params is not None), None)
            if holder is None:
                return                 # no consistent cut exists right now
            holders.append(holder)
        from repro.ckpt import prune_checkpoints, save_checkpoint, \
            stage_dir
        for s, holder in enumerate(holders):
            d = stage_dir(self.scfg.ckpt_dir, s)
            save_checkpoint(d, self.step,
                            holder.executor.snapshot(holder.state, stage=s))
            # keep 2 cuts: if a process dies between per-stage saves the
            # torn newest cut is excluded by _common_ckpt_step's
            # intersection and resume falls back to the previous one
            prune_checkpoints(d, keep=2)

    def _common_ckpt_step(self) -> int:
        """Newest checkpointed step EVERY stage can serve (0 if none).
        A torn cut — a process killed between per-stage saves leaves
        stage dirs at different steps — is excluded by the intersection,
        never resumed at mixed versions."""
        if not self.scfg.ckpt_dir:
            return 0
        from repro.ckpt import available_steps, stage_dir
        common = None
        for s in range(self.n_stages):
            steps = set(available_steps(
                stage_dir(self.scfg.ckpt_dir, s)))
            common = steps if common is None else common & steps
        return max(common) if common else 0

    def _rollback_to(self, step_k: int):
        """A stage must resume from checkpoint step ``step_k`` < the
        pipeline's current step: rewind EVERY stage to it (Varuna-style
        global rollback), so the pipeline trains one consistent version.
        Rewinds the step counter, the data cursor, and the loss
        trajectory — the replayed steps consume the same sample indices
        fault-free training used after ``step_k``, so the final
        trajectory still matches the reference."""
        self._dispatch_paused = True
        # drain in-flight microbatches: their accumulations belong to
        # the aborted round (attempts against the stranded stage fail
        # once trainer retries exhaust)
        while self._inflight > 0 and not self.stopped:
            yield Sleep(0.1)
        if self.stopped:
            return
        for s in range(self.n_stages):
            group = [p for p in self._covering(s)
                     if p.executor is not None]
            if not group:
                continue
            # one disk read per stage, fanned out to all its peers:
            # explicitly the target step (not "latest"), so every stage
            # rewinds to the SAME consistent cut (0 = step-0 reference)
            snap = self._ckpt_snapshot(s, step=step_k)
            for p in group:
                p.executor.restore(p.state, snap, stage=s)
        self.metrics["rollbacks"].append((self.step, step_k))
        K = self.scfg.global_batch // max(self.scfg.microbatch_size, 1)
        self.step = step_k
        self._mb_counter = step_k * K
        # the loss list is relative to the step this RUNNER started at
        # (a cold-resumed runner begins with an empty list at step
        # _resume_step), so truncate by offset, not absolute step
        del self.metrics["loss"][max(step_k - self._resume_step, 0):]
        self._open_round()
        self._dispatch_paused = False

    def _restore_from_checkpoint(self, peer: Peer, stage: int,
                                 step: Optional[int] = None):
        """Stage died entirely (or a cold start): restore the persisted
        checkpoint (``step``; None = the latest; 0 = explicitly the
        step-0 reference params, bypassing the directory) through the
        peer's executor, falling back to the reference when nothing is
        saved."""
        if self._ref_params is None:         # timing-only: no state
            return
        peer.executor.restore(peer.state,
                              self._ckpt_snapshot(stage, step=step),
                              stage=stage)

    def _ckpt_snapshot(self, stage: int, step: Optional[int] = None):
        """Host snapshot tree for ``stage`` (see
        ``_restore_from_checkpoint`` for the ``step`` semantics)."""
        snap = {"params": self._ref_params[stage],
                "opt": self._ref_opt[stage], "version": 0}
        if self.scfg.ckpt_dir and step != 0:
            from repro.ckpt import (available_steps, restore_checkpoint,
                                    stage_dir)
            d = stage_dir(self.scfg.ckpt_dir, stage)
            try:
                snap, got = restore_checkpoint(d, like=snap, step=step)
                self.metrics["ckpt_restores"].append((stage, got))
            except FileNotFoundError:
                # only an EMPTY stage dir may fall back to the step-0
                # reference; a present-but-missing explicitly requested
                # step means the directory is inconsistent with its
                # siblings — restoring anything else would silently mix
                # parameter versions across stages
                if step is not None and available_steps(d):
                    raise RuntimeError(
                        f"checkpoint dir {d} has steps "
                        f"{available_steps(d)} but not the requested "
                        f"step {step} — stage dirs are inconsistent")
        return snap

    def _download_stage_state(self, peer: Peer, s: int):
        """Warm-state download of ONE stage: copy stage ``s``'s
        replicated state from a live covering neighbor (retrying if the
        donor dies mid-transfer), falling back to the checkpoint when
        the stage has no survivors.  Cross-span by construction: a span
        donor emits the single-stage snapshot for ``s``, whatever the
        receiving peer's own span is.  Returns with the stage installed
        — or early if the peer itself dies."""
        if not self.numeric:           # timing-only state transfer
            yield Sleep(1.0)
            return

        while True:
            donors = self._covering(s, but=peer)
            if not donors:
                yield Sleep(1.0)
                # same discipline as the donor path below: never adopt
                # (or get snapshotted serving stale state) inside an
                # All-Reduce window — the stage would re-checkpoint the
                # pre-step params under the post-step number
                while self._dispatch_paused and not self.stopped:
                    yield Sleep(0.05)
                if not peer.alive or self.stopped:
                    return
                if self._covering(s, but=peer):
                    continue           # a peer recovered during the wait
                if self._ref_params is None:
                    return
                # truly stranded: resume from the latest persisted
                # checkpoint.  If that checkpoint is older than the
                # pipeline's current step (ckpt_period > 1, or no
                # ckpt_dir at all), first rewind the WHOLE pipeline to
                # it (Varuna-style global rollback) — a lone stage must
                # never serve params from an older step than its
                # neighbors.
                k = self._common_ckpt_step()
                if k < self.step:
                    yield from self._rollback_to(k)
                if peer.alive:
                    self._restore_from_checkpoint(peer, s, step=k)
                return
            donor = donors[0]
            yield Sleep(peer.profile.recv_time(donor.state_nbytes(stage=s)))
            # adopt outside the All-Reduce window, or the joiner would
            # capture pre-step params while the stage steps past it
            while self._dispatch_paused and not self.stopped:
                yield Sleep(0.05)
            if not peer.alive:
                return
            if donor.alive and donor.serving and s in donor.stages:
                if peer.stages == donor.stages:
                    # same span: whole-state adoption (zero-copy when
                    # the two share an executor)
                    peer.adopt_state_from(donor)
                else:
                    peer.executor.restore(
                        peer.state,
                        donor.executor.snapshot(donor.state, stage=s),
                        stage=s)
                return

    def _download_state(self, peer: Peer, span: range):
        """Download every stage of ``span`` (possibly from different
        donors — a merging peer pulls each stage from whoever covers
        it)."""
        for s in span:
            yield from self._download_stage_state(peer, s)
            if not peer.alive or self.stopped:
                return

    def _complete_warm_join(self, peer: Peer, span: range):
        """Warm-join tail shared by migrations, joins, and span resizes:
        the state download completes BEFORE the peer is announced or
        entered into any wiring — a (re)joining peer must never serve
        stale params.  Returns False if the peer died mid-download."""
        peer.serving = False
        yield from self._download_state(peer, span)
        if not peer.alive:                     # preempted mid-download
            return False
        peer.serving = True
        self._announce(peer)
        for w in self.wirings:
            w.move_server(peer.id, [span.start])
        return True

    def _retire_assignment(self, peer: Peer):
        """Stop serving the current span, in exactly-once order: drain
        queued thunks (they must never execute against newly adopted
        state), release the ledger entries the peer's gradients backed
        (survivors recompute those indices), leave the DHT slots and
        wirings."""
        peer.serving = False
        peer.drain()
        lost = []
        for s in peer.stages:
            lost += [(s, i) for i in self.ledger.release_peer(s, peer.id)]
        self._log_releases(lost, peer.id)
        peer.state.zero_grads()                # grads die with the move
        self._dht_forget(peer)
        for w in self.wirings:
            w.ban_server(peer.id)

    def _migrate(self, peer: Peer, dst: "int | range"):
        """Stage switch, in exactly-once order: stop serving, drain the
        queued src-stage thunks, release the ledger entries, download
        the dst state — and only then re-announce and re-enter
        wirings."""
        dst_span = _as_span(dst)
        # never yank accumulated grads out of an in-progress All-Reduce
        while self._dispatch_paused and not self.stopped:
            yield Sleep(0.05)
        if self.stopped or not peer.alive or not peer.serving:
            return
        # re-check after the deferral: the plan was made from an older
        # snapshot, and leaving must neither strand any source stage nor
        # break the span layout's routability
        if not all(self._covering(s, but=peer) for s in peer.stages) \
                or not self._routes_without(peer, dst_span):
            return
        self._retire_assignment(peer)
        peer.executor = self._rebacked_executor(peer, dst_span)
        peer.set_span(dst_span)
        peer.state = peer._fresh_state()
        ok = yield from self._complete_warm_join(peer, dst_span)
        if ok:
            self.metrics["migrations"] += 1

    def _resize_span(self, peer: Peer, new_span: range):
        """Shrink or grow a serving peer's span in place (Varuna-style
        re-partitioning; how spans split into single-stage peers and
        merge back).  Exactly-once order mirrors ``_migrate``: drain +
        release first, THEN swap the executor and state.  Stages kept
        across the resize keep their params locally (an on-device
        snapshot/restore, no transfer time); newly covered stages
        warm-download from whoever covers them.  Refuses when dropping
        a stage would strand it."""
        while self._dispatch_paused and not self.stopped:
            yield Sleep(0.05)
        if self.stopped or not peer.alive or not peer.serving:
            return False
        old_span = peer.stages
        if new_span == old_span:
            return False
        dropped = [s for s in old_span if s not in new_span]
        if not all(self._covering(s, but=peer) for s in dropped):
            return False                       # would strand a stage
        if not self._routes_without(peer, new_span):
            return False                       # coverage != routability
        kept = [s for s in new_span if s in old_span]
        keep_snaps = {}
        if peer.executor is not None:
            for s in kept:
                keep_snaps[s] = peer.executor.snapshot(peer.state, stage=s)
        self._retire_assignment(peer)
        peer.executor = self._rebacked_executor(peer, new_span)
        peer.set_span(new_span)
        peer.state = peer._fresh_state()
        for s, snap in keep_snaps.items():
            peer.executor.restore(peer.state, snap, stage=s)
        peer.serving = False
        for s in new_span:
            if s not in kept:
                yield from self._download_stage_state(peer, s)
                if not peer.alive or self.stopped:
                    return False
        peer.serving = True
        self._announce(peer)
        for w in self.wirings:
            w.move_server(peer.id, [new_span.start])
        self.metrics["span_changes"] += 1
        return True

    def split_span(self, peer: Peer, at: int):
        """Split ``peer``'s span ``[lo, hi)`` at ``at``: a fresh (or
        revived) peer warm-joins on ``[at, hi)`` — downloading those
        stages from the splitting peer, which still serves them — and
        only then does the donor shrink to ``[lo, at)``.  Coverage never
        gaps; the dying-span-peer path needs no choreography at all
        (per-stage snapshots already interoperate, see
        ``_download_stage_state``)."""
        lo, hi = peer.stages.start, peer.stages.stop
        if not (lo < at < hi):
            raise ValueError(f"split point {at} outside ({lo}, {hi})")
        yield from self._join_new_peer(span=range(at, hi))
        yield from self._resize_span(peer, range(lo, at))

    def merge_spans(self, peer: Peer, new_span: range):
        """Grow ``peer`` to ``new_span`` (absorbing adjacent stages it
        downloads from their current holders) — the inverse of
        ``split_span``."""
        yield from self._resize_span(peer, new_span)

    # ================================================== fault injection
    def apply_trace(self, trace: list[TraceEvent]):
        self.sim.spawn(self._trace_proc(trace))

    def _trace_proc(self, trace: list[TraceEvent]):
        for ev in trace:
            dt = ev.time - self.sim.now
            if dt > 0:
                yield Sleep(dt)
            if self.stopped:
                return
            if ev.delta < 0:
                for _ in range(-ev.delta):
                    self._fail_random_peer(region=ev.region)
            else:
                for _ in range(ev.delta):
                    yield from self._join_new_peer(region=ev.region)

    def _fail_random_peer(self, region: Optional[str] = None):
        live = [p for p in self.peers.values() if p.alive]

        def covered(p: Peer) -> bool:
            return all(any(q.serving and s in q.stages
                           for q in live if q is not p)
                       for s in p.stages)
        # never strand a stage: a serving peer may die only if every
        # stage it covers is served by someone else AND the remaining
        # span layout still routes (a span can be the only bridge at a
        # boundary even when all its stages stay covered); a
        # mid-download peer may die only if its target stages are still
        # served
        candidates = [p for p in live
                      if covered(p) and self._routes_without(p, None)]
        if region is not None:
            # zone-correlated reclaim: the event only takes capacity
            # from its zone — out-of-zone peers are never substituted
            candidates = [p for p in candidates
                          if getattr(p, "region", "local") == region]
        if not candidates:
            return
        self._fail_peer(candidates[self.rng.integers(len(candidates))])

    def _fail_peer(self, victim: Peer):
        """Preempt ``victim`` NOW (no stage-coverage guard — callers that
        must not strand a stage check first, e.g. ``_fail_random_peer``;
        stranding a stage is legal and exercises the checkpoint
        fallback)."""
        victim.fail()
        self.metrics["failures"] += 1
        # the victim's accumulated gradients die with it: survivors
        # recompute exactly the indices it held (App. A)
        self._log_releases(self.ledger.release_all(victim.id), victim.id)
        for w in self.wirings:
            w.ban_server(victim.id)
        self._dht_forget(victim)

    def _join_new_peer(self, span: Optional[range] = None,
                       region: Optional[str] = None):
        if span is None:
            # new peers join the most loaded stage (§3.2 "assigned to the
            # optimal pipeline stage by following the same protocol")
            loads = []
            for s in range(self.n_stages):
                group = self._covering(s)
                q = sum(p.queue_size() for p in group)
                loads.append((q + 1) / max(len(group), 1e-9))
            span = _as_span(int(np.argmax(loads)))
        # preemptible instances coming back reuse their peer object (a
        # revived mesh slice can now serve any span: MeshExecutor
        # .for_span(width > 1) builds a MeshSpanExecutor)
        dead = [p for p in self.peers.values() if not p.alive]
        if dead:
            peer = dead[0]
            # a revived peer keeps its backend (a mesh slice coming back
            # IS that mesh slice), re-targeted at the join span
            peer.executor = (self._rebacked_executor(peer, span)
                             if peer.executor is not None
                             else self._span_executor(span))
            if region is not None:
                peer.region = region      # fresh capacity in the
                # event's zone: the revived object is a new instance
            peer.revive(span)
        else:
            peer = Peer(self.sim, self.profile_fn(len(self.peers)), span,
                        executor=self._span_executor(span),
                        region=(region if region is not None
                                else self.region_fn(len(self.peers))))
            self.peers[peer.id] = peer
        self.metrics["joins"] += 1
        ok = yield from self._complete_warm_join(peer, span)
        if ok:
            self.sim.spawn(self._announcer(peer))

    # ================================================== run
    def run(self, until: Optional[float] = None,
            max_steps: Optional[int] = None):
        if max_steps is not None:
            self.scfg = dataclasses.replace(self.scfg, max_steps=max_steps)
            # _sync_loop reads scfg.max_steps each iteration via self.scfg
        self.sim.run(until=until)
        self.stopped = True
        # derived async-tick metrics (all-zero / empty-ratio in sync
        # runs): per-peer executor idle time and how much of the serial
        # wire cost the in-flight transfers hid.  Idle intervals close
        # at the instant training STOPPED, not at `until` — a max_steps
        # run drains the virtual clock to the horizon afterwards, and
        # that dead time is not executor idleness.
        t_end = min(self.sim.now, self._t_stopped
                    if self._t_stopped is not None else self.sim.now)
        m = self.metrics
        m["peer_idle_s"] = {pid: p.total_idle(t_end)
                            for pid, p in self.peers.items()}
        # clamp: an all-span swarm has no peer-to-peer edge to hide, so
        # inflight == serial up to float noise — report 0, not -1e-15
        m["overlap_fraction"] = max(0.0, (
            1.0 - m["wire_inflight_s"] / m["wire_serial_s"]
            if m["wire_serial_s"] > 0 else 0.0))
        return self.metrics

    def throughput(self, window: float = None) -> float:
        """Samples/s over the run (optionally trailing window)."""
        ts, vs = (self.metrics["throughput_t"],
                  self.metrics["throughput_v"])
        if len(ts) < 2:
            return 0.0
        if window:
            import bisect
            lo = bisect.bisect_left(ts, ts[-1] - window)
            lo = min(lo, len(ts) - 2)
            return (vs[-1] - vs[lo]) / max(ts[-1] - ts[lo], 1e-9)
        return vs[-1] / max(ts[-1], 1e-9)
