"""SwarmRunner — the full SWARM parallelism system on the virtual clock.

Composition (paper Fig. 2): consecutive swarms of peers serve pipeline
stages; trainer processes route microbatches via stochastic wiring; a DHT
carries liveness + load; adaptive rebalancing migrates peers between
stages; once the global batch is accumulated, every stage All-Reduces its
gradients and applies the (optionally delayed, DPU) optimizer step.

Two modes:
  numeric=True   — real JAX math per stage (convergence experiments,
                   equivalence tests; Fig. 4 / App. E analogues).
  numeric=False  — timing only (Tables 2-5, Figs. 5-7 analogues: 400-peer,
                   32-hour traces run in seconds of wall time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import codecs
from repro.core.sim import Sim, Sleep, Spawn
from repro.core.dht import DHT
from repro.core.peer import Peer, DeviceProfile, PeerFailure, T4
from repro.core.wiring import StochasticWiring
from repro.core.trainer import Trainer, Microbatch
from repro.core import rebalance as rb
from repro.core.faults import TraceEvent
from repro.core.stage_model import StageProgram, build_stage_programs, \
    init_stage_params
from repro.models.config import ArchConfig
from repro.models import flops as F
from repro.optim.adamw import Optimizer

Tree = Any


@dataclasses.dataclass
class SwarmConfig:
    n_stages: int = 3
    microbatch_size: int = 1
    seq_len: int = 128
    global_batch: int = 8                # sequences per optimizer step
    n_trainers: int = 4
    rebalance_period: float = 300.0      # T (paper §4.3)
    announce_interval: float = 120.0
    announce_ttl: float = 300.0
    wiring_gamma: float = 0.1            # EMA alpha (paper §4.3)
    # boundary compression: False -> "none", True -> "int8" (back-compat
    # booleans), or an explicit mode string incl. the learned codecs
    # ("none" | "int8" | "bottleneck" | "maxout", paper App. J)
    compress: bool | str = True
    quant_block: int = 64
    dpu: bool = False
    max_steps: Optional[int] = None
    allreduce_bw: float = 50e6           # bytes/s effective per peer


class SwarmRunner:
    def __init__(self, cfg: ArchConfig, scfg: SwarmConfig,
                 optimizer: Optimizer, *, numeric: bool = True,
                 seed: int = 0,
                 profile_fn: Optional[Callable[[int], DeviceProfile]] = None,
                 data_fn: Optional[Callable[[int], dict]] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.optimizer = optimizer
        self.numeric = numeric
        self.sim = Sim()
        self.dht = DHT(lambda: self.sim.now)
        self.n_stages = scfg.n_stages
        self.compress = scfg.compress
        if isinstance(scfg.compress, bool):
            self.compress_mode = "int8" if scfg.compress else "none"
        else:
            self.compress_mode = codecs.resolve_mode(cfg, scfg.compress)
        self.quant_block = scfg.quant_block
        self.rng = np.random.default_rng(seed)
        self.profile_fn = profile_fn or (lambda i: T4)
        self.data_fn = data_fn

        self.programs: list[StageProgram] = build_stage_programs(
            cfg, scfg.n_stages, scfg.seq_len,
            compress=self.compress_mode) if numeric else \
            [None] * scfg.n_stages
        self._ref_params: Optional[list[Tree]] = None
        if numeric:
            self._ref_params = init_stage_params(
                self.programs, jax.random.PRNGKey(seed))
            self._ref_opt = [optimizer.init(p) for p in self._ref_params]

        self.peers: dict[str, Peer] = {}
        self.wirings: list[StochasticWiring] = []
        self.trainers: list[Trainer] = []

        # training progress
        self.stopped = False
        self._mb_counter = 0
        self._inflight = 0
        self._dispatch_paused = False
        self._round_dispatched = 0           # samples handed out this round
        self.step = 0
        self.metrics: dict[str, list] = {
            "loss": [], "step_time": [], "samples_done": [],
            "throughput_t": [], "throughput_v": [], "migrations": 0,
            "failures": 0, "joins": 0, "recomputed_microbatches": 0,
        }
        self._samples_done_total = 0
        self._flops_per_sample_total = 0.0

    # ================================================== setup
    def add_peer(self, stage: int, profile: Optional[DeviceProfile] = None
                 ) -> Peer:
        peer = Peer(self.sim, profile or self.profile_fn(len(self.peers)),
                    stage)
        self.peers[peer.id] = peer
        if self.numeric:
            peer.state.params = jax.tree.map(lambda x: x,
                                             self._ref_params[stage])
            peer.state.opt = jax.tree.map(lambda x: x, self._ref_opt[stage])
            peer.state.grad_acc = jax.tree.map(jnp.zeros_like,
                                               peer.state.params)
        self._announce(peer)
        for w in self.wirings:
            w.add_server(peer.id, [stage])
        self.sim.spawn(self._announcer(peer))
        return peer

    def build(self, peers_per_stage: int | list[int]):
        if isinstance(peers_per_stage, int):
            peers_per_stage = [peers_per_stage] * self.n_stages
        for s, n in enumerate(peers_per_stage):
            for _ in range(n):
                self.add_peer(s)
        for i in range(self.scfg.n_trainers):
            w = StochasticWiring(self.n_stages,
                                 gamma=self.scfg.wiring_gamma,
                                 seed=1000 + i)
            for pid, p in self.peers.items():
                if p.alive:
                    w.add_server(pid, [p.stage])
            self.wirings.append(w)
            t = Trainer(self.sim, self, w, f"trainer{i}")
            self.trainers.append(t)
            self.sim.spawn(t.run())
        self.sim.spawn(self._sync_loop())
        if self.scfg.rebalance_period > 0:
            self.sim.spawn(self._rebalance_loop())

    # ================================================== DHT liveness
    def _announce(self, peer: Peer):
        self.dht.store(self.dht.stage_key(peer.stage), peer.id, peer.stage,
                       self.scfg.announce_ttl)

    def _announcer(self, peer: Peer):
        while peer.alive and not self.stopped:
            self._announce(peer)
            yield Sleep(self.scfg.announce_interval)

    def announced_stages(self) -> dict[str, int]:
        out = {}
        for s in range(self.n_stages):
            for pid, rec in self.dht.get(self.dht.stage_key(s)).items():
                peer = self.peers.get(pid)
                if peer is not None and peer.alive and peer.stage == s:
                    out[pid] = s
        return out

    # ================================================== data / dispatch
    def next_microbatch(self) -> Optional[Microbatch]:
        """Hand out work while the current round's global batch is short —
        SWARM accumulates *exactly* ``global_batch`` samples per optimizer
        step (App. E: synchronous semantics), re-issuing samples lost to
        dead peers."""
        if self.stopped or self._dispatch_paused:
            return None
        if self._round_dispatched + self.scfg.microbatch_size \
                > self.scfg.global_batch:
            return None
        self._round_dispatched += self.scfg.microbatch_size
        idx = self._mb_counter
        self._mb_counter += 1
        self._inflight += 1
        b, S = self.scfg.microbatch_size, self.scfg.seq_len
        mb = Microbatch(index=idx, size=b, n_tokens=b * S)
        if self.numeric:
            batch = (self.data_fn(idx) if self.data_fn else
                     self._default_data(idx))
            mb.tokens, mb.labels = batch["tokens"], batch["labels"]
        return mb

    def _default_data(self, idx: int) -> dict:
        from repro.data.synthetic import SyntheticLM
        ds = SyntheticLM(self.cfg.vocab_size, self.scfg.seq_len,
                         self.scfg.microbatch_size, seed=17)
        return ds.batch(idx)

    def microbatch_done(self, mb: Microbatch, ok: bool):
        self._inflight -= 1
        if ok:
            self._samples_done_total += mb.size
            self.metrics["throughput_t"].append(self.sim.now)
            self.metrics["throughput_v"].append(self._samples_done_total)
        else:
            # the microbatch never landed anywhere: free its budget so a
            # replacement sample is dispatched (App. A)
            self._round_dispatched -= mb.size

    # ================================================== cost model
    def compute_time(self, peer: Peer, kind: str, stage: int,
                     mb: Microbatch) -> float:
        prog = self.programs[stage]
        if prog is not None:
            fpt = (prog.fwd_flops_per_token if kind == "fwd"
                   else prog.bwd_flops_per_token)
        else:
            ctx = F._ctx_for(self.cfg, self.scfg.seq_len, causal_avg=True)
            per = self.cfg.n_layers // self.n_stages
            kinds = self.cfg.block_kinds[stage * per:(stage + 1) * per]
            fpt = sum(F.per_token_layer_flops(self.cfg, k, ctx)
                      for k in kinds)
            if stage == self.n_stages - 1:
                fpt += 2 * self.cfg.d_model * self.cfg.vocab_size
            if kind == "bwd":
                fpt *= 3.0
        return peer.profile.compute_time(fpt * mb.n_tokens)

    def boundary_nbytes(self, mb: Microbatch) -> float:
        # one mode string end-to-end: the sim charges exactly the bytes the
        # active codec puts on the wire (flops.boundary_bytes is the same
        # formula bench_compression measures against the real tensors)
        return F.boundary_bytes(
            self.cfg, mb.size, self.scfg.seq_len, self.compress_mode)

    # ================================================== gradient sync
    def _stage_samples(self, s: int) -> int:
        return sum(p.state.sample_count for p in self.peers.values()
                   if p.alive and p.stage == s)

    def accumulate(self, peer: Peer, gp: Optional[Tree], mb: Microbatch,
                   loss: Optional[float]):
        st = peer.state
        if gp is not None:
            st.grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), st.grad_acc, gp)
        st.sample_count += mb.size
        st.token_count += mb.n_tokens
        if loss is not None:
            st.loss_sum += loss

    def _sync_loop(self):
        """Trigger All-Reduce + optimizer step when global batch reached."""
        gb = self.scfg.global_batch
        while not self.stopped:
            short = min(self._stage_samples(s)
                        for s in range(self.n_stages))
            if short < gb:
                # App. A: samples whose gradients died with a failed peer
                # must be recomputed by survivors — when the dispatch
                # budget is spent and nothing is in flight, re-open it
                if self._inflight == 0 and self._round_dispatched >= gb:
                    self.metrics["recomputed_microbatches"] += \
                        (gb - short) // max(self.scfg.microbatch_size, 1)
                    self._round_dispatched = short
                yield Sleep(0.2)
                continue
            # barrier: stop dispatch, drain in-flight microbatches
            self._dispatch_paused = True
            while self._inflight > 0:
                yield Sleep(0.1)
            # lost-gradient check (App. A): a stage may have lost samples
            # with dead peers — survivors recompute (dispatch resumes below)
            short = min(self._stage_samples(s) for s in range(self.n_stages))
            if short < gb:
                self.metrics["recomputed_microbatches"] += (gb - short) \
                    // max(self.scfg.microbatch_size, 1)
                self._round_dispatched = short
                self._dispatch_paused = False
                continue
            t0 = self.sim.now
            yield from self._all_reduce_and_step()
            self.metrics["step_time"].append(self.sim.now - t0)
            self._round_dispatched = 0
            self._dispatch_paused = False
            if (self.scfg.max_steps is not None
                    and self.step >= self.scfg.max_steps):
                self.stopped = True

    def _all_reduce_and_step(self):
        """Per-stage ring All-Reduce (time) + optimizer step (numerics)."""
        for s in range(self.n_stages):
            group = [p for p in self.peers.values()
                     if p.alive and p.stage == s]
            if not group:
                continue
            k = len(group)
            nbytes = group[0].state_nbytes() / 3.0   # grads only
            if nbytes == 0.0:                        # throughput mode
                nbytes = 2.0 * F.total_params(self.cfg) / self.n_stages
            ar_time = (2 * (k - 1) / max(k, 1)) * nbytes \
                / self.scfg.allreduce_bw + 0.01 * k
            yield Sleep(ar_time)
            if not self.numeric:
                for p in group:
                    p.state.zero_grads() if p.state.grad_acc is not None \
                        else None
                    p.state.sample_count = 0
                continue
            # average gradients over the stage (token-weighted sum / tokens)
            total_tokens = sum(p.state.token_count for p in group)
            gsum = group[0].state.grad_acc
            for p in group[1:]:
                gsum = jax.tree.map(lambda a, b: a + b, gsum,
                                    p.state.grad_acc)
            gmean = jax.tree.map(lambda g: g / max(total_tokens, 1), gsum)
            params, opt = group[0].state.params, group[0].state.opt
            updates, opt = self.optimizer.update(gmean, opt, params)
            params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
            loss_sum = sum(p.state.loss_sum for p in group)
            if s == self.n_stages - 1 and total_tokens:
                self.metrics["loss"].append(loss_sum / total_tokens)
            for p in group:
                p.state.params = params
                p.state.opt = opt
                p.state.version += 1
                p.state.zero_grads()
        self.step += 1

    # ================================================== rebalancing
    def _rebalance_loop(self):
        T = self.scfg.rebalance_period
        while not self.stopped:
            yield Sleep(T)
            # peers report queue sizes (Alg. 2 line 4)
            for p in self.peers.values():
                if p.alive:
                    self.dht.store(self.dht.load_key(p.stage), p.id,
                                   p.queue_size() + 1e-3, T * 1.5)
            pps = {s: [p.id for p in self.peers.values()
                       if p.alive and p.stage == s]
                   for s in range(self.n_stages)}
            mig = rb.plan_migration(self.dht, self.n_stages, pps)
            if mig is None:
                continue
            yield from self._migrate(self.peers[mig.peer], mig.dst_stage)

    def _migrate(self, peer: Peer, dst: int):
        """Stage switch: stop serving, download state, re-announce."""
        donors = [p for p in self.peers.values()
                  if p.alive and p.stage == dst and p is not peer]
        src = peer.stage
        peer.stage = dst                       # stops accepting src work
        if donors and self.numeric:
            donor = donors[0]
            yield Sleep(peer.profile.recv_time(donor.state_nbytes()))
            peer.adopt_state_from(donor)
        else:
            yield Sleep(1.0)
            if self.numeric and self._ref_params is not None and not donors:
                # stage died entirely: restore from checkpointed reference
                peer.state.params = jax.tree.map(
                    lambda x: x, self._ref_params[dst])
                peer.state.opt = jax.tree.map(lambda x: x,
                                              self._ref_opt[dst])
                peer.state.grad_acc = jax.tree.map(
                    jnp.zeros_like, peer.state.params)
        self._announce(peer)
        self.dht.delete(self.dht.load_key(src), peer.id)
        for w in self.wirings:
            w.move_server(peer.id, [dst])
        self.metrics["migrations"] += 1

    # ================================================== fault injection
    def apply_trace(self, trace: list[TraceEvent]):
        self.sim.spawn(self._trace_proc(trace))

    def _trace_proc(self, trace: list[TraceEvent]):
        for ev in trace:
            dt = ev.time - self.sim.now
            if dt > 0:
                yield Sleep(dt)
            if self.stopped:
                return
            if ev.delta < 0:
                for _ in range(-ev.delta):
                    self._fail_random_peer()
            else:
                for _ in range(ev.delta):
                    yield from self._join_new_peer()

    def _fail_random_peer(self):
        live = [p for p in self.peers.values() if p.alive]
        candidates = [p for p in live
                      if sum(1 for q in live
                             if q.stage == p.stage and q.alive) > 1]
        if not candidates:
            return
        victim = candidates[self.rng.integers(len(candidates))]
        victim.fail()
        self.metrics["failures"] += 1
        for w in self.wirings:
            w.ban_server(victim.id)
        self.dht.delete(self.dht.stage_key(victim.stage), victim.id)
        self.dht.delete(self.dht.load_key(victim.stage), victim.id)

    def _join_new_peer(self):
        # new peers join the most loaded stage (§3.2 "assigned to the
        # optimal pipeline stage by following the same protocol")
        loads = []
        for s in range(self.n_stages):
            group = [p for p in self.peers.values()
                     if p.alive and p.stage == s]
            q = sum(p.queue_size() for p in group)
            loads.append((q + 1) / max(len(group), 1e-9))
        dst = int(np.argmax(loads))
        peer = self.add_peer(dst)
        self.metrics["joins"] += 1
        if self.numeric:
            donors = [p for p in self.peers.values()
                      if p.alive and p.stage == dst and p is not peer]
            if donors:
                yield Sleep(peer.profile.recv_time(donors[0].state_nbytes()))
                peer.adopt_state_from(donors[0])

    # ================================================== run
    def run(self, until: Optional[float] = None,
            max_steps: Optional[int] = None):
        if max_steps is not None:
            self.scfg = dataclasses.replace(self.scfg, max_steps=max_steps)
            # _sync_loop reads scfg.max_steps each iteration via self.scfg
        self.sim.run(until=until)
        self.stopped = True
        return self.metrics

    def throughput(self, window: float = None) -> float:
        """Samples/s over the run (optionally trailing window)."""
        ts, vs = (self.metrics["throughput_t"],
                  self.metrics["throughput_v"])
        if len(ts) < 2:
            return 0.0
        if window:
            import bisect
            lo = bisect.bisect_left(ts, ts[-1] - window)
            lo = min(lo, len(ts) - 2)
            return (vs[-1] - vs[lo]) / max(ts[-1] - ts[lo], 1e-9)
        return vs[-1] / max(ts[-1], 1e-9)
