"""Preemption traces for fault-tolerance experiments.

The paper replays the number of active T4 nodes over a 32-hour segment of
its §4.3 run (App. I).  That raw trace is not published, so we generate
statistically similar traces: spot-instance lifetimes are approximately
exponential with mean of a few hours, arrivals Poisson with the pool
drifting around a capacity target (plus occasional mass-preemption events,
which is what produces the 'large drops' App. I describes).  Traces are a
list of (time_s, delta_peers) events, deterministic given the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    time: float
    delta: int            # +k join, -k leave
    #: cloud zone the event hits (None = region-agnostic, the historical
    #: form).  Mass preemptions carry ONE region — spot reclaims are
    #: zone-correlated, the capacity crunch empties a zone, not the fleet.
    region: Optional[str] = None


def synth_preemptible_trace(
    horizon_s: float = 32 * 3600.0,
    target_peers: int = 400,
    mean_lifetime_s: float = 6 * 3600.0,
    mass_preemption_rate_per_h: float = 0.15,
    mass_fraction: float = 0.12,
    seed: int = 0,
    regions: Optional[Sequence[str]] = None,
) -> list[TraceEvent]:
    """``regions`` tags every event with a drawn zone (mass events hit a
    single zone).  The extra draws happen ONLY when regions are
    requested, so region-less traces stay byte-identical to the
    historical rng stream for every seed."""
    rng = np.random.default_rng(seed)

    def _region() -> Optional[str]:
        if regions is None:
            return None
        return str(regions[int(rng.integers(len(regions)))])
    events: list[TraceEvent] = []
    n = target_peers
    t = 0.0
    # per-peer hazard -> pool-level departure rate n/mean_lifetime;
    # arrivals replenish toward target with rate prop. to deficit + churn.
    while t < horizon_s:
        leave_rate = n / mean_lifetime_s
        join_rate = max(target_peers - n, 0) / 600.0 + 0.3 * leave_rate
        mass_rate = mass_preemption_rate_per_h / 3600.0
        total = leave_rate + join_rate + mass_rate
        t += rng.exponential(1.0 / total)
        if t >= horizon_s:
            break
        u = rng.uniform() * total
        if u < leave_rate and n > 1:
            events.append(TraceEvent(t, -1, _region()))
            n -= 1
        elif u < leave_rate + join_rate:
            events.append(TraceEvent(t, +1, _region()))
            n += 1
        elif n > 4:
            k = max(1, int(n * mass_fraction * rng.uniform(0.5, 1.5)))
            k = min(k, n - 1)
            events.append(TraceEvent(t, -k, _region()))
            n -= k
    return events


def constant_pool(n_peers: int, horizon_s: float) -> list[TraceEvent]:
    del n_peers, horizon_s
    return []


def active_counts(trace: list[TraceEvent], n0: int,
                  horizon_s: float, dt: float = 60.0) -> np.ndarray:
    """Sampled active-peer counts (for plotting / Table 5 style summaries)."""
    ts = np.arange(0.0, horizon_s, dt)
    out = np.zeros(len(ts), np.int64)
    n, i = n0, 0
    for j, t in enumerate(ts):
        while i < len(trace) and trace[i].time <= t:
            n += trace[i].delta
            i += 1
        out[j] = n
    return out
