"""Discrete-event simulation kernel (SimPy-lite, generator coroutines).

The SWARM runner simulates hundreds of heterogeneous preemptible peers on a
virtual clock; real JAX math (numeric mode) executes instantly in wall time
while *virtual* time advances by the device cost model.  Processes are
generators that ``yield`` commands:

    yield Sleep(dt)          — advance virtual time
    yield ev.wait()          — block until Event.fire()
    yield res.acquire()      — exclusive resource (a GPU, a link); pair with
    res.release()
    yield Spawn(gen)         — start a child process
    yield link.transfer(dur, nbytes)
                             — await an in-flight transfer: the bytes
                             occupy the LINK for ``dur``, not the
                             issuing process or any compute queue

A fired :class:`Event` may carry a value or an exception (peer failures
propagate into whoever awaits them — that is how trainers observe faults).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Generator, Optional


@dataclasses.dataclass
class Sleep:
    dt: float


@dataclasses.dataclass
class Spawn:
    gen: Generator


class Interrupt(Exception):
    """Raised inside a process that awaited a failed peer/event."""


class Event:
    __slots__ = ("sim", "fired", "value", "exc", "_waiters")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.fired = False
        self.value = None
        self.exc: Optional[BaseException] = None
        self._waiters: list[Generator] = []

    def wait(self) -> "Event":
        return self

    def fire(self, value: Any = None) -> None:
        if self.fired:
            return
        self.fired = True
        self.value = value
        for g in self._waiters:
            self.sim._schedule(0.0, g, value=value)
        self._waiters.clear()

    def fail(self, exc: BaseException) -> None:
        if self.fired:
            return
        self.fired = True
        self.exc = exc
        for g in self._waiters:
            self.sim._schedule(0.0, g, exc=exc)
        self._waiters.clear()


class Resource:
    """FIFO exclusive resource (e.g. one GPU executor, one uplink)."""

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.busy = False
        self._queue: list[Event] = []

    def acquire(self) -> Event:
        ev = Event(self.sim)
        if not self.busy:
            self.busy = True
            ev.fire()
        else:
            self._queue.append(ev)
        return ev

    def release(self) -> None:
        if self._queue:
            self._queue.pop(0).fire()
        else:
            self.busy = False


class Link:
    """In-flight transfer primitive: one direction of a peer's NIC.

    A ``transfer`` occupies the LINK for its duration — never the
    issuing process or a compute queue — so boundary tensors ride the
    wire while the peer computes the next microbatch (the async tick's
    overlap lever).  Transfers serialize FIFO on the link's bandwidth:
    a transfer issued while another is on the wire starts when the link
    frees up.  The returned :class:`Event` fires when the bytes have
    landed; callers that need the payload await it, callers that only
    produce it keep going.
    """

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self._free_at = 0.0          # virtual time the link drains
        self.busy_time = 0.0         # cumulative occupied seconds
        self.bytes_total = 0.0       # cumulative bytes put on the wire
        self.inflight = 0            # transfers currently on the wire

    def transfer(self, duration: float, nbytes: float = 0.0) -> Event:
        ev = Event(self.sim)
        begin = max(self.sim.now, self._free_at)
        end = begin + duration
        self._free_at = end
        self.busy_time += duration
        self.bytes_total += nbytes
        self.inflight += 1
        self.sim.spawn(self._complete(ev, end - self.sim.now))
        return ev

    def occupy(self, duration: float, nbytes: float = 0.0) -> None:
        """Account occupancy without a completion event — the far side
        of a point-to-point transfer (the receiving link owns the
        event; the sending link is just busy for the window)."""
        begin = max(self.sim.now, self._free_at)
        self._free_at = begin + duration
        self.busy_time += duration
        self.bytes_total += nbytes

    def _complete(self, ev: Event, dt: float):
        yield Sleep(dt)
        self.inflight -= 1
        ev.fire()


class Sim:
    def __init__(self):
        self.now = 0.0
        self._heap: list = []
        self._ctr = itertools.count()
        self.live_processes = 0

    # -------------------------------------------------------- scheduling
    def _schedule(self, dt: float, gen: Generator, value: Any = None,
                  exc: Optional[BaseException] = None) -> None:
        heapq.heappush(self._heap,
                       (self.now + dt, next(self._ctr), gen, value, exc))

    def spawn(self, gen: Generator) -> None:
        self.live_processes += 1
        self._schedule(0.0, gen)

    def event(self) -> Event:
        return Event(self)

    def resource(self) -> Resource:
        return Resource(self)

    def link(self) -> Link:
        return Link(self)

    # -------------------------------------------------------- stepping
    def _step_process(self, gen: Generator, value: Any,
                      exc: Optional[BaseException]) -> None:
        try:
            cmd = gen.throw(exc) if exc is not None else gen.send(value)
        except StopIteration:
            self.live_processes -= 1
            return
        except Interrupt:
            self.live_processes -= 1
            return
        if isinstance(cmd, Sleep):
            self._schedule(cmd.dt, gen)
        elif isinstance(cmd, Event):
            if cmd.fired:
                self._schedule(0.0, gen, value=cmd.value, exc=cmd.exc)
            else:
                cmd._waiters.append(gen)
        elif isinstance(cmd, Spawn):
            self.spawn(cmd.gen)
            self._schedule(0.0, gen)
        else:
            raise TypeError(f"process yielded {cmd!r}")

    def run(self, until: Optional[float] = None) -> float:
        while self._heap:
            t, _, gen, value, exc = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return self.now
            heapq.heappop(self._heap)
            self.now = t
            self._step_process(gen, value, exc)
        if until is not None:
            self.now = until
        return self.now
