"""The square-cube law of distributed training (paper §3.1, Fig. 1/3,
Table 1).

Per pipeline stage: compute grows ~O(n^3) with the hidden dimension (matmul)
while the boundary transfer grows ~O(n^2) (activations) — so GPU utilization
``t_compute / (t_compute + t_exposed_comm)`` rises with model size at fixed
bandwidth.  SWARM additionally overlaps communication with queued
microbatches; ``overlap`` interpolates between fully-serial (0) and
fully-overlapped (1) communication.

The efficiency curve models the empirical fact (paper App. F, Table 6
timings) that small matmuls underutilize the GPU: eff rises from ~8% for
d=768 toward ~45% for d=12288 on V100-class parts running unfused fp16
PyTorch blocks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.models.config import ArchConfig
from repro.models import flops as F

MBPS = 125_000.0


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One benchmark configuration of §4.1 / App. F."""
    name: str
    d_model: int
    d_ff: int
    n_heads: int
    layers_per_stage: int = 1
    quantize8: bool = False


# The four configurations of §4.1 (App. F).
BASE = LayerSpec("base", 768, 3072, 12)
XXLARGE = LayerSpec("xxlarge", 4096, 16384, 32)
GPT3 = LayerSpec("GPT-3", 12288, 49152, 96)
OURS = LayerSpec("Ours", 4096, 16384, 32, layers_per_stage=3, quantize8=True)
ALL_SPECS = [BASE, XXLARGE, GPT3, OURS]


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One inter-region (or intra-region) link class: the §4.3
    deployment spans preemptible zones whose pairwise bandwidth/latency
    differ by an order of magnitude, so boundary pricing must be a
    function of the REGION PAIR, not one fleet-wide constant."""
    a: str
    b: str
    bandwidth_mbps: float
    latency_s: float

    def transfer_time(self, nbytes: float) -> float:
        return self.latency_s + nbytes / (self.bandwidth_mbps * MBPS)


class LinkTable:
    """Symmetric region-pair -> :class:`LinkSpec` lookup.

    Unlisted pairs fall back to ``intra_default`` (same region) or
    ``cross_default`` (different regions) so a partial table still
    prices every edge.  ``edge_costs`` is the planner entry point: it
    turns per-boundary byte counts plus a per-stage region vector into
    per-boundary *seconds*, which feed ``optimal_assignment`` /
    ``plan_span_change`` as ``boundary_cost`` — an edge between stages
    homed in regions linked by a slow WAN pair prices high, so the span
    planners fuse across slow links first (the region-aware placement
    of ISSUE 10)."""

    def __init__(self, specs: "list[LinkSpec] | None" = None, *,
                 intra_default: Optional[LinkSpec] = None,
                 cross_default: Optional[LinkSpec] = None):
        self._by_pair: dict[frozenset, LinkSpec] = {}
        for sp in specs or []:
            self._by_pair[frozenset((sp.a, sp.b))] = sp
        self.intra_default = intra_default or LinkSpec(
            "*", "*", bandwidth_mbps=800.0, latency_s=0.002)
        self.cross_default = cross_default or LinkSpec(
            "*", "*", bandwidth_mbps=100.0, latency_s=0.045)

    def spec(self, a: str, b: str) -> LinkSpec:
        sp = self._by_pair.get(frozenset((a, b)))
        if sp is not None:
            return sp
        return self.intra_default if a == b else self.cross_default

    def transfer_time(self, nbytes: float, a: str, b: str) -> float:
        return self.spec(a, b).transfer_time(nbytes)

    def edge_costs(self, nbytes_per_edge: "Sequence[float]",
                   stage_regions: "Sequence[str]") -> list[float]:
        """Per-boundary seconds for edge ``b`` between the regions
        serving stages ``b`` and ``b+1``."""
        if len(stage_regions) != len(nbytes_per_edge) + 1:
            raise ValueError(
                f"{len(stage_regions)} stage regions cannot price "
                f"{len(nbytes_per_edge)} edges (need n_stages = "
                f"n_edges + 1)")
        return [self.transfer_time(nb, stage_regions[b],
                                   stage_regions[b + 1])
                for b, nb in enumerate(nbytes_per_edge)]


def default_wan_table() -> LinkTable:
    """A 4-region preemptible-fleet WAN model (App. I flavored):
    fast in-zone links, a slower cross-country pair, and genuinely
    bad trans-ocean pairs — the spread that makes region-aware span
    fusion matter."""
    regions = ("us-east", "us-west", "eu", "ap")
    specs = [LinkSpec(r, r, bandwidth_mbps=800.0, latency_s=0.002)
             for r in regions]
    specs += [
        LinkSpec("us-east", "us-west", 200.0, 0.030),
        LinkSpec("us-east", "eu", 100.0, 0.045),
        LinkSpec("us-west", "eu", 80.0, 0.070),
        LinkSpec("us-east", "ap", 60.0, 0.080),
        LinkSpec("us-west", "ap", 100.0, 0.060),
        LinkSpec("eu", "ap", 50.0, 0.090),
    ]
    return LinkTable(specs)


def layer_flops(spec: LayerSpec, seq: int, batch: int) -> float:
    d, f = spec.d_model, spec.d_ff
    attn = 8 * d * d + 4 * seq * d
    ffn = 4 * d * f
    per_token = (attn + ffn) * spec.layers_per_stage
    return per_token * seq * batch


# Calibrated against the paper's Table 1 (20 points, log-space least
# squares): V100 running unfused fp16 PyTorch blocks reaches ~31 TFLOP/s
# asymptotically; small matmuls fall off with tau=2000; each boundary RPC
# costs ~5 ms; queued microbatches overlap ~90% of communication.
PEAK_FLOPS = 31e12
RPC_OVERHEAD = 0.005
DEFAULT_OVERLAP = 0.9


def matmul_efficiency(d_model: int, peak_flops: float = PEAK_FLOPS) -> float:
    """Effective fraction of peak for an unfused fp16 transformer layer —
    saturating curve calibrated on the paper's App. F timings."""
    return 0.45 * (1.0 - math.exp(-d_model / 2000.0)) + 0.02


def stage_times(spec: LayerSpec, *, seq: int = 512, batch: int = 1,
                bandwidth_mbps: float = 500.0, rtt_s: float = 0.0,
                peak_flops: float = PEAK_FLOPS, train: bool = True
                ) -> tuple[float, float]:
    """(compute_time, comm_time) for one microbatch through one stage."""
    flops = layer_flops(spec, seq, batch) * (3.0 if train else 1.0)
    eff = matmul_efficiency(spec.d_model, peak_flops)
    t_compute = flops / (peak_flops * eff)
    elem_bytes = 1.0625 if spec.quantize8 else 2.0   # int8+scales vs fp16
    nbytes = batch * seq * spec.d_model * elem_bytes
    n_transfers = 2.0 if train else 1.0              # activations + grads
    bw = bandwidth_mbps * MBPS
    t_comm = n_transfers * (nbytes / bw + RPC_OVERHEAD + rtt_s / 2.0)
    return t_compute, t_comm


def utilization(spec: LayerSpec, *, overlap: float = DEFAULT_OVERLAP,
                **kw) -> float:
    """Fraction of time the GPU computes (paper's '100% - idle time')."""
    t_c, t_n = stage_times(spec, **kw)
    exposed = max(0.0, t_n * (1 - overlap) + max(0.0, t_n - t_c) * overlap)
    return t_c / (t_c + exposed)


def scaling_exponents(spec: LayerSpec, factor: float = 2.0,
                      seq: int = 512) -> tuple[float, float]:
    """Empirical d(log cost)/d(log n): compute ~2-3, comm ~1 in d_model —
    the square-cube gap (property-tested)."""
    import dataclasses as dc
    big = dc.replace(spec, d_model=int(spec.d_model * factor),
                     d_ff=int(spec.d_ff * factor))
    f1 = layer_flops(spec, seq, 1)
    f2 = layer_flops(big, seq, 1)
    c1 = spec.d_model
    c2 = big.d_model
    return (math.log(f2 / f1) / math.log(factor),
            math.log(c2 / c1) / math.log(factor))
