"""Distributed Hash Table, in-process (Kademlia semantics à la hivemind).

SWARM uses the DHT for (a) peer discovery — each peer announces the stage it
serves with a TTL and re-announces every few minutes; trainers ban peers
until their next re-announcement (§3.2) — and (b) the rebalancing protocol,
which writes per-peer queue sizes under ``DHT[stage]`` as (subkey -> value)
pairs (Alg. 2 line 4).

We model the *semantics* (multi-writer keys, expiration, staleness) on the
virtual clock; network latency for DHT RPCs is charged by the caller via the
cost model.  Replication/routing internals of Kademlia are irrelevant to the
algorithms built on top and are not simulated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Hashable, Optional


@dataclasses.dataclass
class DHTRecord:
    value: Any
    expiration: float


class DHT:
    def __init__(self, clock: Callable[[], float]):
        self._clock = clock
        self._store: dict[Hashable, dict[Hashable, DHTRecord]] = {}

    def store(self, key: Hashable, subkey: Hashable, value: Any,
              ttl: float) -> None:
        self._store.setdefault(key, {})[subkey] = DHTRecord(
            value, self._clock() + ttl)

    def get(self, key: Hashable) -> dict[Hashable, DHTRecord]:
        now = self._clock()
        recs = self._store.get(key, {})
        live = {sk: r for sk, r in recs.items() if r.expiration > now}
        self._store[key] = live
        return dict(live)

    def get_value(self, key: Hashable, subkey: Hashable,
                  default: Any = None) -> Any:
        rec = self.get(key).get(subkey)
        return rec.value if rec is not None else default

    def delete(self, key: Hashable, subkey: Optional[Hashable] = None):
        if subkey is None:
            self._store.pop(key, None)
        else:
            self._store.get(key, {}).pop(subkey, None)

    # convenience namespaces used by SWARM
    @staticmethod
    def stage_key(stage: int) -> str:
        return f"stage/{stage}"

    @staticmethod
    def load_key(stage: int) -> str:
        return f"load/{stage}"
