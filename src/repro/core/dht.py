"""Distributed Hash Table, in-process (Kademlia semantics à la hivemind).

SWARM uses the DHT for (a) peer discovery — each peer announces the stage it
serves with a TTL and re-announces every few minutes; trainers ban peers
until their next re-announcement (§3.2) — and (b) the rebalancing protocol,
which writes per-peer queue sizes under ``DHT[stage]`` as (subkey -> value)
pairs (Alg. 2 line 4).

We model the *semantics* (multi-writer keys, expiration, staleness) on the
virtual clock; network latency for DHT RPCs is charged by the caller via the
cost model.  Replication/routing internals of Kademlia are irrelevant to the
algorithms built on top and are not simulated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Hashable, Optional


@dataclasses.dataclass
class DHTRecord:
    value: Any
    expiration: float


class DHT:
    def __init__(self, clock: Callable[[], float]):
        self._clock = clock
        self._store: dict[Hashable, dict[Hashable, DHTRecord]] = {}

    def store(self, key: Hashable, subkey: Hashable, value: Any,
              ttl: float) -> None:
        self._store.setdefault(key, {})[subkey] = DHTRecord(
            value, self._clock() + ttl)

    def get(self, key: Hashable) -> dict[Hashable, DHTRecord]:
        """Live records under ``key``.

        Deliberately a *mutating* read: expired records are compacted
        out of the backing store as a write-back side effect.  On a
        preemptible fleet most peers never say goodbye — their records
        simply lapse — so without this compaction every key table grows
        with the total number of peers that EVER announced, and the
        control plane's snapshot capture would scan dead entries
        forever.  Callers relying on ``get`` being side-effect-free on
        the store are wrong on purpose; the returned dict is a copy and
        safe to hold."""
        now = self._clock()
        recs = self._store.get(key, {})
        live = {sk: r for sk, r in recs.items() if r.expiration > now}
        self._store[key] = live
        return dict(live)

    def get_values(self, key: Hashable) -> dict[Hashable, Any]:
        """Live ``{subkey: value}`` under ``key`` — the snapshot-capture
        fast path.  Same write-back compaction as :meth:`get`, but skips
        materialising :class:`DHTRecord` copies: at 1000-peer scale a
        span-fused fleet announces ~50k load records per round, and the
        double copy in ``get`` dominates capture time."""
        now = self._clock()
        recs = self._store.get(key, {})
        if any(r.expiration <= now for r in recs.values()):
            recs = {sk: r for sk, r in recs.items() if r.expiration > now}
            self._store[key] = recs
        return {sk: r.value for sk, r in recs.items()}

    def n_records(self, prefix: Optional[str] = None) -> int:
        """Count of live records (optionally only under keys whose str
        form starts with ``prefix``) — leak diagnostics: after a churny
        run this should track the LIVE fleet, not every peer that ever
        existed."""
        now = self._clock()
        return sum(
            sum(1 for r in recs.values() if r.expiration > now)
            for key, recs in self._store.items()
            if prefix is None or str(key).startswith(prefix))

    def get_value(self, key: Hashable, subkey: Hashable,
                  default: Any = None) -> Any:
        rec = self.get(key).get(subkey)
        return rec.value if rec is not None else default

    def delete(self, key: Hashable, subkey: Optional[Hashable] = None):
        if subkey is None:
            self._store.pop(key, None)
        else:
            self._store.get(key, {}).pop(subkey, None)

    # convenience namespaces used by SWARM
    @staticmethod
    def stage_key(stage: int) -> str:
        return f"stage/{stage}"

    @staticmethod
    def load_key(stage: int) -> str:
        return f"load/{stage}"
