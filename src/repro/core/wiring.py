"""Stochastic wiring (paper §3.2 + Appendix C, Algorithm 1).

Interleaved Weighted Round-Robin over a priority queue: every peer serving a
stage carries *the total processing time over all previous requests*; a
microbatch routes to the peer with the smallest total, whose priority is
then bumped by the EMA of its response time.  A device that is 2× faster
thus receives 2× the requests.  Failed peers are banned (priority = ∞)
until they re-announce in the DHT.

Faithfulness notes vs Algorithm 1:
  * ``ema`` starts at ``epsilon`` and is updated as
    ``ema = gamma*dt + (1-gamma)*ema`` (line 30).
  * ``choose_server`` bumps priority by the *current* EMA before dispatch
    (lines 14-19) so concurrent trainers spread load.
  * different trainers keep independent EMAs — this is what makes routing
    topology-aware (§3.2 "trainers automatically adjust to the network
    topology").
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Hashable, Optional

INF = math.inf


@dataclasses.dataclass
class _Entry:
    priority: float
    seq: int
    server: Hashable
    valid: bool = True


class StagePriorityQueue:
    """Lazy-deletion priority queue keyed by accumulated processing time.

    Every ``choose_server`` bump pushes a fresh tuple and merely marks
    the old one invalid, so without compaction the heap grows O(#requests)
    for the life of the trainer (the ISSUE-10 leak).  When invalidated
    entries outnumber live ones the heap is rebuilt in place from the
    survivors — amortized O(1) per update, keeping the heap O(#servers)."""

    #: below this size compaction isn't worth the heapify (and the ratio
    #: test would thrash on 2-3 entry heaps)
    _COMPACT_MIN = 8

    def __init__(self):
        self._heap: list[tuple[float, int, _Entry]] = []
        self._entries: dict[Hashable, _Entry] = {}
        self._seq = 0
        self._invalid = 0        # invalidated entries still in the heap

    def _invalidate(self, e: _Entry) -> None:
        e.valid = False
        if e.priority != INF:    # INF entries were never pushed
            self._invalid += 1

    def _maybe_compact(self) -> None:
        if self._invalid > self._COMPACT_MIN \
                and 2 * self._invalid > len(self._heap):
            self._heap = [t for t in self._heap if t[2].valid]
            heapq.heapify(self._heap)
            self._invalid = 0

    def update(self, server: Hashable, priority: float) -> None:
        old = self._entries.get(server)
        if old is not None:
            self._invalidate(old)
        self._seq += 1
        e = _Entry(priority, self._seq, server)
        self._entries[server] = e
        if priority != INF:
            heapq.heappush(self._heap, (priority, self._seq, e))
        self._maybe_compact()

    def remove(self, server: Hashable) -> None:
        old = self._entries.pop(server, None)
        if old is not None:
            self._invalidate(old)
            self._maybe_compact()

    def top(self) -> Optional[tuple[Hashable, float]]:
        while self._heap:
            priority, _, e = self._heap[0]
            if not e.valid:
                heapq.heappop(self._heap)
                self._invalid -= 1
                continue
            return e.server, priority
        return None

    def heap_size(self) -> int:
        """Current physical heap length (leak diagnostics / tests)."""
        return len(self._heap)

    def servers(self) -> list[Hashable]:
        return [s for s, e in self._entries.items() if e.priority != INF]

    def priority_of(self, server: Hashable) -> Optional[float]:
        e = self._entries.get(server)
        return e.priority if e is not None else None


class StochasticWiring:
    """Algorithm 1. One instance per *trainer* (per-trainer EMAs)."""

    def __init__(self, n_stages: int, gamma: float = 0.1,
                 epsilon: float = 1e-3, seed: Optional[int] = None):
        self.n_stages = n_stages
        self.gamma = gamma
        self.epsilon = epsilon
        self.ema: dict[Hashable, float] = {}
        self.queues = [StagePriorityQueue() for _ in range(n_stages)]
        self._stages_of: dict[Hashable, list[int]] = {}
        import random
        self._rng = random.Random(seed)

    # ------------------------------------------------------------ peers
    def add_server(self, server: Hashable, stages: list[int]) -> None:
        # jittered priors break the herd: with exactly-equal priorities
        # every trainer's first assignments pile onto one peer until EMAs
        # diverge (real deployments never observe identical times).
        prior = self.epsilon * self._rng.uniform(0.5, 1.5)
        self.ema.setdefault(server, prior)
        self._stages_of[server] = list(stages)
        for s in stages:
            self.queues[s].update(server, self.ema[server])

    def remove_server(self, server: Hashable) -> None:
        for s in self._stages_of.pop(server, []):
            self.queues[s].remove(server)

    def ban_server(self, server: Hashable) -> None:
        for s in self._stages_of.get(server, []):
            self.queues[s].update(server, INF)

    def move_server(self, server: Hashable, new_stages: list[int]) -> None:
        self.remove_server(server)
        self.add_server(server, new_stages)

    # ------------------------------------------------------------ routing
    def choose_server(self, stage: int) -> Optional[Hashable]:
        top = self.queues[stage].top()
        if top is None:
            return None
        server, priority = top
        self.queues[stage].update(server, priority + self.ema[server])
        return server

    def observe(self, server: Hashable, dt: float) -> None:
        """EMA update after a completed request (Alg. 1 line 30)."""
        prev = self.ema.get(server, self.epsilon)
        self.ema[server] = self.gamma * dt + (1 - self.gamma) * prev

    def is_banned(self, server: Hashable) -> bool:
        stages = self._stages_of.get(server)
        if not stages:
            return False
        return any(self.queues[s].priority_of(server) == INF
                   for s in stages)

    def refresh_from_dht(self, dht, stage_of_peer) -> None:
        """Reconcile routing state with the DHT's live view (§3.2).
        ``stage_of_peer``: server -> stage from DHT records.

        Three cases: evict peers ABSENT from the snapshot, re-admit
        banned peers that re-announced, discover new ones.  Eviction is
        the load-bearing half on preemptible fleets — a reclaimed spot
        instance never says goodbye, its DHT records simply expire, so
        a peer missing from the snapshot must leave the queues,
        ``_stages_of`` and ``ema`` after ONE refresh.  Historically it
        stayed forever: routing kept offering the dead peer until a
        request failed, and under churn the maps grew without bound
        (the ISSUE-10 leak).  A healthy peer is never evicted by this —
        its own TTL'd announcement keeps it in every snapshot — and an
        evicted peer that comes back is re-discovered below with a
        fresh jittered EMA prior, exactly like a first join."""
        for server in list(self._stages_of):
            if server not in stage_of_peer:
                self.remove_server(server)
                self.ema.pop(server, None)
        for server, stage in stage_of_peer.items():
            cur = self._stages_of.get(server)
            if cur != [stage]:
                self.move_server(server, [stage])
            elif self.is_banned(server):
                # stage unchanged but the peer is live in the DHT: the
                # ban was transient (e.g. a routing race during a
                # migration window) and lifts on re-announce — it must
                # not become a permanent per-trainer blacklist
                for s in cur:
                    self.queues[s].update(server, self.ema[server])
