"""Exactly-once elastic gradient accounting (paper App. A).

SWARM promises *synchronous semantics under churn*: every optimizer step
averages exactly ``global_batch`` samples, with gradients lost to dead or
migrating peers recomputed by survivors.  The :class:`MicrobatchLedger`
is the bookkeeping that makes this literal rather than statistical — per
round it tracks, for every pipeline stage, *which* microbatch indices
have been folded into some live peer's gradient accumulator and *by
whom*:

* ``record(stage, idx, peer)`` admits each ``(stage, idx)`` pair at most
  once per round, so a microbatch that fails mid-backward and gets
  re-issued is never double-counted by the stages that already hold it
  (re-running the backward with unchanged params reproduces the same
  gradient, so skipping the re-accumulation is exact);
* ``release_peer(stage, peer)`` forgets the contributions that die with
  a failed or migrating peer and re-queues exactly those indices for
  recompute — no generic re-dispatch budget that could over-issue;
* ``complete()`` is the All-Reduce trigger: every stage holds every
  index of the round, i.e. the global batch is bitwise accounted.

The ledger is mode-agnostic: numeric and throughput-only simulations use
the same accounting, so timing experiments exercise the identical
protocol the equivalence tests verify.

Serving reuses the same spine: :class:`ExactlyOnceLedger` is the
stage-by-key holdership core (admit each ``(stage, key)`` once, forget a
dead peer's holdings), :class:`MicrobatchLedger` layers training rounds
and re-dispatch on top, and :class:`SessionKVLedger` tracks which peer
holds each live session's KV cache per stage — where "admit at most
once" becomes the *no-double-prefill* invariant: a session's stage is
prefilled exactly once unless its holder died and released it first.
"""
from __future__ import annotations

from collections import deque
from typing import Hashable, Iterable, Optional


class ExactlyOnceLedger:
    """Per-stage keyed holdership with at-most-once admission.

    The shared accounting spine: ``acc[stage]`` maps a key (a microbatch
    index, a session id) to the peer currently holding the associated
    state (accumulated grads, a KV cache).  ``record`` admits each
    ``(stage, key)`` at most once; ``release_peer`` forgets what died
    with a peer and returns it so the caller can schedule recompute."""

    def __init__(self, n_stages: int):
        self.n_stages = n_stages
        # per stage: key -> id of the peer holding its state
        self.acc: list[dict[Hashable, Hashable]] = \
            [{} for _ in range(n_stages)]

    def record(self, stage: int, key: Hashable,
               peer_id: Hashable) -> bool:
        """Admit ``(stage, key)``; False if already held, in which case
        the caller must NOT duplicate the associated state."""
        if key in self.acc[stage]:
            return False
        self.acc[stage][key] = peer_id
        return True

    def holder(self, stage: int, key: Hashable) -> Optional[Hashable]:
        return self.acc[stage].get(key)

    def release(self, stage: int, key: Hashable) -> bool:
        """Forget one ``(stage, key)`` holdership (True if it was held)."""
        return self.acc[stage].pop(key, None) is not None

    def release_peer(self, stage: int, peer_id: Hashable) -> list:
        """Forget ``peer_id``'s holdings at ``stage`` (they died with
        it); returns the lost keys."""
        lost = [k for k, pid in self.acc[stage].items() if pid == peer_id]
        for k in lost:
            del self.acc[stage][k]
        return lost

    def release_all(self, peer_id: Hashable) -> list[tuple[int, Hashable]]:
        """Release ``peer_id`` from every stage (peer death)."""
        return [(s, k) for s in range(self.n_stages)
                for k in self.release_peer(s, peer_id)]

    def missing_stages(self, key: Hashable) -> list[int]:
        return [s for s in range(self.n_stages) if key not in self.acc[s]]

    def stage_counts(self) -> list[int]:
        return [len(d) for d in self.acc]


class SessionKVLedger(ExactlyOnceLedger):
    """``(stage, session) -> peer`` holdership of serving KV caches.

    The serving analogue of gradient accounting: a session's stage is
    prefilled into exactly one live peer's ``"kv"`` slot.  ``record`` is
    *strict* by default — admitting a held ``(stage, session)`` twice
    means some recovery path re-prefilled a stage whose cache never
    died, so it raises instead of returning False (release first, on
    peer death, is the only legal path to a second prefill).
    ``transfer`` moves holdership without re-admission: the
    disaggregated prefill -> decode hand-off, where the cache crosses
    peers via ``export_slot``/``install_slot`` but was computed once."""

    def record(self, stage: int, key: Hashable, peer_id: Hashable,
               strict: bool = True) -> bool:
        if not super().record(stage, key, peer_id):
            if strict:
                raise RuntimeError(
                    f"double prefill: stage {stage} of session {key!r} "
                    f"already held by {self.acc[stage][key]!r}")
            return False
        return True

    def transfer(self, stage: int, key: Hashable,
                 new_peer: Hashable) -> None:
        assert key in self.acc[stage], (stage, key)
        self.acc[stage][key] = new_peer

    def sessions_of(self, peer_id: Hashable) -> set:
        return {k for d in self.acc for k, pid in d.items()
                if pid == peer_id}


class MicrobatchLedger(ExactlyOnceLedger):
    """Per-round exactly-once accounting of (stage, microbatch) pairs."""

    def __init__(self, n_stages: int):
        super().__init__(n_stages)
        self.round_indices: tuple[int, ...] = ()
        self._round_set: frozenset[int] = frozenset()
        self.inflight: set[int] = set()
        self.attempts: dict[int, int] = {}
        self._pending: deque[int] = deque()
        self._pending_set: set[int] = set()

    # ------------------------------------------------------------ rounds
    def open_round(self, indices: Iterable[int]) -> None:
        """Start a fresh accumulation round over ``indices``."""
        self.round_indices = tuple(indices)
        self._round_set = frozenset(self.round_indices)
        for d in self.acc:
            d.clear()
        self.inflight.clear()
        self.attempts = {i: 0 for i in self.round_indices}
        self._pending = deque(self.round_indices)
        self._pending_set = set(self.round_indices)

    def complete(self) -> bool:
        n = len(self.round_indices)
        return all(len(d) == n for d in self.acc)

    # ---------------------------------------------------------- dispatch
    def next_index(self) -> Optional[tuple[int, int]]:
        """Next microbatch index needing (re)dispatch, as ``(index,
        attempt)`` provenance, or None when nothing is pending.  An index
        is pending iff it is not in flight and some stage lacks it."""
        while self._pending:
            idx = self._pending.popleft()
            self._pending_set.discard(idx)
            if idx in self.inflight or not self.missing_stages(idx):
                continue
            self.inflight.add(idx)
            self.attempts[idx] += 1
            return idx, self.attempts[idx]
        return None

    def settle(self, idx: int) -> None:
        """The in-flight attempt for ``idx`` finished (ok or not); if any
        stage still lacks the index, queue it for re-issue."""
        self.inflight.discard(idx)
        if self.missing_stages(idx):
            self._requeue(idx)

    # ------------------------------------------------------- accounting
    def record(self, stage: int, idx: int, peer_id: Hashable) -> bool:
        """Admit ``(stage, idx)``; False if already held (or stale — the
        index is not part of the current round), in which case the
        caller must NOT fold the gradient in."""
        if idx not in self._round_set or idx in self.acc[stage]:
            return False
        self.acc[stage][idx] = peer_id
        return True

    def release_peer(self, stage: int, peer_id: Hashable) -> list[int]:
        """Forget ``peer_id``'s contributions to ``stage`` (its grads
        died with it); the lost indices are re-queued for recompute."""
        lost = [i for i, pid in self.acc[stage].items() if pid == peer_id]
        for i in lost:
            del self.acc[stage][i]
            if i not in self.inflight:
                self._requeue(i)
        return lost

    def release_all(self, peer_id: Hashable) -> list[tuple[int, int]]:
        """Release ``peer_id`` from every stage (peer death)."""
        return [(s, i) for s in range(self.n_stages)
                for i in self.release_peer(s, peer_id)]

    # ---------------------------------------------------------- queries
    def missing_stages(self, idx: int) -> list[int]:
        return [s for s in range(self.n_stages) if idx not in self.acc[s]]

    def stage_counts(self) -> list[int]:
        return [len(d) for d in self.acc]

    def _requeue(self, idx: int) -> None:
        if idx not in self._pending_set:
            self._pending.append(idx)
            self._pending_set.add(idx)
