"""SWARM peers: device profiles, the GPU executor loop, stage state.

A peer serves one pipeline stage (a group of layers with identical
parameters across the stage's peers).  In **numeric mode** requests execute
real JAX math — forward, and backward via activation checkpointing (the
peer recomputes the forward from the boundary input, exactly like the
paper's implementation) — while *virtual* time advances per the device cost
model.  In **throughput mode** only the clock moves, which is how the
Table 2/5 style experiments run 400-peer × 32-hour traces in seconds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.sim import Sim, Sleep, Event, Interrupt
# StageState is owned by the stage-runtime layer (repro.runtime): every
# mutation that touches device memory goes through a StageExecutor.  The
# re-export keeps the historical import path alive.
from repro.runtime.base import StageState  # noqa: F401  (re-export)

Tree = Any


class PeerFailure(Exception):
    pass


def _alias_state(dst: StageState, src: StageState) -> None:
    """Zero-copy single-stage state adoption (identical backend +
    placement: aliasing the immutable device arrays is exact).  Only the
    training state crosses: the donor's non-core slots (e.g. serving KV,
    whose per-session holdership the KV ledger tracks) are NOT cloned,
    and any the adopter held are dropped — same semantics as a
    snapshot/restore hand-off with default ``slots=()``."""
    from repro.runtime.base import CORE_SLOTS
    dst.params = jax.tree.map(lambda x: x, src.params)
    dst.opt = jax.tree.map(lambda x: x, src.opt)
    dst.version = src.version
    for name in [n for n in dst.slots if n not in CORE_SLOTS]:
        del dst.slots[name]
    dst.grad_acc = (jax.tree.map(jnp.zeros_like, src.params)
                    if src.params is not None else None)
    dst.loss_sum = 0.0
    dst.token_count = 0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Effective (not peak) throughput + NIC model, per paper §4 hardware."""
    name: str
    flops_per_s: float          # effective mixed-precision FLOP/s
    up_bw: float                # bytes/s
    down_bw: float              # bytes/s
    latency: float              # one-way network latency, seconds

    def compute_time(self, flops: float) -> float:
        return flops / self.flops_per_s

    def send_time(self, nbytes: float) -> float:
        return self.latency + nbytes / self.up_bw

    def recv_time(self, nbytes: float) -> float:
        return self.latency + nbytes / self.down_bw


MBPS = 125_000.0  # 1 Mb/s in bytes/s

# Effective throughputs: vendor peak x a realistic utilization for
# unfused fp16 transformer blocks (paper App. F measures ~10-45%).
T4 = DeviceProfile("T4", 65e12 * 0.25, 400 * MBPS, 400 * MBPS, 0.005)
V100 = DeviceProfile("V100", 125e12 * 0.25, 500 * MBPS, 500 * MBPS, 0.003)
A100 = DeviceProfile("A100", 312e12 * 0.25, 550 * MBPS, 550 * MBPS, 0.003)


@dataclasses.dataclass
class _Task:
    kind: str                 # "fwd" | "bwd"
    payload: Any
    done: Event
    compute_time: float


class Peer:
    _ids = 0

    def __init__(self, sim: Sim, profile: DeviceProfile,
                 stage: "int | range", *, name: Optional[str] = None,
                 executor=None, region: str = "local"):
        Peer._ids += 1
        self.id = name or f"peer{Peer._ids}"
        self.sim = sim
        self.profile = profile
        # which cloud zone this instance lives in: boundary edges between
        # peers in different regions are priced by the swarm's LinkTable
        # (repro.core.square_cube), and zone-correlated spot reclaims
        # take out peers region by region
        self.region = region
        # how this peer runs its stages (repro.runtime.StageExecutor):
        # a NumericExecutor shared by the stage's peers, a MeshExecutor
        # backing this peer with a device mesh, a PipelineExecutor
        # fusing a contiguous span, or None in timing-only simulations.
        # The SwarmRunner assigns and swaps it.
        self.executor = executor
        self.set_span(stage)
        self.alive = True
        # serving=False while the peer downloads stage state (a joining
        # or migrating peer must never serve stale params); routing and
        # submit both refuse non-serving peers
        self.serving = True
        self.state = self._fresh_state()
        self._tasks: list[_Task] = []
        self._wake = sim.event()
        self._epoch = 0               # bumped by drain(): voids queued work
        self._generation = 0          # bumped by revive(): retires executor
        self.busy_time = 0.0          # for utilization metrics
        # NIC model for the async tick: boundary tensors in flight
        # occupy these links, never the compute queue, so the peer
        # computes microbatch k+1 while k's boundary is on the wire
        self.uplink = sim.link()
        self.downlink = sim.link()
        self.idle_time = 0.0          # executor-waited-empty seconds
        self._idle_since: Optional[float] = None
        self.spawn_executor()

    # ------------------------------------------------------------ span
    def set_span(self, stage: "int | range"):
        """Adopt a stage assignment: a single stage or a contiguous span
        ``range(lo, hi)``.  ``self.stage`` stays the ENTRY stage (span
        start) — the only stage a trainer may route this peer at — while
        ``self.stages`` is the full covered range (the peer's DHT slots,
        All-Reduce groups, and ledger rows)."""
        span = stage if isinstance(stage, range) else range(stage, stage + 1)
        if self.executor is not None and hasattr(self.executor, "stages"):
            assert (self.executor.stages.start == span.start
                    and self.executor.stages.stop == span.stop), \
                (self.executor.stages, span)
        self.span = span
        self.stage = span.start

    @property
    def stages(self) -> range:
        return self.span

    def _fresh_state(self) -> StageState:
        # timing-only span peers (no executor) still keep per-stage
        # bookkeeping so the All-Reduce barrier reads per-stage counters
        if self.executor is None and len(self.span) > 1:
            return StageState(per_stage={s: StageState() for s in self.span})
        return StageState()

    # ------------------------------------------------------------ executor
    def spawn_executor(self):
        self.sim.spawn(self._executor(self._generation))

    def _executor(self, gen: int):
        while self.alive and gen == self._generation:
            if not self._tasks:
                self._wake = self.sim.event()
                self._idle_since = self.sim.now
                try:
                    yield self._wake.wait()
                except Interrupt:
                    self._close_idle()
                    return
                self._close_idle()
                continue
            task = self._tasks.pop(0)
            epoch = self._epoch
            yield Sleep(task.compute_time)
            if not self.alive or gen != self._generation:
                task.done.fail(PeerFailure(self.id))   # died mid-compute
                return
            if epoch != self._epoch:    # drained mid-compute (migration)
                task.done.fail(PeerFailure(self.id))
                continue
            self.busy_time += task.compute_time
            try:
                result = task.payload()
            except PeerFailure as e:
                task.done.fail(e)
                continue
            task.done.fire(result)

    def queue_size(self) -> int:
        return len(self._tasks)

    def _close_idle(self) -> None:
        if self._idle_since is not None:
            self.idle_time += self.sim.now - self._idle_since
            self._idle_since = None

    def total_idle(self, now: Optional[float] = None) -> float:
        """Executor idle seconds, including the currently open interval."""
        open_dt = 0.0
        if self._idle_since is not None:
            open_dt = (now if now is not None else self.sim.now) \
                - self._idle_since
        return self.idle_time + open_dt

    # ------------------------------------------------------------ wire
    def send(self, nbytes: float, to: "Optional[Peer]" = None) -> Event:
        """Put ``nbytes`` on this peer's uplink.  The transfer occupies
        the LINK, not the compute queue — the executor keeps working
        while the boundary is in flight.  With ``to`` given the transfer
        is end-to-end priced at the bottleneck of the pair (one latency,
        min of up/down bandwidth) and the receiver's downlink is charged
        the same window."""
        if to is None:
            dur = self.profile.send_time(nbytes)
        else:
            bw = min(self.profile.up_bw, to.profile.down_bw)
            dur = self.profile.latency + nbytes / bw
            to.downlink.occupy(dur, nbytes)
        return self.uplink.transfer(dur, nbytes)

    def recv(self, nbytes: float, frm: "Optional[Peer]" = None) -> Event:
        """Await ``nbytes`` landing on this peer's downlink.  With
        ``frm`` given the transfer is priced at the bottleneck of the
        pair and the sender's uplink is charged the same window."""
        if frm is None:
            dur = self.profile.recv_time(nbytes)
        else:
            bw = min(self.profile.down_bw, frm.profile.up_bw)
            dur = self.profile.latency + nbytes / bw
            frm.uplink.occupy(dur, nbytes)
        return self.downlink.transfer(dur, nbytes)

    def submit(self, kind: str, compute_time: float,
               thunk: Callable[[], Any]) -> Event:
        """Enqueue work; returns completion Event (fails on peer death
        and while the peer is downloading state, i.e. not serving)."""
        if not self.alive or not self.serving:
            ev = self.sim.event()
            ev.fail(PeerFailure(self.id))
            return ev
        done = self.sim.event()
        self._tasks.append(_Task(kind, thunk, done, compute_time))
        if not self._wake.fired:
            self._wake.fire()
        return done

    # ------------------------------------------------------------ failure
    def fail(self):
        self.alive = False
        self.serving = False
        for t in self._tasks:
            t.done.fail(PeerFailure(self.id))
        self._tasks.clear()
        if not self._wake.fired:
            self._wake.fail(Interrupt())

    def drain(self):
        """Fail every queued and in-compute task without killing the
        peer — trainers observe PeerFailure and re-route (App. A).  Used
        when a migration retires the peer's current stage: queued thunks
        were built against the old stage's params and must never execute
        against the newly adopted state."""
        self._epoch += 1
        for t in self._tasks:
            t.done.fail(PeerFailure(self.id))
        self._tasks.clear()

    def revive(self, stage: "int | range"):
        """Rejoin (a fresh preemptible instance reusing this peer
        object): reset state and restart the executor.  The swarm that
        revives a peer is responsible for the warm join — download the
        stage state, re-announce in the DHT, and re-spawn the announcer
        (see ``SwarmRunner._join_new_peer``)."""
        self.alive = True
        self.serving = True
        self.set_span(stage)
        self.state = self._fresh_state()
        self._tasks = []
        self._epoch += 1
        self._generation += 1        # retire any executor still parked
        self._wake = self.sim.event()
        self.spawn_executor()

    # ------------------------------------------------------------ state
    def state_nbytes(self, stage: Optional[int] = None) -> float:
        """Transferable state bytes: one covered stage with ``stage=``,
        the whole (possibly span) state otherwise."""
        views = ([self.state.stage_view(stage)] if stage is not None
                 else self.state.views())
        pbytes = sum(x.size * x.dtype.itemsize
                     for v in views if v.params is not None
                     for x in jax.tree.leaves(v.params))
        return 3 * pbytes          # params + adam m/v, roughly

    def adopt_state_from(self, donor: "Peer"):
        """Download the stage checkpoint from a live neighbor (Fig. 2).

        The transfer goes through the executors' snapshot/restore pair —
        a host-side (numpy) tree is the wire format — so the donor and
        the adopter may run *different* backends (a mesh-backed peer can
        seed a single-device joiner and vice versa).  Peers SHARING an
        executor (all numeric peers of a stage do) skip the host
        round-trip: identical backend and placement make aliasing the
        immutable device arrays exact and zero-copy."""
        if (self.executor is not None and donor.executor is not None
                and self.executor is not donor.executor
                and (donor.state.params is not None
                     or donor.state.per_stage is not None)):
            self.executor.restore(self.state,
                                  donor.executor.snapshot(donor.state))
            return
        if donor.state.per_stage is not None:   # shared span backend
            self.state.per_stage = {}
            for s, sub in donor.state.per_stage.items():
                mine = self.state.per_stage[s] = StageState()
                _alias_state(mine, sub)
            return
        _alias_state(self.state, donor.state)
