"""Baseline systems the paper compares against (Table 2): GPipe, 1F1B, and
ZeRO-Offload, modelled on the same device/network cost model as SWARM.

These are steady-state analytic models (the baselines are rigid synchronous
systems, so closed forms are exact up to the bubble term), matching the
paper's §4.2 setup: 16 workers, 4 stages x 4 data-parallel groups for the
pipelines; full-model data parallelism for ZeRO-Offload.
"""
from __future__ import annotations

import dataclasses

from repro.core.peer import DeviceProfile
from repro.models.config import ArchConfig
from repro.models import flops as F


@dataclasses.dataclass(frozen=True)
class BaselineResult:
    name: str
    throughput: float          # samples/s
    allreduce_time: float      # s per averaging round


def _stage_times(cfg: ArchConfig, profile: DeviceProfile, seq: int,
                 n_stages: int, microbatch: int, compress: str):
    ctx = F._ctx_for(cfg, seq, causal_avg=True)
    per = cfg.n_layers // n_stages
    fpt = sum(F.per_token_layer_flops(cfg, k, ctx)
              for k in cfg.block_kinds[:per])
    t_c = profile.compute_time(3.0 * fpt * seq * microbatch)   # fwd+bwd
    # boundary_bytes resolves the REAL per-codec wire size (int8 block
    # scales, cfg.bottleneck_dim / maxout k) — baseline-vs-SWARM tables
    # therefore compare identical wire-byte assumptions for every mode
    nbytes = F.boundary_bytes(cfg, microbatch, seq, compress)
    t_n = 2 * (profile.latency + nbytes / profile.up_bw)       # act + grad
    return t_c, t_n


def _allreduce_time(nbytes: float, k: int, bw: float, latency: float):
    return 2 * (k - 1) / max(k, 1) * nbytes / bw + 2 * latency * k


def gpipe(cfg: ArchConfig, profile: DeviceProfile, *, seq: int = 512,
          n_workers: int = 16, n_stages: int = 4, microbatch: int = 1,
          n_microbatches: int = 8, compress: str = "none",
          name: str = "GPipe") -> BaselineResult:
    """Synchronous pipeline: communication is exposed (blocking RPC), and
    the (S-1)/(M+S-1) bubble applies."""
    groups = n_workers // n_stages
    t_c, t_n = _stage_times(cfg, profile, seq, n_stages, microbatch,
                            compress)
    t_mb = t_c + t_n                          # no compute/comm overlap
    t_batch = (n_microbatches + n_stages - 1) * t_mb
    thr = groups * n_microbatches * microbatch / t_batch
    stage_bytes = 2.0 * F.total_params(cfg) / n_stages
    ar = _allreduce_time(stage_bytes, groups, profile.up_bw,
                         profile.latency)
    return BaselineResult(name, thr, ar)


def one_f1b(cfg: ArchConfig, profile: DeviceProfile, **kw) -> BaselineResult:
    """1F1B (PipeDream-flush): same steady-state throughput as GPipe,
    lower activation memory (identical in this cost model — Table 2 shows
    identical throughput/all-reduce too)."""
    r = gpipe(cfg, profile, **kw)
    return BaselineResult("1F1B", r.throughput, r.allreduce_time)


def zero_offload(cfg: ArchConfig, profile: DeviceProfile, *, seq: int = 512,
                 n_workers: int = 16, microbatch: int = 1,
                 offload_slowdown: float = 1.6) -> BaselineResult:
    """Full-model data parallelism with CPU-offloaded optimizer: every
    worker computes the whole model (slowed by PCIe streaming), then
    All-Reduces the FULL parameter-sized gradient."""
    ctx = F._ctx_for(cfg, seq, causal_avg=True)
    fpt = sum(F.per_token_layer_flops(cfg, k, ctx) for k in cfg.block_kinds)
    t_c = profile.compute_time(3.0 * fpt * seq * microbatch) \
        * offload_slowdown
    thr = n_workers * microbatch / t_c
    ar = _allreduce_time(2.0 * F.total_params(cfg), n_workers,
                         profile.up_bw, profile.latency)
    return BaselineResult("ZeRO-Offload", thr, ar)
