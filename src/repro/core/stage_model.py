"""Back-compat shim: the stage-program machinery moved into the stage
runtime layer (``repro.runtime.stage_model``), which owns jitting and
the process-wide compile cache — see ``repro.runtime``.  Import from
there in new code."""
from repro.runtime.stage_model import (  # noqa: F401
    StageProgram, build_stage_programs, init_stage_params)

__all__ = ["StageProgram", "build_stage_programs", "init_stage_params"]
