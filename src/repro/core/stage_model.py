"""DEPRECATED back-compat shim: the stage-program machinery lives in the
stage runtime layer (``repro.runtime.stage_model``), which owns jitting
and the process-wide compile cache — see ``repro.runtime``.  Importing
this module warns; it will be removed once nothing references it."""
import warnings

from repro.runtime.stage_model import (  # noqa: F401
    StageProgram, build_stage_programs, init_stage_params)

warnings.warn(
    "repro.core.stage_model is deprecated; import StageProgram, "
    "build_stage_programs and init_stage_params from repro.runtime",
    DeprecationWarning, stacklevel=2)

__all__ = ["StageProgram", "build_stage_programs", "init_stage_params"]
