"""SWARM parallelism — the paper's primary contribution.

sim/dht/wiring/rebalance/peer/trainer/swarm compose the decentralized
pipeline-parallel system of §3.2; square_cube is the §3.1 analysis;
faults supplies the preemption traces of §4.4/App. I.
"""
from repro.core.sim import Sim, Sleep, Event, Resource
from repro.core.dht import DHT
from repro.core.ledger import MicrobatchLedger
from repro.core.wiring import StochasticWiring
from repro.core.rebalance import plan_migration, optimal_assignment, \
    pipeline_throughput, Migration
from repro.core.peer import Peer, DeviceProfile, PeerFailure, StageState, \
    T4, V100, A100
from repro.core.swarm import SwarmRunner, SwarmConfig
from repro.core.faults import synth_preemptible_trace, TraceEvent

__all__ = [
    "Sim", "Sleep", "Event", "Resource", "DHT", "MicrobatchLedger",
    "StochasticWiring",
    "plan_migration", "optimal_assignment", "pipeline_throughput",
    "Migration", "Peer", "DeviceProfile", "PeerFailure", "StageState",
    "T4", "V100", "A100", "SwarmRunner", "SwarmConfig",
    "synth_preemptible_trace", "TraceEvent",
]
