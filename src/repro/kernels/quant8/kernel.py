"""Pallas TPU kernel: blockwise dynamic int8 quantization (Dettmers 2021).

This is the compression hot spot of SWARM (§4.3): every pipeline-boundary
tensor is quantized before hitting the wire and dequantized on arrival.

TPU mapping: the flat tensor is viewed as [rows, block]; a grid step loads a
[ROW_TILE, block] tile into VMEM, computes per-row absmax on the VPU, and
writes int8 codes + f32 scales.  ``block`` is the quantization granularity
(64, paper-faithful); ROW_TILE x block = 128 x 64 keeps the tile layout
(8,128)-aligned for the VPU while staying well under VMEM limits
(128*64*4B = 32 KiB in, 8 KiB + 0.5 KiB out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # [ROW_TILE, block]
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    q = jnp.round(x / jnp.maximum(scale, 1e-12) * 127.0)
    q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref, *, dtype):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...] / 127.0).astype(dtype)


@functools.partial(jax.jit, static_argnums=(1, 2))
def quantize(x: jax.Array, block: int = 64, interpret: bool = True):
    """x: flat [n], n % block == 0 -> (int8 [n/block, block], f32 scales)."""
    rows = x.shape[0] // block
    xr = x.reshape(rows, block)
    row_tile = min(ROW_TILE, rows)
    assert rows % row_tile == 0, (rows, row_tile)
    grid = (rows // row_tile,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((row_tile, block), lambda i: (i, 0)),
                   pl.BlockSpec((row_tile, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(xr)
    return q, s


@functools.partial(jax.jit, static_argnums=(2, 3))
def dequantize(q: jax.Array, s: jax.Array, dtype=jnp.float32,
               interpret: bool = True):
    rows, block = q.shape
    row_tile = min(ROW_TILE, rows)
    assert rows % row_tile == 0
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, dtype=dtype),
        grid=(rows // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, block), lambda i: (i, 0)),
                  pl.BlockSpec((row_tile, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), dtype),
        interpret=interpret,
    )(q, s)
    return out
