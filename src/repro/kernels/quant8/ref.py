"""Pure-jnp oracle for the blockwise int8 quantization kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array, block: int = 64):
    """x [n] (flat, n % block == 0) -> (codes int8 [n//block, block],
    scales f32 [n//block, 1])."""
    blocks = x.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12) * 127.0),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale / 127.0).astype(dtype)


def roundtrip_ref(x: jax.Array, block: int = 64) -> jax.Array:
    q, s = quantize_ref(x, block)
    return dequantize_ref(q, s, x.dtype).reshape(x.shape)
