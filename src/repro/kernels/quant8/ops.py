"""jit'd public wrappers: arbitrary-shape blockwise int8 round trip.

``interpret=None`` (the default) auto-detects the backend — the kernel
lowers natively on TPU/GPU and runs under the Pallas interpreter
elsewhere (``repro.kernels.backend``), so nothing is silently
interpreted on real hardware.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.quant8 import kernel as K
from repro.kernels.quant8 import ref as R


def quantize(x: jax.Array, block: int = 64, *, use_kernel: bool = True,
             interpret: Optional[bool] = None):
    """Any-shape x -> (codes [nb, block] int8, scales [nb,1] f32, meta)."""
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    if use_kernel:
        q, s = K.quantize(flat, block, resolve_interpret(interpret))
    else:
        q, s = R.quantize_ref(flat, block)
    return q, s, (shape, dtype, pad)


def dequantize(q, s, meta, *, use_kernel: bool = True,
               interpret: Optional[bool] = None):
    shape, dtype, pad = meta
    if use_kernel:
        flat = K.dequantize(q, s, dtype,
                            resolve_interpret(interpret)).reshape(-1)
    else:
        flat = R.dequantize_ref(q, s, dtype).reshape(-1)
    if pad:
        flat = flat[:flat.shape[0] - pad]
    return flat.reshape(shape)


def roundtrip(x: jax.Array, block: int = 64, *, use_kernel: bool = True,
              interpret: Optional[bool] = None) -> jax.Array:
    q, s, meta = quantize(x, block, use_kernel=use_kernel,
                          interpret=interpret)
    return dequantize(q, s, meta, use_kernel=use_kernel,
                      interpret=interpret)
