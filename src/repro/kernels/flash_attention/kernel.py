"""Pallas TPU kernel: flash attention forward (FlashAttention-2 schedule).

TPU mapping (vs the CUDA original — see DESIGN.md §3 hardware adaptation):
  * grid = (B*KV*G, nq, nk); the innermost ``nk`` axis iterates key blocks
    for a fixed query block, so the (m, l, acc) online-softmax state lives
    in VMEM scratch that persists across ``nk`` steps — the TPU analogue of
    FA2's per-CTA registers.
  * BlockSpec tiles: q [1, BQ, D], k/v [1, BK, D] with BQ/BK multiples of
    the (8,128) VPU layout and D = head_dim (128-aligned in every assigned
    arch); the two matmuls per tile hit the MXU at [BQ,D]x[D,BK] and
    [BQ,BK]x[BK,Dv].
  * causal masking via block-level position arithmetic (fully-masked key
    blocks still execute — Pallas grids are static; the Splash-style
    skip is a further optimization, noted in EXPERIMENTS.md §Perf).

GQA is handled by flattening (B, KV, G) into the leading grid axis and
indexing k/v with ``h // G``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 512


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                      scale, causal, window, nk, bq, bk, sq, sk,
                      lse_ref=None):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0]                                   # [bq, D]
    k = k_ref[0]                                   # [bk, D]
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [bq, bk]

    qpos = (sk - sq) + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kpos < sk
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1, keepdims=True)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_sc[...]
                    / jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)
        if lse_ref is not None:     # log-sum-exp residual for the backward
            lse_ref[0] = (m_sc[...] + jnp.log(
                jnp.maximum(l_sc[...], 1e-30)))[:, 0]


def _flash_fwd_kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref,
                          m_sc, l_sc, acc_sc, **kw):
    _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
                      lse_ref=lse_ref, **kw)


@functools.partial(jax.jit,
                   static_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention_fwd(q, k, v, causal=True, window=0, scale=None,
                        block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                        interpret=None, with_lse: bool = False):
    """q [B,Sq,H,D], k/v [B,Sk,KV,Dv] -> [B,Sq,H,Dv].

    ``interpret=None`` auto-detects the backend (interpret mode only off
    TPU/GPU).  ``with_lse=True`` additionally returns the per-query
    log-sum-exp ``[B, KV, G, Sq]`` — the residual the FlashAttention-2
    backward (``repro.models.flash._flash_bwd``) recomputes tiles from.
    """
    from repro.kernels.backend import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    scale = D ** -0.5 if scale is None else scale

    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Sk + pk) // bk

    # [B*H, S, D] views; kv indexed by h // G
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq + pq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pk, Dv)

    body = _flash_fwd_kernel_lse if with_lse else _flash_fwd_kernel
    kernel = functools.partial(
        body, scale=scale, causal=causal, window=window,
        nk=nk, bq=bq, bk=bk, sq=Sq, sk=Sk)

    out_specs = pl.BlockSpec((1, bq, Dv), lambda h, qi, ki: (h, qi, 0))
    out_shape = jax.ShapeDtypeStruct((B * H, Sq + pq, Dv), v.dtype)
    if with_lse:
        out_specs = [out_specs,
                     pl.BlockSpec((1, bq), lambda h, qi, ki: (h, qi))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((B * H, Sq + pq), jnp.float32)]

    res = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda h, qi, ki, _G=G: (h // _G, ki, 0)),
            pl.BlockSpec((1, bk, Dv),
                         lambda h, qi, ki, _G=G: (h // _G, ki, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom l
            pltpu.VMEM((bq, Dv), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = res[0] if with_lse else res
    out = out.reshape(B, H, Sq + pq, Dv).transpose(0, 2, 1, 3)[:, :Sq]
    if not with_lse:
        return out
    # [B*H, Sq] -> [B, KV, G, Sq]: H splits as (KV, G) with h = kv*G + g,
    # matching the jnp oracle's lse layout (models.flash._flash_fwd_impl)
    lse = res[1].reshape(B, KV, G, Sq + pq)[..., :Sq]
    return out, lse
