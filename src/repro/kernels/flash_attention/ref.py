"""Pure-jnp oracle for the Pallas flash-attention kernel (forward)."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q [B,Sq,H,D], k/v [B,Sk,KV,D(v)] -> [B,Sq,H,Dv]."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = (Sk - Sq) + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(v.dtype)
