"""Pure-jnp oracle for fused RMSNorm."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = (x32 ** 2).mean(-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
