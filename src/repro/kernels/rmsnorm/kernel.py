"""Pallas TPU kernel: fused RMSNorm.

One grid step normalizes a [ROW_TILE, d] tile: the f32 upcast, mean-square
reduction, rsqrt and scale all stay in VMEM/VREGs — the unfused jnp version
round-trips an f32 copy of the activation through HBM (2.5x the bytes).
``d`` is the full model dim (128-aligned for every assigned arch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)              # [ROW_TILE, d]
    var = jnp.mean(x * x, axis=1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnums=(2, 3))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            interpret=None) -> jax.Array:
    """x [..., d] -> rmsnorm(x) * scale.  ``interpret=None`` auto-detects
    the backend (interpret mode only off TPU/GPU)."""
    from repro.kernels.backend import resolve_interpret
    interpret = resolve_interpret(interpret)
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xr = x.reshape(rows, d)
    row_tile = min(ROW_TILE, rows)
    while rows % row_tile:
        row_tile //= 2
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xr, scale.reshape(1, d))
    return out.reshape(shape)
