from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_ref"]
