"""RMSNorm ops: the raw forward kernel plus the training-time custom VJP.

``rmsnorm_train`` is the hot-path op ``repro.models.layers.apply_norm``
routes through under ``cfg.kernels == "pallas"``: the forward is the
fused Pallas kernel (one pass over the activation instead of the
unfused f32 round trip), the backward is the closed-form RMSNorm
gradient in plain jnp — with ``r = rsqrt(mean(x^2) + eps)`` and scale
``s``:

    dx = g * s * r - x * (r^3 / d) * sum_j(g_j * s_j * x_j)
    ds = sum_rows g * x * r

so autodiff never differentiates through the pallas_call itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_train(x: jax.Array, scale: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
    """Differentiable fused RMSNorm: pallas forward, analytic backward."""
    return rmsnorm(x, scale, eps)


def _rms_fwd(x, scale, eps):
    return rmsnorm(x, scale, eps), (x, scale)


def _rms_bwd(eps, res, g):
    x, scale = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    s32 = scale.astype(jnp.float32)
    d = x.shape[-1]
    r = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    gs = g32 * s32                                      # [..., d]
    inner = jnp.sum(gs * x32, axis=-1, keepdims=True)   # sum_j g_j s_j x_j
    dx = gs * r - x32 * (r ** 3 / d) * inner
    ds = jnp.sum((g32 * x32 * r).reshape(-1, d), axis=0)
    return dx.astype(x.dtype), ds.astype(scale.dtype)


rmsnorm_train.defvjp(_rms_fwd, _rms_bwd)

__all__ = ["rmsnorm", "rmsnorm_ref", "rmsnorm_train"]
