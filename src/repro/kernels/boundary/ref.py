"""jnp oracles for the fused boundary-codec crossing.

These define the semantics the Pallas kernels must reproduce (property-
tested in ``tests/test_pallas_path.py``) and double as the CPU fallback
and the backward-pass recompute target — the fused ops' custom VJPs
pull cotangents back through THESE functions on both backends, so
``kernels="pallas"`` and ``kernels="jnp"`` produce identical gradients
by construction.

Wire quantization is *row-blocked*: the trailing (feature) dim of the
wire tensor splits into blocks of ``wire_qblock(width)`` elements, each
scaled by its absmax and rounded to int8 — the same Dettmers-2021 math
as ``repro.compression.quant8``, but aligned to the wire rows so one
kernel tile quantizes what it just encoded.  (The flat d-dim ``int8``
boundary mode keeps quant8's layout exactly: its flat [n/block, block]
view IS the row-blocked case with width == block.)
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compression.bottleneck import _ln

Tree = Any

QBLOCK = 64          # default quantization granularity (paper-faithful)


def wire_qblock(width: int, block: int = QBLOCK) -> int:
    """Largest block <= ``block`` that divides the wire width — ``block``
    itself when it divides, else gcd (e.g. c=16 -> one block per row)."""
    if width % block == 0:
        return block
    return math.gcd(width, block)


# ------------------------------------------------------------------- QDQ
def qdq_ref(x: jax.Array, qb: int) -> jax.Array:
    """Row-blocked int8 quantize-dequantize along the trailing dim
    (``x.shape[-1] % qb == 0``); absmax scaling, clip to [-127, 127]."""
    shape, dtype = x.shape, x.dtype
    blocks = x.astype(jnp.float32).reshape(*shape[:-1], shape[-1] // qb, qb)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12) * 127.0),
                 -127, 127)
    return (q * scale / 127.0).reshape(shape).astype(dtype)


# ------------------------------------------------------------ codec sides
def encode_ref(x: jax.Array, w: Optional[jax.Array], mode: str,
               k: int) -> jax.Array:
    """Sending side: [..., d] -> [..., c] (bottleneck: ln -> @w_c -> ln;
    maxout: ln -> max-pool over windows of ``k``)."""
    if mode == "bottleneck":
        return _ln(_ln(x) @ w.astype(x.dtype))
    if mode == "maxout":
        z = _ln(x)
        m = z.shape[-1]
        return z.reshape(*z.shape[:-1], m // k, k).max(-1)
    raise ValueError(f"not a learned codec: {mode!r}")


def decode_ref(z: jax.Array, w: jax.Array, mode: str) -> jax.Array:
    """Receiving side: [..., c] -> [..., d]."""
    if mode == "bottleneck":
        return z @ w.astype(z.dtype)
    if mode == "maxout":
        return _ln(z) @ w.astype(z.dtype)
    raise ValueError(f"not a learned codec: {mode!r}")


# ----------------------------------------------- true wire (codes) format
def encode_quantize_ref(x: jax.Array, w: Optional[jax.Array], mode: str,
                        k: int, qb: int):
    """Encode + quantize to the actual wire payload: (int8 codes
    [..., c], f32 scales [..., c//qb])."""
    z = encode_ref(x, w, mode, k).astype(jnp.float32)
    blocks = z.reshape(*z.shape[:-1], z.shape[-1] // qb, qb)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12) * 127.0),
                 -127, 127).astype(jnp.int8)
    return q.reshape(z.shape), scale[..., 0]


def dequantize_decode_ref(q: jax.Array, s: jax.Array, w: jax.Array,
                          mode: str, qb: int,
                          dtype=jnp.float32) -> jax.Array:
    """Mirror of :func:`encode_quantize_ref`: codes + scales -> decoded
    [..., d] hidden state."""
    blocks = q.astype(jnp.float32).reshape(
        *q.shape[:-1], q.shape[-1] // qb, qb)
    z = (blocks * s[..., None] / 127.0).reshape(q.shape).astype(dtype)
    return decode_ref(z, w, mode)
