"""Autodiff-aware fused boundary-crossing ops.

Each op pairs a forward (fused Pallas kernel under ``use_kernel=True``,
the jnp oracle otherwise) with ONE shared jnp backward that pulls
cotangents through :mod:`repro.kernels.boundary.ref` — so switching
``cfg.kernels`` between ``"jnp"`` and ``"pallas"`` changes launch count,
never gradients.

Wire-quantization semantics mirror ``quant8.compress_boundary``: the
QDQ is straight-through (rounding contributes no gradient), and under
``quantized=True`` the *cotangent* is QDQ'd too — that is what actually
crosses the wire in SWARM both directions (§4.3).  The backward QDQ
lives on the sending side's :func:`encode_wire` only, so splitting a
crossing across two peers (elastic path) or composing it in one program
(GSPMD path) quantizes each direction exactly once.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.boundary import kernel as K
from repro.kernels.boundary import ref as R

QBLOCK = R.QBLOCK
wire_qblock = R.wire_qblock


# ------------------------------------------------------------ int8 wire
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def int8_roundtrip(x: jax.Array, block: int = QBLOCK,
                   grad_block: int = QBLOCK,
                   use_kernel: bool = True) -> jax.Array:
    """Fused single-launch ``quant8.compress_boundary``: flat blockwise
    int8 QDQ forward, QDQ'd cotangent backward (STE)."""
    if jax.numpy.issubdtype(x.dtype, jax.numpy.integer):
        return x
    return K.qdq_flat(x, block) if use_kernel else _flat_ref(x, block)


def _flat_ref(x, block):
    from repro.compression.quant8 import _roundtrip
    return _roundtrip(x, block)


def _i8_fwd(x, block, grad_block, use_kernel):
    return int8_roundtrip(x, block, grad_block, use_kernel), None


def _i8_bwd(block, grad_block, use_kernel, _, g):
    out = K.qdq_flat(g, grad_block) if use_kernel else _flat_ref(
        g, grad_block)
    return (out,)


int8_roundtrip.defvjp(_i8_fwd, _i8_bwd)


# ---------------------------------------------------------- learned wire
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def encode_wire(x: jax.Array, w: Optional[jax.Array], mode: str, k: int,
                qb: int, quantized: bool, use_kernel: bool) -> jax.Array:
    """Sending side of a boundary crossing: codec encode [..., d] ->
    [..., c] with the wire QDQ fused in when ``quantized``.  ``w`` is
    ``w_c`` for the bottleneck, ``None`` for maxout."""
    if use_kernel:
        return K.encode(x, w, mode, k, qb, quantized)
    z = R.encode_ref(x, w, mode, k)
    return R.qdq_ref(z, qb) if quantized else z


def _enc_fwd(x, w, mode, k, qb, quantized, use_kernel):
    return encode_wire(x, w, mode, k, qb, quantized, use_kernel), (x, w)


def _enc_bwd(mode, k, qb, quantized, use_kernel, res, g):
    x, w = res
    if quantized:                 # the backward wire is quantized too
        g = R.qdq_ref(g, qb)
    _, vjp = jax.vjp(lambda x_, w_: R.encode_ref(x_, w_, mode, k), x, w)
    return vjp(g)


encode_wire.defvjp(_enc_fwd, _enc_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def decode_wire(z: jax.Array, w: jax.Array, mode: str,
                use_kernel: bool) -> jax.Array:
    """Receiving side: [..., c] wire -> [..., d].  No QDQ here — the
    backward-direction wire quantization happens exactly once, at the
    sender's :func:`encode_wire` VJP."""
    if use_kernel:
        return K.decode(z, w, mode)
    return R.decode_ref(z, w, mode)


def _dec_fwd(z, w, mode, use_kernel):
    return decode_wire(z, w, mode, use_kernel), (z, w)


def _dec_bwd(mode, use_kernel, res, g):
    z, w = res
    _, vjp = jax.vjp(lambda z_, w_: R.decode_ref(z_, w_, mode), z, w)
    return vjp(g)


decode_wire.defvjp(_dec_fwd, _dec_bwd)


# ----------------------------------------------- true wire (codes) format
def encode_quantize(x, w, mode, k, qb, use_kernel=True):
    """Fused encode + quantize to the actual payload (int8 codes + f32
    scales) — what a real transport would put on the wire."""
    if use_kernel:
        return K.encode_quantize(x, w, mode, k, qb)
    return R.encode_quantize_ref(x, w, mode, k, qb)


def dequantize_decode(q, s, w, mode, qb, dtype=None, use_kernel=True):
    """Mirror fused dequantize + decode from wire codes + scales."""
    import jax.numpy as jnp
    dtype = jnp.float32 if dtype is None else dtype
    if use_kernel:
        return K.dequantize_decode(q, s, w, mode, qb, dtype)
    return R.dequantize_decode_ref(q, s, w, mode, qb, dtype)
