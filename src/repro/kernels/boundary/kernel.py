"""Pallas TPU kernels: the fused boundary-codec crossing (paper App. J
codecs + §4.3 quantize-on-send in one kernel launch per direction).

The two-pass jnp sequence this replaces (``compression/codecs.py`` +
``dist/pipeline.py::boundary_crossing``) materializes the c-dim wire
tensor in HBM between the codec matmul and the quantizer; here one grid
step loads a [ROW_TILE, d] activation tile into VMEM, runs LayerNorm ->
``w_c`` matmul (or maxout pooling) -> LayerNorm -> blockwise-int8
quantize entirely in registers/VMEM, and writes only the wire payload.
The mirror kernel dequantizes + decodes on the receiving side.

TPU mapping: rows = flattened (batch x seq) tokens, tiled at ROW_TILE;
``w_c``/``w_d`` ride along whole (c is small — the wire width), so the
matmuls hit the MXU at [ROW_TILE, d] x [d, c].  Quantization blocks
(``qb``) subdivide the trailing wire dim, matching
``repro.kernels.boundary.ref`` bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

ROW_TILE = 128


def _row_tile(rows: int) -> int:
    t = min(ROW_TILE, rows)
    while rows % t:
        t //= 2
    return t


def _ln32(x32: jax.Array) -> jax.Array:
    """LayerNorm core on an f32 tile (mirrors compression.bottleneck._ln)."""
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return (x32 - mu) * jax.lax.rsqrt(var + 1e-6)


def _qdq32(z32: jax.Array, qb: int) -> jax.Array:
    """In-register row-blocked int8 round trip on an f32 tile."""
    rows, c = z32.shape
    blocks = z32.reshape(rows, c // qb, qb)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12) * 127.0),
                 -127, 127)
    return (q * scale / 127.0).reshape(rows, c)


def _quant32(z32: jax.Array, qb: int):
    rows, c = z32.shape
    blocks = z32.reshape(rows, c // qb, qb)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12) * 127.0),
                 -127, 127)
    return q.reshape(rows, c).astype(jnp.int8), scale[..., 0]


def _encode32(x, w_ref, *, mode, k):
    """Codec encode on one tile, mirroring boundary.ref.encode_ref's
    dtype discipline (f32 norm cores, matmul in the activation dtype)."""
    dt = x.dtype
    z = _ln32(x.astype(jnp.float32)).astype(dt)
    if mode == "bottleneck":
        z = jnp.dot(z, w_ref[...].astype(dt))
        z = _ln32(z.astype(jnp.float32)).astype(dt)
    else:                                        # maxout: param-free pool
        rows, d = z.shape
        z = z.reshape(rows, d // k, k).max(-1)
    return z


def _decode32(z, w_ref, *, mode):
    dt = z.dtype
    if mode == "maxout":
        z = _ln32(z.astype(jnp.float32)).astype(dt)
    return jnp.dot(z, w_ref[...].astype(dt))


# ----------------------------------------------------------- kernel bodies
def _qdq_kernel(x_ref, o_ref, *, qb):
    x = x_ref[...]
    o_ref[...] = _qdq32(x.astype(jnp.float32), qb).astype(o_ref.dtype)


def _encode_kernel(x_ref, w_ref, o_ref, *, mode, k, qb, quantize):
    z = _encode32(x_ref[...], w_ref, mode=mode, k=k)
    if quantize:
        z = _qdq32(z.astype(jnp.float32), qb).astype(z.dtype)
    o_ref[...] = z.astype(o_ref.dtype)


def _encode_nw_kernel(x_ref, o_ref, *, mode, k, qb, quantize):
    z = _encode32(x_ref[...], None, mode=mode, k=k)
    if quantize:
        z = _qdq32(z.astype(jnp.float32), qb).astype(z.dtype)
    o_ref[...] = z.astype(o_ref.dtype)


def _encode_quant_kernel(x_ref, w_ref, q_ref, s_ref, *, mode, k, qb):
    z = _encode32(x_ref[...], w_ref, mode=mode, k=k)
    q, s = _quant32(z.astype(jnp.float32), qb)
    q_ref[...], s_ref[...] = q, s


def _encode_quant_nw_kernel(x_ref, q_ref, s_ref, *, mode, k, qb):
    z = _encode32(x_ref[...], None, mode=mode, k=k)
    q, s = _quant32(z.astype(jnp.float32), qb)
    q_ref[...], s_ref[...] = q, s


def _decode_kernel(z_ref, w_ref, o_ref, *, mode):
    o_ref[...] = _decode32(z_ref[...], w_ref, mode=mode).astype(o_ref.dtype)


def _dequant_decode_kernel(q_ref, s_ref, w_ref, o_ref, *, mode, qb):
    rows, c = q_ref.shape
    blocks = q_ref[...].astype(jnp.float32).reshape(rows, c // qb, qb)
    z = (blocks * s_ref[...][..., None] / 127.0).reshape(rows, c)
    z = z.astype(o_ref.dtype)
    o_ref[...] = _decode32(z, w_ref, mode=mode).astype(o_ref.dtype)


# ------------------------------------------------------------- call plumbing
def _rows_call(body, x2d, w, out_shapes, interpret):
    """Tile the leading (rows) dim; any ``w`` rides along whole."""
    rows = x2d.shape[0]
    t = _row_tile(rows)
    in_specs = [pl.BlockSpec((t, x2d.shape[1]), lambda i: (i, 0))]
    args = [x2d]
    if w is not None:
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        args.append(w)
    single = not isinstance(out_shapes, (list, tuple))
    outs = [out_shapes] if single else list(out_shapes)
    out_specs = [pl.BlockSpec((t, o.shape[1]), lambda i: (i, 0))
                 for o in outs]
    res = pl.pallas_call(
        body, grid=(rows // t,), in_specs=in_specs,
        out_specs=out_specs[0] if single else out_specs,
        out_shape=outs[0] if single else outs,
        interpret=resolve_interpret(interpret),
    )(*args)
    return res


def _flatten_rows(x: jax.Array):
    c = x.shape[-1]
    return x.reshape(-1, c), x.shape


# ------------------------------------------------------------- public ops
def qdq(x: jax.Array, qb: int, interpret: Optional[bool] = None):
    """Fused single-pass row-blocked int8 round trip over the trailing
    dim (the two quant8 kernel launches collapsed into one)."""
    x2d, shape = _flatten_rows(x)
    out = _rows_call(functools.partial(_qdq_kernel, qb=qb), x2d, None,
                     jax.ShapeDtypeStruct(x2d.shape, x.dtype), interpret)
    return out.reshape(shape)


def qdq_flat(x: jax.Array, block: int, interpret: Optional[bool] = None):
    """Flat-blocked fused round trip matching
    ``compression.quant8._roundtrip`` exactly (any shape; pads the tail
    block with zeros, which never raises an absmax)."""
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = qdq(flat.reshape(-1, block), block, interpret).reshape(-1)
    if pad:
        out = out[:out.shape[0] - pad]
    return out.reshape(shape).astype(dtype)


def encode(x: jax.Array, w: Optional[jax.Array], mode: str, k: int,
           qb: int, quantize: bool,
           interpret: Optional[bool] = None) -> jax.Array:
    """Fused codec encode (+ optional in-kernel QDQ): [..., d] -> the
    [..., c] float wire tensor, one kernel launch."""
    x2d, shape = _flatten_rows(x)
    c = x2d.shape[1] // k if mode == "maxout" else w.shape[1]
    out_shape = jax.ShapeDtypeStruct((x2d.shape[0], c), x.dtype)
    if mode == "maxout":
        body = functools.partial(_encode_nw_kernel, mode=mode, k=k, qb=qb,
                                 quantize=quantize)
        out = _rows_call(body, x2d, None, out_shape, interpret)
    else:
        body = functools.partial(_encode_kernel, mode=mode, k=k, qb=qb,
                                 quantize=quantize)
        out = _rows_call(body, x2d, w, out_shape, interpret)
    return out.reshape(*shape[:-1], c)


def encode_quantize(x: jax.Array, w: Optional[jax.Array], mode: str,
                    k: int, qb: int, interpret: Optional[bool] = None):
    """Fused encode + quantize emitting the actual wire payload:
    (int8 codes [..., c], f32 scales [..., c//qb])."""
    x2d, shape = _flatten_rows(x)
    c = x2d.shape[1] // k if mode == "maxout" else w.shape[1]
    outs = [jax.ShapeDtypeStruct((x2d.shape[0], c), jnp.int8),
            jax.ShapeDtypeStruct((x2d.shape[0], c // qb), jnp.float32)]
    if mode == "maxout":
        body = functools.partial(_encode_quant_nw_kernel, mode=mode, k=k,
                                 qb=qb)
        q, s = _rows_call(body, x2d, None, outs, interpret)
    else:
        body = functools.partial(_encode_quant_kernel, mode=mode, k=k,
                                 qb=qb)
        q, s = _rows_call(body, x2d, w, outs, interpret)
    return (q.reshape(*shape[:-1], c),
            s.reshape(*shape[:-1], c // qb))


def decode(z: jax.Array, w: jax.Array, mode: str,
           interpret: Optional[bool] = None) -> jax.Array:
    """Fused codec decode: [..., c] float wire -> [..., d]."""
    z2d, shape = _flatten_rows(z)
    d = w.shape[1]
    out = _rows_call(functools.partial(_decode_kernel, mode=mode), z2d, w,
                     jax.ShapeDtypeStruct((z2d.shape[0], d), z.dtype),
                     interpret)
    return out.reshape(*shape[:-1], d)


def dequantize_decode(q: jax.Array, s: jax.Array, w: jax.Array, mode: str,
                      qb: int, dtype=jnp.float32,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Mirror of :func:`encode_quantize`: one kernel launch from wire
    codes + scales to the decoded [..., d] hidden state."""
    c = q.shape[-1]
    q2d = q.reshape(-1, c)
    s2d = s.reshape(-1, c // qb)
    d = w.shape[1]
    rows = q2d.shape[0]
    t = _row_tile(rows)
    out = pl.pallas_call(
        functools.partial(_dequant_decode_kernel, mode=mode, qb=qb),
        grid=(rows // t,),
        in_specs=[pl.BlockSpec((t, c), lambda i: (i, 0)),
                  pl.BlockSpec((t, c // qb), lambda i: (i, 0)),
                  pl.BlockSpec(w.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), dtype),
        interpret=resolve_interpret(interpret),
    )(q2d, s2d, w)
    return out.reshape(*q.shape[:-1], d)
