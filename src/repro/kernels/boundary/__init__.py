# Fused boundary-codec crossing kernels: codec encode (w_c matmul or
# maxout) + blockwise-int8 quantize in ONE Pallas kernel, and the mirror
# dequantize + decode on the receiving side.  repro.compression.codecs
# dispatches here under cfg.kernels == "pallas".
