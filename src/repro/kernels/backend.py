"""Backend policy for the Pallas kernels: when to run in interpret mode.

Pallas kernels lower natively on TPU/GPU; everywhere else (the CPU CI
runners, laptops) they must run under ``interpret=True`` — the Pallas
interpreter executes the kernel body with plain jax ops, trading speed
for portability.  Every kernel wrapper in ``repro.kernels`` takes
``interpret=None`` and resolves it here at trace time, so the same call
site compiles the real kernel on an accelerator and the interpreted one
on CPU — nothing is *silently* interpreted on real hardware (the bug
this module fixes: ``interpret=True`` unconditionally).
"""
from __future__ import annotations

from typing import Optional

import jax

# Backends with a native Pallas lowering (Mosaic / Triton).
_NATIVE_BACKENDS = ("tpu", "gpu")


def default_interpret() -> bool:
    """True iff the default jax backend has no native Pallas lowering."""
    return jax.default_backend() not in _NATIVE_BACKENDS


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> auto-detect; an explicit bool is honored as-is."""
    return default_interpret() if interpret is None else bool(interpret)
