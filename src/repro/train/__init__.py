from repro.train.steps import (
    make_train_step, make_serve_step, make_loss_fn, input_specs,
    make_abstract_state, cross_entropy,
)

__all__ = ["make_train_step", "make_serve_step", "make_loss_fn",
           "input_specs", "make_abstract_state", "cross_entropy"]
