"""train_step / serve_step builders + ShapeDtypeStruct input specs.

``input_specs(cfg, shape)`` is the dry-run contract: weak-type-correct,
shardable stand-ins for every model input — no device allocation ever
happens for the full configs.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import model as model_lib
from repro.models import whisper as whisper_lib
from repro.models.config import ArchConfig
from repro.models import params as P
from repro.optim.adamw import Optimizer

Tree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Stable mean token CE; logits [B,S,V] (any float), labels [B,S] int.

    The gold logit is extracted with a one-hot contraction instead of
    ``take_along_axis``: a gather along a model-sharded vocab dim would
    force GSPMD to all-gather the full logits; the contraction partitions
    as partial sums + a small all-reduce.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(lse - gold)


def model_specs(cfg: ArchConfig) -> Tree:
    if cfg.family == "audio":
        return whisper_lib.whisper_specs(cfg)
    specs = model_lib.lm_specs(cfg)
    # configs that declare a pipeline depth + a learned boundary codec own
    # one (w_c, w_d) pair per stage boundary as first-class trainable
    # params — the GSPMD pipeline consumes them; the plain step carries
    # them with zero GRADIENTS (same tree shape through both paths), but
    # the optimizer still applies weight decay to them — don't train a
    # codec config through the plain step and expect pristine codecs
    from repro.compression import codecs   # lazy: codecs imports params
    boundary = codecs.pipeline_boundary_specs(cfg)
    if boundary is not None:
        specs["boundary"] = boundary
    return specs


def make_loss_fn(cfg: ArchConfig, remat: bool | str = True):
    def loss_fn(params: Tree, batch: Tree):
        if cfg.family == "audio":
            logits, aux = whisper_lib.whisper_apply(cfg, params, batch, remat)
        else:
            logits, aux = model_lib.lm_apply(
                cfg, params, batch["tokens"], batch.get("positions"),
                remat=remat)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, ce
    return loss_fn


def _split_microbatches(batch: Tree, accum: int) -> Tree:
    def split(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "positions":                       # [3, B, S]
            return a.reshape(a.shape[0], accum, a.shape[1] // accum,
                             *a.shape[2:]).swapaxes(0, 1)
        return a.reshape(accum, a.shape[0] // accum, *a.shape[1:])
    return jax.tree_util.tree_map_with_path(split, batch)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    remat: bool | str = True, accum: int = 1):
    """accum > 1: gradient accumulation over ``accum`` microbatches —
    activation working set scales with B/accum at zero extra FLOPs (the
    fp32 grad buffer costs one param-sized f32 tree)."""
    loss_fn = make_loss_fn(cfg, remat)

    def train_step(state: Tree, batch: Tree):
        params = state["params"]
        if accum == 1:
            (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            mbs = _split_microbatches(batch, accum)

            def mb_step(acc, mb):
                (l, c), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                   acc, g)
                return acc, (l, c)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gacc, (ls, cs) = jax.lax.scan(mb_step, zeros, mbs)
            grads = jax.tree.map(lambda a: a / accum, gacc)
            loss, ce = ls.mean(), cs.mean()
        updates, opt = optimizer.update(grads, state["opt"], params)
        new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
        new_state = {"params": new_params, "opt": opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "ce": ce}

    return train_step


def make_prefill_step(cfg: ArchConfig, remat: bool = True,
                      last_only: bool = True,
                      cache_len: Optional[int] = None):
    """Inference prefill: forward + decode-cache emission + first token.

    ``cache_len`` sizes the emitted caches for the session's full
    horizon (prompt + generated), so decode steps write in place —
    defaults to the prompt length (the historical behavior, which then
    needs cache re-padding before decoding further)."""
    def prefill_step(params: Tree, batch: Tree):
        if cfg.family == "audio":
            logits, caches = whisper_lib.whisper_prefill(
                cfg, params, batch, cache_len=cache_len, remat=remat,
                last_only=last_only)
        else:
            logits, caches = model_lib.lm_prefill(
                cfg, params, batch["tokens"], batch.get("positions"),
                cache_len=cache_len, remat=remat, last_only=last_only)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, caches

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One greedy decode step: (params, caches, token [B,1], pos) ->
    (next_token [B,1], caches)."""
    def serve_step(params: Tree, caches: Tree, token: jax.Array,
                   pos: jax.Array):
        if cfg.family == "audio":
            logits, caches = whisper_lib.whisper_decode_step(
                cfg, params, token, caches, pos)
        else:
            logits, caches = model_lib.lm_decode_step(
                cfg, params, token, caches, pos)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(token.dtype)
        return nxt, caches

    return serve_step


def make_state(cfg: ArchConfig, optimizer: Optimizer,
               key: jax.Array) -> Tree:
    specs = model_specs(cfg)
    params = P.init(key, specs)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_abstract_state(cfg: ArchConfig) -> Tree:
    """ShapeDtypeStruct train state for the dry-run (no allocation)."""
    specs = model_specs(cfg)
    aparams = P.abstract(specs)
    m = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                     aparams)
    return {"params": aparams,
            "opt": {"m": m, "v": jax.tree.map(lambda x: x, m),
                    "count": jax.ShapeDtypeStruct((), jnp.int32)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ------------------------------------------------------------ input specs
def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec,
                      labels: bool = True) -> Tree:
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch: Tree = {"tokens": tok}
    if labels:
        batch["labels"] = tok
    if cfg.rope == "mrope":
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if cfg.family == "audio":
        # frontend stub supplies precomputed frame embeddings
        enc = min(S, cfg.encoder_max_len)
        batch["audio_embed"] = jax.ShapeDtypeStruct(
            (B, enc, cfg.d_model), cfg.compute_jdtype)
    return batch


def decode_cache_param_specs(cfg: ArchConfig, shape: ShapeSpec) -> Tree:
    """Raw ParamSpec tree (carries logical axes for sharding rules)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return whisper_lib.whisper_cache_specs(cfg, B, S)
    return model_lib.lm_cache_specs(cfg, B, S)


def decode_cache_specs(cfg: ArchConfig, shape: ShapeSpec) -> Tree:
    return P.abstract(decode_cache_param_specs(cfg, shape))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Tree:
    """All inputs for the step function of this cell, as ShapeDtypeStructs."""
    if shape.kind == "train":
        return {"state": make_abstract_state(cfg),
                "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": P.abstract(model_specs(cfg)),
                "batch": train_batch_specs(cfg, shape, labels=False)}
    return {"params": P.abstract(model_specs(cfg)),
            "caches": decode_cache_specs(cfg, shape),
            "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
