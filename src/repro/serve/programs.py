"""Session programs: span-parameterized prefill/decode for swarm serving.

Training's unit of work is a microbatch crossing the pipeline once; a
serving *session* crosses it once per generated token, carrying a decode
cache per covered stage between crossings.  A :class:`SessionProgram` is
the serving analogue of :class:`repro.runtime.stage_model.SpanProgram`:
stages ``[lo, hi)`` fused into one jitted ``prefill`` and one jitted
``decode``, parameterized the same way (tuple of per-stage param trees,
ordered ``lo..hi-1``) so the same per-stage-keyed
:class:`~repro.runtime.base.StageState` backs both — the KV caches live
in the state's ``"kv"`` keyed slot next to ``"grads"`` and ``"opt"``,
and ride the exact churn machinery (snapshot/restore, per-stage
hand-offs, ``export_slot``/``install_slot``) grads and opt already do.

Caches are allocated at ``total_len`` (the session's full horizon) by
the prefill, so decode steps write in place — no cache re-padding ever
happens between prefill and decode, which is what retired the
``decode_cache_specs`` shuffle from ``examples/serve_pipeline.py``.

Like the stage/span programs, session programs are cached process-wide
(one prefill + one decode compile per ``(config, span, horizon, codec)``
— N peers of a span share the jits) and report XLA traces to the same
``repro.runtime.numeric`` counters, tagged ``"serve"``.

:func:`full_session_program` wraps the single-process model path
(``repro.train.steps.make_prefill_step`` / ``make_serve_step``) in the
same interface — the token-for-token reference the staged swarm is
tested against, and what ``examples/serve_pipeline.py`` runs.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.compression import codecs
from repro.models.blocks import REGISTRY
from repro.models.config import ArchConfig
from repro.runtime import numeric as numeric_rt
from repro.runtime.stage_model import (_head_logits, _stage_fwd_flops,
                                       _stage_runs)

Tree = Any

# the StageState keyed slot serving KV caches live in (keyed by session)
KV_SLOT = "kv"


@dataclasses.dataclass
class SessionProgram:
    """Stages ``[lo, hi)`` fused into one prefill + one decode jit.

    ``prefill(params, inp) -> (out, kv)`` — ``inp`` is the token batch
    ``[B, S]`` when the span covers stage 0, the inbound wire tensor
    otherwise; ``out`` is the first generated token ``[B, 1]`` when the
    span covers the last stage, the *full-sequence* outbound wire tensor
    otherwise (a downstream span prefills from it).  ``kv`` is a tuple
    of per-covered-stage cache trees, allocated at ``total_len``.

    ``decode(params, kv, inp, pos) -> (out, kv)`` — one token step;
    ``inp`` is ``[B, 1]`` tokens or the one-position wire tensor, ``pos``
    the scalar write position (shared across the batch: continuous
    batching is slot-granular, sequences in one session advance in
    lockstep).
    """
    span: tuple[int, int]
    n_stages: int
    total_len: int
    prefill: Callable             # jitted
    decode: Callable              # jitted
    flops_per_token: float        # forward flops, summed over the span
    prefill_fn: Optional[Callable] = None
    decode_fn: Optional[Callable] = None

    @property
    def stages(self) -> range:
        return range(*self.span)

    @property
    def covers_first(self) -> bool:
        return self.span[0] == 0

    @property
    def covers_last(self) -> bool:
        return self.span[1] == self.n_stages


# (cfg, n_stages, (lo, hi), total_len, comp) -> SessionProgram; plus the
# full-model reference programs under (cfg, "full", total_len, remat)
_SESSIONS: dict[tuple, SessionProgram] = {}
_LOCK = threading.Lock()


def reset_session_cache() -> None:
    with _LOCK:
        _SESSIONS.clear()


def _embed_in(cfg: ArchConfig, params: Tree, tokens) -> jax.Array:
    x = params["embed"][tokens].astype(cfg.compute_jdtype)
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    return x


def _make_stage_prefill(cfg: ArchConfig, s: int, n_stages: int,
                        comp: str, learned: bool) -> Callable:
    """Stage ``s``'s wire-to-wire prefill: same in/out framing as
    ``stage_model._make_stage_fwd`` (embed / codec at the edges), plus
    decode-cache emission at ``cache_len``."""
    _, runs, reps = _stage_runs(cfg, s, n_stages)
    is_first, is_last = s == 0, s == n_stages - 1

    def stage_prefill(params: Tree, inp, cache_len: int):
        if is_first:
            x = _embed_in(cfg, params, inp)
        else:
            x = inp.astype(cfg.compute_jdtype)
            if learned:
                x = codecs.decompress(cfg, comp, params.get("boundary"), x)
        positions = jnp.arange(x.shape[1])
        caches = []
        for (kind, _), seg_params in zip(runs, params["blocks"]):
            prefill_fn = REGISTRY[kind][4]

            def body(x, p_l, _pf=prefill_fn):
                y, _, cache = _pf(cfg, p_l, x, positions, cache_len)
                return y, cache

            if reps > 1:             # shared group applied `reps` times
                def group_body(x, p_g, _body=body):
                    cs = []
                    for _ in range(reps):
                        x, c = _body(x, p_g)
                        cs.append(c)
                    return x, jax.tree.map(lambda *a: jnp.stack(a), *cs)
                x, cs = jax.lax.scan(group_body, x, seg_params)
                cs = jax.tree.map(
                    lambda a: a.reshape(a.shape[0] * a.shape[1],
                                        *a.shape[2:]), cs)
            else:
                x, cs = jax.lax.scan(body, x, seg_params)
            caches.append(cs)
        if learned and not is_last:
            x = codecs.compress(cfg, comp, params.get("boundary"), x)
        return x, caches

    return stage_prefill


def _make_stage_decode(cfg: ArchConfig, s: int, n_stages: int,
                       comp: str, learned: bool) -> Callable:
    """Stage ``s``'s one-token decode against its caches (mirrors
    ``model.lm_decode_step``'s layer walk, wire-framed like the stage
    forward)."""
    _, runs, reps = _stage_runs(cfg, s, n_stages)
    is_first, is_last = s == 0, s == n_stages - 1

    def stage_decode(params: Tree, caches: Tree, inp, pos):
        if is_first:
            x = _embed_in(cfg, params, inp)
        else:
            x = inp.astype(cfg.compute_jdtype)
            if learned:
                x = codecs.decompress(cfg, comp, params.get("boundary"), x)
        B = x.shape[0]
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(pos, (3, B, 1))
        else:
            positions = jnp.broadcast_to(pos, (B, 1))
        new_caches = []
        for (kind, _), seg_params, seg_cache in zip(runs, params["blocks"],
                                                    caches):
            decode_fn = REGISTRY[kind][2]
            if reps > 1:
                def body(x, pc, _dec=decode_fn):
                    p_g, c_ls = pc      # group params + its [reps, ...] caches
                    def inner(x, c_l):
                        return _dec(cfg, p_g, x, c_l, pos, positions)
                    return jax.lax.scan(inner, x, c_ls)

                c_re = jax.tree.map(
                    lambda a: a.reshape(-1, reps, *a.shape[1:]), seg_cache)
                x, cs = jax.lax.scan(body, x, (seg_params, c_re))
                cs = jax.tree.map(
                    lambda a: a.reshape(a.shape[0] * reps, *a.shape[2:]),
                    cs)
            else:
                def body(x, pc, _dec=decode_fn):
                    p_l, c_l = pc
                    return _dec(cfg, p_l, x, c_l, pos, positions)
                x, cs = jax.lax.scan(body, x, (seg_params, seg_cache))
            new_caches.append(cs)
        if learned and not is_last:
            x = codecs.compress(cfg, comp, params.get("boundary"), x)
        return x, new_caches

    return stage_decode


def build_session_program(cfg: ArchConfig, n_stages: int,
                          span: tuple[int, int], total_len: int,
                          compress: Optional[str] = None,
                          trace_hook: Optional[Callable] = None
                          ) -> SessionProgram:
    lo, hi = span
    if not (0 <= lo < hi <= n_stages):
        raise ValueError(f"span [{lo}, {hi}) outside [0, {n_stages})")
    assert cfg.n_layers % n_stages == 0
    if cfg.family == "audio":
        raise NotImplementedError(
            "staged serving covers the LM families; audio serves through "
            "full_session_program")
    comp = codecs.resolve_mode(cfg, compress)
    learned = comp in codecs.LEARNED and n_stages > 1
    covers_last = hi == n_stages

    prefs = {s: _make_stage_prefill(cfg, s, n_stages, comp, learned)
             for s in range(lo, hi)}
    decs = {s: _make_stage_decode(cfg, s, n_stages, comp, learned)
            for s in range(lo, hi)}
    flops = sum(_stage_fwd_flops(cfg, s, n_stages, total_len, comp,
                                 learned) for s in range(lo, hi))

    def prefill_fn(params_by_stage, inp):
        x, kv = inp, []
        for i, s in enumerate(range(lo, hi)):
            x, caches = prefs[s](params_by_stage[i], x, total_len)
            kv.append(caches)
        kv = tuple(kv)
        if covers_last:
            logits = _head_logits(cfg, params_by_stage[-1], x[:, -1:])
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), kv
        return x, kv

    def decode_fn(params_by_stage, kv, inp, pos):
        x, new_kv = inp, []
        for i, s in enumerate(range(lo, hi)):
            x, caches = decs[s](params_by_stage[i], kv[i], x, pos)
            new_kv.append(caches)
        new_kv = tuple(new_kv)
        if covers_last:
            logits = _head_logits(cfg, params_by_stage[-1], x)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_kv
        return x, new_kv

    def traced(fn, kind):
        if trace_hook is None:
            return jax.jit(fn)

        def counted(*args):
            trace_hook((lo, hi), kind,
                       tuple(tuple(a.shape) for a in jax.tree.leaves(args)
                             if hasattr(a, "shape"))[:4])
            return fn(*args)
        return jax.jit(counted)

    return SessionProgram(
        span=(lo, hi), n_stages=n_stages, total_len=total_len,
        prefill=traced(prefill_fn, "prefill"),
        decode=traced(decode_fn, "decode"),
        flops_per_token=flops,
        prefill_fn=prefill_fn, decode_fn=decode_fn)


def get_session_program(cfg: ArchConfig, n_stages: int,
                        span: tuple[int, int], total_len: int,
                        compress: Optional[str] = None) -> SessionProgram:
    """The shared, counted session program for one span and horizon —
    one prefill/decode compile per ``(config, span, total_len, codec)``
    process-wide, same discipline as the stage/span program caches."""
    comp = codecs.resolve_mode(cfg, compress)
    key = (cfg, n_stages, tuple(span), total_len, comp)
    with _LOCK:
        prog = _SESSIONS.get(key)
    if prog is not None:
        return prog
    tag = (cfg.name, n_stages, total_len, comp, "serve")

    def hook(span_id, kind, shapes):
        numeric_rt.record_trace(tag + (span_id, kind, shapes))

    prog = build_session_program(cfg, n_stages, tuple(span), total_len,
                                 compress=comp, trace_hook=hook)
    with _LOCK:
        prog = _SESSIONS.setdefault(key, prog)
    return prog


def full_session_program(cfg: ArchConfig, total_len: int,
                         remat: bool = True) -> SessionProgram:
    """The whole model as one session program — the single-process
    reference path (``make_prefill_step``/``make_serve_step``) behind
    the same interface the staged spans expose.  ``kv`` is a 1-tuple
    (the model as one "stage")."""
    key = (cfg, "full", total_len, remat)
    with _LOCK:
        prog = _SESSIONS.get(key)
    if prog is not None:
        return prog
    from repro.train.steps import make_prefill_step, make_serve_step
    prefill_step = make_prefill_step(cfg, remat=remat, last_only=True,
                                     cache_len=total_len)
    serve_step = make_serve_step(cfg)

    def prefill_fn(params, tokens):
        nxt, caches = prefill_step(params, {"tokens": tokens})
        return nxt, (caches,)

    def decode_fn(params, kv, token, pos):
        nxt, caches = serve_step(params, kv[0], token, pos)
        return nxt.astype(jnp.int32), (caches,)

    tag = (cfg.name, 1, total_len, "none", "serve")

    def traced(fn, kind):
        def counted(*args):
            numeric_rt.record_trace(
                tag + ((0, 1), kind,
                       tuple(tuple(a.shape) for a in jax.tree.leaves(args)
                             if hasattr(a, "shape"))[:4]))
            return fn(*args)
        return jax.jit(counted)

    prog = SessionProgram(
        span=(0, 1), n_stages=1, total_len=total_len,
        prefill=traced(prefill_fn, "prefill"),
        decode=traced(decode_fn, "decode"),
        flops_per_token=(0.0 if cfg.family == "audio" else
                         _stage_fwd_flops(cfg, 0, 1, total_len, "none",
                                          False)),
        prefill_fn=prefill_fn, decode_fn=decode_fn)
    with _LOCK:
        prog = _SESSIONS.setdefault(key, prog)
    return prog
