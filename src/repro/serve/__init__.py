"""Elastic span serving over SWARM pipelines.

Layers on top of ``repro.runtime`` (the executors) and ``repro.core``
(the sim + swarm machinery): :mod:`repro.serve.programs` fuses a span's
prefill/decode into session programs whose KV caches live in the
executor-state ``"kv"`` keyed slot, and :mod:`repro.serve.runner` drives
sessions through a churning swarm — prefill/decode disaggregation,
slot-granular continuous batching, and KV-ledger-exact re-prefill of
only the stages a dead peer took with it.
"""
from repro.serve.programs import (KV_SLOT, SessionProgram,
                                  build_session_program,
                                  full_session_program,
                                  get_session_program)
from repro.serve.runner import (Request, ServeConfig, ServeRunner,
                                ServeStats)

__all__ = [
    "KV_SLOT", "SessionProgram", "build_session_program",
    "full_session_program", "get_session_program",
    "Request", "ServeConfig", "ServeRunner", "ServeStats",
]
