"""ServeRunner — elastic span serving over a simulated SWARM.

Training crosses the pipeline once per microbatch; serving crosses it
once per *generated token*, dragging a per-stage KV cache along.  This
runner drives sessions through churning span pools on the same discrete
event sim training uses (:mod:`repro.core.sim` / :class:`Peer`), with
three serving-specific mechanisms:

* **Prefill/decode disaggregation** — two span pools from
  :func:`repro.core.rebalance.serve_assignment`: narrow compute-optimal
  prefill spans (a boundary costs one prompt-sized transfer, amortized),
  wide decode spans (every host hop taxes every token).  After the
  prefill chain runs, each stage's cache crosses to its decode peer via
  the executor ``export_slot``/``install_slot`` wire and a
  :class:`~repro.core.ledger.SessionKVLedger` ``transfer`` — computed
  once, moved, never re-prefilled.

* **Slot-granular continuous batching** — requests with matching shape
  are stacked into batched sessions (``max_batch`` requests per slot,
  ``max_sessions`` slots decoding concurrently); a finishing session
  frees its slot for the next queued batch immediately, no global
  barrier between generations.

* **KV-exact recovery** — the runner records the wire tensor entering
  every hop (the prompt / full-sequence wire at prefill, one position
  per decode step).  When a decode peer dies, only *its* span
  re-prefills: a same-span replacement rebuilds rows ``[0, pos)`` from
  the recorded boundary history in one fused prefill, then the
  interrupted token step resumes at that hop with its recorded input.
  Surviving upstream/downstream spans never recompute, and the KV
  ledger's strict ``record`` turns any double-prefill into a hard error
  rather than silent waste.  (Recomputing the prefix into a *fresh*
  cache is what makes recovery cache-type-agnostic: attention rows
  rebuild bitwise, and recurrent/SSM states — which are not idempotent
  under re-applied decode steps — rebuild by the same scan prefill
  always runs.)

Virtual time advances by the device cost model (compute from the
session program's flops, wire from actual tensor bytes), so the bench
reports tokens/s and latency percentiles under churn without real
hardware.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.compression import codecs
from repro.core.ledger import SessionKVLedger
from repro.core.peer import T4, DeviceProfile, Peer, PeerFailure
from repro.core.rebalance import serve_assignment
from repro.core.sim import Sim, Sleep
from repro.models.config import ArchConfig
from repro.runtime.base import StageState
from repro.runtime.numeric import build_numeric_executors
from repro.runtime.stage_model import split_lm_params
from repro.serve.programs import KV_SLOT, full_session_program

Tree = Any

_REQ_IDS = itertools.count()


class SessionFailed(Exception):
    """No live route could finish the session within the retry budget."""


def _tree_nbytes(tree: Tree) -> float:
    return float(sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(tree)
                     if hasattr(x, "size")))


@dataclasses.dataclass
class Request:
    """One generation request: greedy-decode ``new_tokens`` after
    ``prompt``.  Filled in place as the swarm serves it."""
    prompt: np.ndarray                    # [S] int32 prompt token ids
    new_tokens: int
    id: int = dataclasses.field(default_factory=lambda: next(_REQ_IDS))
    arrival: float = 0.0
    done_at: Optional[float] = None
    tokens: Optional[np.ndarray] = None   # [new_tokens] generated ids
    failed: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_stages: int = 4
    max_batch: int = 2        # requests stacked into one session slot
    max_sessions: int = 2     # session slots decoding concurrently
    codec: str = "none"       # wire codec; "auto" = cfg.boundary_compression
    quant_block: int = 64
    retry_wait: float = 0.25  # backoff while a boundary has no live peer
    max_retries: int = 10     # per-hop failure budget before the session fails
    poll: float = 0.05        # scheduler tick


@dataclasses.dataclass
class ServeStats:
    completed: int = 0            # requests fully generated
    failed: int = 0               # requests lost to dead routes
    tokens: int = 0               # tokens generated (sum over requests)
    hop_failures: int = 0         # PeerFailure observed by sessions
    reprefills: int = 0           # recovery prefills (one per lost span)
    reprefilled_stages: int = 0   # stages rebuilt by those prefills
    kv_transfers: int = 0         # prefill -> decode cache hand-offs
    handoff_fallbacks: int = 0    # hand-offs voided by a dead prefill peer
    wire_bytes: float = 0.0
    latencies: list = dataclasses.field(default_factory=list)

    def percentile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))]

    def summary(self, elapsed: float) -> dict:
        return {
            "completed": self.completed, "failed": self.failed,
            "tokens": self.tokens, "elapsed_s": elapsed,
            "tokens_per_s": self.tokens / max(elapsed, 1e-9),
            "p50_latency_s": self.percentile(0.5),
            "p99_latency_s": self.percentile(0.99),
            "hop_failures": self.hop_failures,
            "reprefills": self.reprefills,
            "reprefilled_stages": self.reprefilled_stages,
            "kv_transfers": self.kv_transfers,
            "handoff_fallbacks": self.handoff_fallbacks,
            "wire_bytes": self.wire_bytes,
        }


@dataclasses.dataclass
class _Session:
    """One batched generation in flight (a continuous-batching slot)."""
    key: int
    requests: list
    tokens: np.ndarray            # [B, S] stacked prompts
    new_tokens: int
    total_len: int
    # boundary stage -> wire tensors sent into hops entering there, in
    # order: the full-sequence prefill wire, then one per decode step.
    # Concatenated along the sequence axis this is exactly the input a
    # replacement peer needs to re-prefill the boundary's span.
    edges: dict = dataclasses.field(default_factory=dict)
    chain: list = dataclasses.field(default_factory=list)        # decode peers
    chain_spans: list = dataclasses.field(default_factory=list)  # their spans
    generated: list = dataclasses.field(default_factory=list)    # [B,1] each
    last: Optional[np.ndarray] = None                            # [B,1]

    @property
    def prompt_len(self) -> int:
        return self.tokens.shape[1]

    @property
    def batch(self) -> int:
        return self.tokens.shape[0]


def reference_generate(cfg: ArchConfig, params: Tree, prompts,
                       new_tokens: int) -> np.ndarray:
    """Single-process greedy reference: the token-for-token oracle the
    staged swarm is tested against.  Returns ``[B, new_tokens]``."""
    import jax.numpy as jnp
    prompts = np.asarray(prompts, np.int32)
    S = prompts.shape[1]
    prog = full_session_program(cfg, S + new_tokens)
    nxt, kv = prog.prefill(params, prompts)
    out = [np.asarray(nxt)]
    for i in range(new_tokens - 1):
        nxt, kv = prog.decode(params, kv, nxt, jnp.int32(S + i))
        out.append(np.asarray(nxt))
    return np.concatenate(out, axis=1)


class ServeRunner:
    """Serve sessions through prefill/decode span pools under churn."""

    def __init__(self, cfg: ArchConfig, scfg: Optional[ServeConfig] = None,
                 params: Optional[Tree] = None, seed: int = 0):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.n_stages = self.scfg.n_stages
        self.sim = Sim()
        self.comp = codecs.resolve_mode(
            cfg, None if self.scfg.codec == "auto" else self.scfg.codec)
        if params is None:
            from repro.models import model as model_lib
            from repro.models import params as P
            params = P.init(jax.random.PRNGKey(seed),
                            model_lib.lm_specs(cfg))
        self.params = params
        self._stage_params = split_lm_params(cfg, self.n_stages, params,
                                             compress=self.comp)
        # seq_len only keys the (unused-here) training program cache
        self._family = build_numeric_executors(
            cfg, self.n_stages, seq_len=8, compress=self.comp,
            quant_block=self.scfg.quant_block)
        self._ex_cache: dict[tuple[int, int], Any] = {}
        self.kv = SessionKVLedger(self.n_stages)
        self.prefill_peers: list[Peer] = []
        self.decode_peers: list[Peer] = []
        self._peers: dict = {}
        self.queue: list[Request] = []
        self.active = 0
        self._session_ids = itertools.count()
        self.stats = ServeStats()

    # ------------------------------------------------------------- pools
    def _span_executor(self, lo: int, hi: int):
        ex = self._ex_cache.get((lo, hi))
        if ex is None:
            ex = self._family[lo].for_span(range(lo, hi))
            self._ex_cache[(lo, hi)] = ex
        return ex

    @staticmethod
    def _blank_state(span: range) -> StageState:
        if len(span) > 1:
            return StageState(per_stage={s: StageState() for s in span})
        return StageState()

    def _install_params(self, peer: Peer) -> None:
        for s in peer.span:
            peer.executor.restore(
                peer.state, {"params": self._stage_params[s]}, stage=s)

    def add_peer(self, span: tuple[int, int], pool: str = "decode",
                 profile: DeviceProfile = T4,
                 name: Optional[str] = None) -> Peer:
        lo, hi = span
        peer = Peer(self.sim, profile, range(lo, hi), name=name,
                    executor=self._span_executor(lo, hi))
        peer.state = self._blank_state(peer.span)
        self._install_params(peer)
        pool_list = self.prefill_peers if pool == "prefill" \
            else self.decode_peers
        pool_list.append(peer)
        self._peers[peer.id] = peer
        return peer

    def build_pools(self, n_prefill: int, n_decode: int,
                    stage_costs: Optional[list[float]] = None,
                    profile: DeviceProfile = T4,
                    boundary_cost: float = 0.0) -> dict:
        """Disaggregated layout via :func:`serve_assignment`; with
        ``n_prefill == 0`` prefill runs on the decode chain itself."""
        layout = serve_assignment(n_prefill, n_decode, self.n_stages,
                                  stage_costs, boundary_cost=boundary_cost)
        for sp in layout["prefill"]:
            self.add_peer(sp, pool="prefill", profile=profile)
        for sp in layout["decode"]:
            self.add_peer(sp, pool="decode", profile=profile)
        return layout

    def _resolve(self, peer) -> Peer:
        return self._peers[peer] if not isinstance(peer, Peer) else peer

    # ------------------------------------------------------------- churn
    def fail_peer(self, peer) -> None:
        """Kill a peer; its KV holdings are released so recovery (and a
        later revival of the same peer object) sees them as lost."""
        peer = self._resolve(peer)
        peer.fail()
        self.kv.release_all(peer.id)

    def revive_peer(self, peer) -> None:
        """Warm-rejoin a dead peer on its old span: fresh state, params
        re-installed; sessions re-prefill KV on their next touch."""
        peer = self._resolve(peer)
        peer.revive(peer.span)
        peer.state = self._blank_state(peer.span)
        self._install_params(peer)

    def schedule_fail(self, t: float, peer) -> None:
        def proc():
            yield Sleep(t)
            self.fail_peer(peer)
        self.sim.spawn(proc())

    def schedule_revive(self, t: float, peer) -> None:
        def proc():
            yield Sleep(t)
            self.revive_peer(peer)
        self.sim.spawn(proc())

    # ---------------------------------------------------------- requests
    def submit(self, prompt: Sequence[int], new_tokens: int) -> Request:
        r = Request(prompt=np.asarray(prompt, np.int32),
                    new_tokens=int(new_tokens), arrival=self.sim.now)
        self.queue.append(r)
        return r

    def run(self, until: Optional[float] = None) -> dict:
        """Serve every queued request to completion (or ``until``);
        returns the stats summary."""
        self.sim.spawn(self._scheduler())
        self.sim.run(until=until)
        return self.stats.summary(self.sim.now)

    # --------------------------------------------------------- scheduler
    def _next_batch(self) -> Optional[list[Request]]:
        if not self.queue:
            return None
        head = self.queue[0]
        shape = (len(head.prompt), head.new_tokens)
        batch = [r for r in self.queue
                 if (len(r.prompt), r.new_tokens) == shape]
        batch = batch[:self.scfg.max_batch]
        for r in batch:
            self.queue.remove(r)
        return batch

    def _scheduler(self):
        while self.queue or self.active:
            while self.queue and self.active < self.scfg.max_sessions:
                batch = self._next_batch()
                if not batch:
                    break
                sess = _Session(
                    key=next(self._session_ids), requests=batch,
                    tokens=np.stack([r.prompt for r in batch]),
                    new_tokens=batch[0].new_tokens,
                    total_len=len(batch[0].prompt) + batch[0].new_tokens)
                self.active += 1
                self.sim.spawn(self._session_proc(sess))
            yield Sleep(self.scfg.poll)

    # ------------------------------------------------------------ session
    def _session_proc(self, sess: _Session):
        try:
            yield from self._prefill_phase(sess)
            yield from self._handoff(sess)
            for step in range(sess.new_tokens - 1):
                yield from self._decode_step(sess, step)
            self._finish(sess)
        except (SessionFailed, PeerFailure):
            for r in sess.requests:
                r.failed = True
            self.stats.failed += len(sess.requests)
            self._release(sess)
        finally:
            self.active -= 1

    def _pick(self, pool: list[Peer], start: int,
              span: Optional[tuple[int, int]] = None,
              exclude: Optional[Peer] = None) -> Optional[Peer]:
        cand = [p for p in pool
                if p.alive and p.serving and p is not exclude
                and p.span.start == start
                and (span is None
                     or (p.span.start, p.span.stop) == span)]
        if not cand:
            return None
        return min(cand, key=lambda p: (p.queue_size(), str(p.id)))

    def _edge(self, sess: _Session, b: int, x) -> None:
        sess.edges.setdefault(b, []).append(np.asarray(x))

    # ------------------------------------------------------------ prefill
    def _prefill_phase(self, sess: _Session):
        """Route the prompt through the prefill pool (or, without one,
        the decode pool — which then doubles as the session's chain),
        recording each hop's entry wire and the per-stage KV holders."""
        pool = self.prefill_peers or self.decode_peers
        direct = not self.prefill_peers
        b, x, retries = 0, sess.tokens, 0
        while b < self.n_stages:
            peer = self._pick(pool, b)
            if peer is None:
                retries += 1
                if retries > self.scfg.max_retries:
                    raise SessionFailed(f"no prefill peer at boundary {b}")
                yield Sleep(self.scfg.retry_wait)
                continue
            span = (peer.span.start, peer.span.stop)
            prog = peer.executor.session_program(sess.total_len)
            self._edge(sess, b, x)
            nb = _tree_nbytes(x)
            self.stats.wire_bytes += nb
            yield Sleep(peer.profile.recv_time(nb))
            ct = peer.profile.compute_time(
                prog.flops_per_token * sess.batch * sess.prompt_len)
            try:
                out = yield peer.submit(
                    "prefill", ct,
                    self._prefill_thunk(sess, peer, prog, x)).wait()
            except PeerFailure:
                self.stats.hop_failures += 1
                retries += 1
                if retries > self.scfg.max_retries:
                    raise SessionFailed(f"prefill died at boundary {b}")
                yield Sleep(self.scfg.retry_wait)
                continue
            if direct:
                sess.chain.append(peer)
                sess.chain_spans.append(span)
            x, b = out, span[1]
        sess.last = np.asarray(x)            # first generated token [B,1]
        sess.generated.append(sess.last)

    def _prefill_thunk(self, sess: _Session, peer: Peer, prog, x):
        def thunk():
            views = [peer.state.stage_view(s) for s in prog.stages]
            params = tuple(v.params for v in views)
            out, kv = prog.prefill(params, x)
            for s, c in zip(prog.stages, kv):
                peer.executor.install_slot(peer.state, KV_SLOT, sess.key,
                                           c, stage=s)
                self.kv.record(s, sess.key, peer.id)
            if prog.covers_last:
                return np.asarray(out)
            return np.asarray(jax.device_get(peer.executor.wire_fwd(out)))
        return thunk

    # ------------------------------------------------------------ handoff
    def _handoff(self, sess: _Session):
        """Build the decode chain; move each stage's KV from its prefill
        holder over the executor slot wire (``transfer``: computed once,
        never re-prefilled).  A dead prefill holder voids its span's
        hand-off — the first decode step's missing-stage path re-prefills
        it from the recorded boundary history instead."""
        if not self.prefill_peers:
            return                    # prefilled on the decode chain itself
        b, retries = 0, 0
        while b < self.n_stages:
            peer = self._pick(self.decode_peers, b)
            if peer is None:
                retries += 1
                if retries > self.scfg.max_retries:
                    raise SessionFailed(f"no decode peer at boundary {b}")
                yield Sleep(self.scfg.retry_wait)
                continue
            span = (peer.span.start, peer.span.stop)
            sess.chain.append(peer)
            sess.chain_spans.append(span)
            holders = {s: self._peers.get(self.kv.holder(s, sess.key))
                       for s in range(*span)}
            if all(h is not None and h.alive for h in holders.values()):
                nb = 0.0
                for s in range(*span):
                    h = holders[s]
                    val = h.executor.export_slot(h.state, KV_SLOT,
                                                 sess.key, stage=s)
                    peer.executor.install_slot(peer.state, KV_SLOT,
                                               sess.key, val, stage=s)
                    h.executor.drop_slot(h.state, KV_SLOT, key=sess.key,
                                         stage=s)
                    self.kv.transfer(s, sess.key, peer.id)
                    nb += _tree_nbytes(val)
                    self.stats.kv_transfers += 1
                self.stats.wire_bytes += nb
                yield Sleep(peer.profile.recv_time(nb))
            else:
                for s in range(*span):
                    self.kv.release(s, sess.key)
                self.stats.handoff_fallbacks += 1
            b = span[1]

    # ------------------------------------------------------------- decode
    def _decode_step(self, sess: _Session, step: int):
        pos = sess.prompt_len + step
        x = sess.last
        for hop in range(len(sess.chain)):
            x = yield from self._decode_hop(sess, hop, x, pos)
        sess.last = np.asarray(x)
        sess.generated.append(sess.last)

    def _decode_hop(self, sess: _Session, hop: int, x, pos: int):
        lo, hi = sess.chain_spans[hop]
        self._edge(sess, lo, x)
        retries = 0
        while True:
            peer = sess.chain[hop]
            if not (peer.alive and peer.serving):
                repl = self._pick(self.decode_peers, lo, span=(lo, hi),
                                  exclude=peer)
                if repl is None:
                    retries += 1
                    if retries > self.scfg.max_retries:
                        raise SessionFailed(
                            f"no replacement for decode span ({lo}, {hi})")
                    yield Sleep(self.scfg.retry_wait)
                    continue
                sess.chain[hop] = peer = repl
            prog = peer.executor.session_program(sess.total_len)
            missing = [s for s in range(lo, hi)
                       if self.kv.holder(s, sess.key) != peer.id]
            try:
                if missing:
                    yield from self._reprefill(sess, peer, prog, missing)
                nb = _tree_nbytes(x)
                self.stats.wire_bytes += nb
                yield Sleep(peer.profile.recv_time(nb))
                ct = peer.profile.compute_time(
                    prog.flops_per_token * sess.batch)
                out = yield peer.submit(
                    "decode", ct,
                    self._decode_thunk(sess, peer, prog, x, pos)).wait()
                return out
            except PeerFailure:
                self.stats.hop_failures += 1
                retries += 1
                if retries > self.scfg.max_retries:
                    raise SessionFailed(
                        f"decode span ({lo}, {hi}) kept dying")
                yield Sleep(self.scfg.retry_wait)

    def _reprefill(self, sess: _Session, peer: Peer, prog, missing):
        """Rebuild exactly the lost span's KV on ``peer``: one fused
        prefill of the recorded boundary history ``[0, pos)`` (the last
        recorded entry is the *interrupted* step's input — it resumes as
        a decode right after, so it is excluded from the prefix)."""
        lo, hi = prog.span
        # KV moves span-atomically (hand-off and re-prefill both run
        # without yielding), so a partial hold means ledger corruption
        assert missing == list(range(lo, hi)), (missing, prog.span)
        hist = sess.edges.get(lo)
        if not hist:
            raise SessionFailed(f"no boundary history at stage {lo}")
        prefix = hist[0] if len(hist) == 1 \
            else np.concatenate(hist[:-1], axis=1)
        ct = peer.profile.compute_time(
            prog.flops_per_token * sess.batch * prefix.shape[1])

        def thunk():
            views = [peer.state.stage_view(s) for s in prog.stages]
            params = tuple(v.params for v in views)
            _, kv = prog.prefill(params, prefix)   # prefix output discarded:
            for s, c in zip(prog.stages, kv):      # downstream KV is alive
                peer.executor.install_slot(peer.state, KV_SLOT, sess.key,
                                           c, stage=s)
                self.kv.record(s, sess.key, peer.id)   # strict: died first
            return None

        yield peer.submit("prefill", ct, thunk).wait()
        self.stats.reprefills += 1
        self.stats.reprefilled_stages += hi - lo

    def _decode_thunk(self, sess: _Session, peer: Peer, prog, x, pos: int):
        import jax.numpy as jnp

        def thunk():
            views = [peer.state.stage_view(s) for s in prog.stages]
            params = tuple(v.params for v in views)
            kv = tuple(v.slot(KV_SLOT)[sess.key] for v in views)
            out, new_kv = prog.decode(params, kv, x, jnp.int32(pos))
            for v, c in zip(views, new_kv):
                v.slot(KV_SLOT)[sess.key] = c
            if prog.covers_last:
                return np.asarray(out)
            return np.asarray(jax.device_get(peer.executor.wire_fwd(out)))
        return thunk

    # ----------------------------------------------------------- teardown
    def _finish(self, sess: _Session) -> None:
        gen = np.concatenate(sess.generated, axis=1)   # [B, new_tokens]
        for r, row in zip(sess.requests, gen):
            r.tokens = row
            r.done_at = self.sim.now
            self.stats.latencies.append(self.sim.now - r.arrival)
        self.stats.completed += len(sess.requests)
        self.stats.tokens += int(gen.size)
        self._release(sess)

    def _release(self, sess: _Session) -> None:
        for s in range(self.n_stages):
            pid = self.kv.holder(s, sess.key)
            if pid is None:
                continue
            peer = self._peers.get(pid)
            if peer is not None and peer.alive:
                peer.executor.drop_slot(peer.state, KV_SLOT, key=sess.key,
                                        stage=s)
            self.kv.release(s, sess.key)
