"""Partition an ArchConfig into SWARM pipeline stages (stage programs).

Stage 0 additionally owns the embedding, the last stage the final norm +
LM head + loss (mirroring the paper's §4.3 placement).  Backward runs via
activation checkpointing: a stage recomputes its forward from the boundary
input it is handed, so backward can be re-routed to *any* peer of the stage
after a failure (App. A).

Under a learned boundary codec (paper App. J: ``compress="bottleneck"`` /
``"maxout"``) each stage's program *includes* its side of the codec: a
sending stage compresses its output (owning ``w_c`` for the bottleneck), a
receiving stage decompresses its input (owning ``w_d``) — so the tensor a
trainer carries between peers IS the c-dim wire tensor, and codec gradients
arrive through the ordinary per-stage ``bwd`` like any other parameter.
``"int8"`` stays outside the programs (the trainer round-trips the wire
tensor), matching SWARM's quantize-on-send.

The builders are *span-parameterized*: :func:`build_stage_programs` is the
``[s, s+1)`` special case of the same machinery
:func:`build_span_program` uses to fuse a contiguous span ``[lo, hi)`` of
stages into ONE jitted fwd/bwd (the
:class:`repro.runtime.pipeline.PipelineExecutor` backend).  Inside a span,
intra-span boundaries never leave the device: chaining stage ``b``'s
in-program compress with stage ``b+1``'s decompress reproduces the exact
single-stage math, minus the host crossing.  Structurally identical
consecutive stages are stacked with :func:`repro.dist.pipeline.restack`
(the XLA-0.4.x sharded-concat workaround — the same construction the
GSPMD shifting buffer vmaps over ``pod``) and scanned over the stage dim;
the per-stage layer math itself is
:func:`repro.dist.pipeline.make_block_core`, shared with the compiled
pipeline, so span peers, single-stage peers, and the GSPMD step compute
one set of stage numerics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.compression import codecs
from repro.dist.constrain import constrain
from repro.dist.pipeline import make_block_core, restack
from repro.models.config import ArchConfig
from repro.models import params as P
from repro.models import layers as L
from repro.models import model as model_lib
from repro.models import flops as F

Tree = Any


@dataclasses.dataclass
class StageProgram:
    stage: int
    n_stages: int
    specs: Tree
    fwd: Callable                 # jitted
    bwd: Callable                 # jitted
    fwd_flops_per_token: float
    bwd_flops_per_token: float    # includes checkpoint recompute
    fwd_fn: Optional[Callable] = None   # unjitted (mesh backends re-jit
    bwd_fn: Optional[Callable] = None   # with their own shardings)


@dataclasses.dataclass
class SpanProgram:
    """A contiguous span ``[lo, hi)`` of stages fused into one jitted step.

    ``fwd``/``bwd`` take a *tuple* of per-stage param trees (ordered
    ``lo..hi-1``, each shaped exactly like the corresponding
    :class:`StageProgram`'s ``specs``) so a span peer's state stays
    per-stage-keyed: checkpoint cuts, peer-to-peer downloads and span
    split/merge hand-offs move single-stage snapshots, never a fused
    blob.  ``bwd`` returns per-stage gradients as the same tuple.
    """
    span: tuple[int, int]
    n_stages: int
    specs: dict[int, Tree]        # per covered stage, keyed by global id
    fwd: Callable                 # jitted
    bwd: Callable                 # jitted
    fwd_flops_per_token: float    # whole-span totals
    bwd_flops_per_token: float
    fwd_fn: Optional[Callable] = None
    bwd_fn: Optional[Callable] = None

    @property
    def stages(self) -> range:
        return range(*self.span)


def _traced(fn: Callable, hook: Optional[Callable], stage, kind: str
            ) -> Callable:
    """Jit ``fn``; if ``hook`` is given, call it once per XLA trace (the
    body side effect runs at trace time only) with the argument shapes —
    the runtime layer's retrace counter hangs off this.  ``stage`` is an
    int for single-stage programs, a ``(lo, hi)`` span tuple for spans."""
    if hook is None:
        return jax.jit(fn)

    def counted(*args):
        hook(stage, kind, tuple(tuple(a.shape) for a in args
                                if hasattr(a, "shape")))
        return fn(*args)
    return jax.jit(counted)


def _stage_slice(cfg: ArchConfig, stage: int, n_stages: int):
    per = cfg.n_layers // n_stages
    lo, hi = stage * per, (stage + 1) * per
    if cfg.share_groups:
        # one shared parameter group per stage (paper §4.3: 3 stages x 16
        # shared layers); reuse count = layers per stage
        assert cfg.share_groups == n_stages, (
            "share_groups must equal n_stages for the paper's model")
        return cfg.block_kinds[lo:hi], True
    return cfg.block_kinds[lo:hi], False


def _stage_runs(cfg: ArchConfig, s: int, n_stages: int):
    """(kinds, [per-run (kind, count)], reps) for one stage's layer slice."""
    kinds, shared = _stage_slice(cfg, s, n_stages)
    runs = model_lib.segments(kinds)
    if shared:
        runs = [(kinds[0], 1)]          # single shared group
    reps = len(kinds) if shared else 1
    return kinds, runs, reps


def _stage_specs(cfg: ArchConfig, s: int, n_stages: int, comp: str,
                 learned: bool) -> Tree:
    """One stage's ParamSpec tree: blocks + edge extras (embed / head) +
    its side(s) of the learned boundary codec."""
    _, runs, _ = _stage_runs(cfg, s, n_stages)
    from repro.models.blocks import REGISTRY
    specs: Tree = {"blocks": [
        model_lib.stack_specs(REGISTRY[k][0](cfg), n) for k, n in runs]}
    if s == 0:
        specs["embed"] = P.ParamSpec(
            (cfg.vocab_size, cfg.d_model), cfg.param_jdtype, "embed",
            ("vocab", "embed"))
    if s == n_stages - 1:
        specs["final_norm"] = L.norm_specs(cfg)
        if not cfg.tie_embeddings or s != 0:
            specs["head"] = P.ParamSpec(
                (cfg.d_model, cfg.vocab_size), cfg.param_jdtype,
                "normal", ("embed", "vocab"))
    if learned:
        # receiving side (w_d) for s > 0, sending side (w_c) for
        # s < S-1; maxout's compress is param-free so its stage-0
        # "boundary" tree is empty and omitted
        bnd: Tree = {}
        if s > 0:
            bnd.update(codecs.receiver_specs(cfg, comp))
        if s < n_stages - 1:
            bnd.update(codecs.sender_specs(cfg, comp))
        if bnd:
            specs["boundary"] = bnd
    return specs


def _make_stage_fwd(cfg: ArchConfig, s: int, n_stages: int, comp: str,
                    learned: bool) -> Callable:
    """Stage ``s``'s wire-to-wire forward: decode the inbound wire tensor
    (embed for stage 0), run the stage's layers through the shared block
    core, emit the outbound wire tensor (hidden for the last stage — the
    head/loss is applied by the caller)."""
    _, runs, reps = _stage_runs(cfg, s, n_stages)
    core = make_block_core(cfg, runs, reps)
    is_first, is_last = s == 0, s == n_stages - 1

    def stage_fwd(params: Tree, inp):
        if is_first:
            tokens = inp
            x = params["embed"][tokens].astype(cfg.compute_jdtype)
            if cfg.scale_embed:
                x = x * (cfg.d_model ** 0.5)
        else:
            x = inp.astype(cfg.compute_jdtype)
            if learned:          # wire tensor arrives c-dim: restore
                x = codecs.decompress(cfg, comp,
                                      params.get("boundary"), x)
        positions = jnp.arange(x.shape[1])
        x, _aux = core(params["blocks"], x,
                       jnp.zeros((), jnp.float32), positions)
        if learned and not is_last:    # emit the c-dim wire tensor
            x = codecs.compress(cfg, comp, params.get("boundary"), x)
        return x

    return stage_fwd


def _head_logits(cfg: ArchConfig, params: Tree, x):
    """Final norm + LM head — the last stage's extra ownership.  Shared
    by the training loss below and the serving session programs
    (``repro.serve.programs``), so staged decode and staged training
    read logits through one code path."""
    x = L.apply_norm(cfg, params["final_norm"], x)
    w = (params["embed"].T if cfg.tie_embeddings and "head" not in
         params else params["head"])
    logits = x @ w.astype(x.dtype)
    return logits.astype(jnp.float32)


def _head_loss(cfg: ArchConfig, params: Tree, x, labels):
    """Logits + token-sum CE (so microbatch gradients add exactly,
    App. E)."""
    logits = _head_logits(cfg, params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.sum(lse - gold)


def _stage_fwd_flops(cfg: ArchConfig, s: int, n_stages: int, seq_len: int,
                     comp: str, learned: bool) -> float:
    kinds, _, _ = _stage_runs(cfg, s, n_stages)
    is_first, is_last = s == 0, s == n_stages - 1
    ctx = F._ctx_for(cfg, seq_len, causal_avg=True)
    layer_f = sum(F.per_token_layer_flops(cfg, k, ctx) for k in kinds)
    head_f = 2 * cfg.d_model * cfg.vocab_size if is_last else 0.0
    codec_f = codecs.codec_flops_per_token(
        cfg, comp, sender=learned and not is_last,
        receiver=learned and not is_first)
    return layer_f + head_f + codec_f


def build_stage_programs(cfg: ArchConfig, n_stages: int, seq_len: int,
                         compress: Optional[str] = None,
                         trace_hook: Optional[Callable] = None
                         ) -> list[StageProgram]:
    assert cfg.n_layers % n_stages == 0
    assert cfg.encoder_layers == 0, "enc-dec archs use pod-DP (DESIGN §5)"
    comp = codecs.resolve_mode(cfg, compress)
    learned = comp in codecs.LEARNED and n_stages > 1
    programs = []
    for s in range(n_stages):
        specs = _stage_specs(cfg, s, n_stages, comp, learned)
        stage_fwd = _make_stage_fwd(cfg, s, n_stages, comp, learned)
        is_first, is_last = s == 0, s == n_stages - 1

        def stage_loss(params, inp, labels, _fwd=stage_fwd):
            return _head_loss(cfg, params, _fwd(params, inp), labels)

        if is_last:
            def fwd(params, inp, labels, _sl=stage_loss):
                return _sl(params, inp, labels)

            def bwd(params, inp, labels, _sl=stage_loss):
                if is_first_and_last := (n_stages == 1):
                    (loss), g = jax.value_and_grad(_sl)(params, inp, labels)
                    return loss, None, g
                (loss), (gp, gx) = jax.value_and_grad(_sl, argnums=(0, 1))(
                    params, inp, labels)
                return loss, gx, gp
        elif is_first:
            def fwd(params, inp, _sf=stage_fwd):
                return _sf(params, inp)

            def bwd(params, inp, dy, _sf=stage_fwd):
                y, pullback = jax.vjp(lambda p: _sf(p, inp), params)
                (gp,) = pullback(dy.astype(y.dtype))
                return None, gp
        else:
            def fwd(params, inp, _sf=stage_fwd):
                return _sf(params, inp)

            def bwd(params, inp, dy, _sf=stage_fwd):
                y, pullback = jax.vjp(_sf, params, inp)
                gp, gx = pullback(dy.astype(y.dtype))
                return gx, gp
        fwd_j = _traced(fwd, trace_hook, s, "fwd")
        bwd_j = _traced(bwd, trace_hook, s, "bwd")

        fwd_f = _stage_fwd_flops(cfg, s, n_stages, seq_len, comp, learned)
        programs.append(StageProgram(
            stage=s, n_stages=n_stages, specs=specs, fwd=fwd_j, bwd=bwd_j,
            fwd_flops_per_token=fwd_f,
            bwd_flops_per_token=3.0 * fwd_f,   # recompute + 2x backward
            fwd_fn=fwd, bwd_fn=bwd,
        ))
    return programs


# ------------------------------------------------------------- span fusion
def _span_fingerprint(cfg: ArchConfig, s: int, n_stages: int, comp: str,
                      learned: bool, specs_s: Tree):
    """Two covered stages may share one scan slot iff this matches: same
    layer runs, same edge role, and bit-identical param-tree geometry."""
    _, runs, reps = _stage_runs(cfg, s, n_stages)
    leaves, treedef = jax.tree.flatten(specs_s, is_leaf=P.is_spec)
    return (tuple(runs), reps, s == 0, s == n_stages - 1,
            treedef, tuple(leaves))


def _scan_groups(fingerprints: list) -> list[tuple[int, int]]:
    """Maximal runs of consecutive equal fingerprints, as (start, count)
    over span-local indices."""
    groups, i = [], 0
    while i < len(fingerprints):
        j = i + 1
        while j < len(fingerprints) and fingerprints[j] == fingerprints[i]:
            j += 1
        groups.append((i, j - i))
        i = j
    return groups


def build_span_program(cfg: ArchConfig, n_stages: int, seq_len: int,
                       span: tuple[int, int],
                       compress: Optional[str] = None,
                       trace_hook: Optional[Callable] = None
                       ) -> SpanProgram:
    """Fuse stages ``[lo, hi)`` into one jitted fwd/bwd.

    The single-jit span step is what lets a well-provisioned peer hold
    *more of the model* (the paper's square-cube rebalancing; Varuna's
    stage fusion): intra-span boundaries stay on-device — under a learned
    codec the sending stage's in-program compress chains into the
    receiving stage's decompress, reproducing the single-stage math
    exactly, with zero host bytes for the fused boundary.  Runs of
    structurally identical covered stages are stacked along a leading
    stage dim with :func:`repro.dist.pipeline.restack` (constrained to
    ``pod`` when a mesh is ambient — the same sharded stacking the GSPMD
    tick uses, so the XLA-0.4.x concat workaround is load-bearing here
    too) and executed as a ``lax.scan`` over stages.
    """
    lo, hi = span
    if not (0 <= lo < hi <= n_stages):
        raise ValueError(f"span [{lo}, {hi}) outside [0, {n_stages})")
    assert cfg.n_layers % n_stages == 0
    assert cfg.encoder_layers == 0, "enc-dec archs use pod-DP (DESIGN §5)"
    comp = codecs.resolve_mode(cfg, compress)
    learned = comp in codecs.LEARNED and n_stages > 1
    covers_last = hi == n_stages

    specs: dict[int, Tree] = {}
    fwds: dict[int, Callable] = {}
    fprints = []
    fwd_f = 0.0
    for s in range(lo, hi):
        specs[s] = _stage_specs(cfg, s, n_stages, comp, learned)
        fwds[s] = _make_stage_fwd(cfg, s, n_stages, comp, learned)
        fprints.append(_span_fingerprint(cfg, s, n_stages, comp, learned,
                                         specs[s]))
        fwd_f += _stage_fwd_flops(cfg, s, n_stages, seq_len, comp, learned)
    groups = _scan_groups(fprints)

    def span_fwd(params_by_stage, inp):
        """(tuple ordered lo..hi-1, inbound wire) -> hidden (covers_last)
        or outbound wire tensor."""
        x = inp
        for start, count in groups:
            f = fwds[lo + start]
            if count >= 2:
                members = [params_by_stage[i]
                           for i in range(start, start + count)]
                stacked = jax.tree.map(
                    lambda *xs: restack(list(xs)), *members)
                stacked = jax.tree.map(
                    lambda a: constrain(a, "pod", *([None] * (a.ndim - 1))),
                    stacked)

                def body(x, p_s, _f=f):
                    return _f(p_s, x), None
                x, _ = jax.lax.scan(body, x, stacked)
            else:
                x = f(params_by_stage[start], x)
        return x

    if covers_last:
        def span_loss(ps, inp, labels, _sf=span_fwd):
            return _head_loss(cfg, ps[-1], _sf(ps, inp), labels)

        def fwd(ps, inp, labels, _sl=span_loss):
            return _sl(ps, inp, labels)

        if lo == 0:
            def bwd(ps, inp, labels, _sl=span_loss):
                loss, gp = jax.value_and_grad(_sl)(ps, inp, labels)
                return loss, None, gp
        else:
            def bwd(ps, inp, labels, _sl=span_loss):
                loss, (gp, gx) = jax.value_and_grad(_sl, argnums=(0, 1))(
                    ps, inp, labels)
                return loss, gx, gp
    else:
        def fwd(ps, inp, _sf=span_fwd):
            return _sf(ps, inp)

        if lo == 0:
            def bwd(ps, inp, dy, _sf=span_fwd):
                y, pullback = jax.vjp(lambda p: _sf(p, inp), ps)
                (gp,) = pullback(dy.astype(y.dtype))
                return None, gp
        else:
            def bwd(ps, inp, dy, _sf=span_fwd):
                y, pullback = jax.vjp(_sf, ps, inp)
                gp, gx = pullback(dy.astype(y.dtype))
                return gx, gp

    return SpanProgram(
        span=(lo, hi), n_stages=n_stages, specs=specs,
        fwd=_traced(fwd, trace_hook, (lo, hi), "fwd"),
        bwd=_traced(bwd, trace_hook, (lo, hi), "bwd"),
        fwd_flops_per_token=fwd_f, bwd_flops_per_token=3.0 * fwd_f,
        fwd_fn=fwd, bwd_fn=bwd)


def init_stage_params(programs: list[StageProgram], key: jax.Array
                      ) -> list[Tree]:
    keys = jax.random.split(key, len(programs))
    return [P.init(k, p.specs) for k, p in zip(keys, programs)]


def split_lm_params(cfg: ArchConfig, n_stages: int, params: Tree,
                    compress: Optional[str] = None) -> list[Tree]:
    """Slice a full-model param tree (``repro.models.model.lm_specs``
    layout) into per-stage trees shaped like :func:`_stage_specs` — how
    weights trained or loaded through the single-process path get served
    by a staged swarm.  Exact: every leaf is a copy or a slice of the
    original, so staged forward/decode matches the full model
    bit-for-bit (the serving equivalence test relies on this).

    Learned boundary codecs are unsupported: the single-process tree
    carries the GSPMD pipeline's per-boundary codec stack, not the
    per-stage ``w_c``/``w_d`` split the stage programs own.
    """
    comp = codecs.resolve_mode(cfg, compress)
    if comp in codecs.LEARNED and n_stages > 1:
        raise NotImplementedError(
            "split_lm_params cannot split learned boundary-codec params; "
            "init per-stage codec weights via init_stage_params instead")
    assert cfg.n_layers % n_stages == 0
    per = cfg.n_layers // n_stages
    if not cfg.share_groups:
        per_layer: list[Tree] = []
        for (kind, n), seg in zip(model_lib.segments(cfg.block_kinds),
                                  params["blocks"]):
            for i in range(n):
                per_layer.append(jax.tree.map(lambda a, _i=i: a[_i], seg))
    out: list[Tree] = []
    for s in range(n_stages):
        if cfg.share_groups:
            # one shared group per stage (stage s applies group s
            # `per` times) — slice keeps the leading stack dim of 1
            blocks = [jax.tree.map(lambda a, _s=s: a[_s:_s + 1],
                                   params["blocks"][0])]
        else:
            blocks, idx = [], s * per
            for kind, n in model_lib.segments(
                    cfg.block_kinds[s * per:(s + 1) * per]):
                trees = per_layer[idx:idx + n]
                idx += n
                blocks.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *trees))
        st: Tree = {"blocks": blocks}
        if s == 0:
            st["embed"] = params["embed"]
        if s == n_stages - 1:
            st["final_norm"] = params["final_norm"]
            if not cfg.tie_embeddings:
                st["head"] = params["head"]
            elif s != 0:
                # tied embeddings with the embed table on another stage:
                # the last stage materializes the tied head
                st["head"] = params["embed"].T
        out.append(st)
    return out
