"""Partition an ArchConfig into SWARM pipeline stages (stage programs).

Stage 0 additionally owns the embedding, the last stage the final norm +
LM head + loss (mirroring the paper's §4.3 placement).  Backward runs via
activation checkpointing: a stage recomputes its forward from the boundary
input it is handed, so backward can be re-routed to *any* peer of the stage
after a failure (App. A).

Under a learned boundary codec (paper App. J: ``compress="bottleneck"`` /
``"maxout"``) each stage's program *includes* its side of the codec: a
sending stage compresses its output (owning ``w_c`` for the bottleneck), a
receiving stage decompresses its input (owning ``w_d``) — so the tensor a
trainer carries between peers IS the c-dim wire tensor, and codec gradients
arrive through the ordinary per-stage ``bwd`` like any other parameter.
``"int8"`` stays outside the programs (the trainer round-trips the wire
tensor), matching SWARM's quantize-on-send.

The builders are *span-parameterized*: :func:`build_stage_programs` is the
``[s, s+1)`` special case of the same machinery
:func:`build_span_program` uses to fuse a contiguous span ``[lo, hi)`` of
stages into ONE jitted fwd/bwd (the
:class:`repro.runtime.pipeline.PipelineExecutor` backend).  Inside a span,
intra-span boundaries never leave the device: chaining stage ``b``'s
in-program compress with stage ``b+1``'s decompress reproduces the exact
single-stage math, minus the host crossing.  Structurally identical
consecutive stages are stacked with :func:`repro.dist.pipeline.restack`
(the XLA-0.4.x sharded-concat workaround — the same construction the
GSPMD shifting buffer vmaps over ``pod``) and scanned over the stage dim;
the per-stage layer math itself is
:func:`repro.dist.pipeline.make_block_core`, shared with the compiled
pipeline, so span peers, single-stage peers, and the GSPMD step compute
one set of stage numerics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.compression import codecs
from repro.dist.constrain import constrain
from repro.dist.pipeline import make_block_core, restack
from repro.models.config import ArchConfig
from repro.models.stage_plan import StagePlan, get_stage_plan
from repro.models import params as P
from repro.models import layers as L
from repro.models import model as model_lib
from repro.models import flops as F

Tree = Any


@dataclasses.dataclass
class StageProgram:
    stage: int
    n_stages: int
    specs: Tree
    fwd: Callable                 # jitted
    bwd: Callable                 # jitted
    fwd_flops_per_token: float
    bwd_flops_per_token: float    # includes checkpoint recompute
    fwd_fn: Optional[Callable] = None   # unjitted (mesh backends re-jit
    bwd_fn: Optional[Callable] = None   # with their own shardings)


@dataclasses.dataclass
class SpanProgram:
    """A contiguous span ``[lo, hi)`` of stages fused into one jitted step.

    ``fwd``/``bwd`` take a *tuple* of per-stage param trees (ordered
    ``lo..hi-1``, each shaped exactly like the corresponding
    :class:`StageProgram`'s ``specs``) so a span peer's state stays
    per-stage-keyed: checkpoint cuts, peer-to-peer downloads and span
    split/merge hand-offs move single-stage snapshots, never a fused
    blob.  ``bwd`` returns per-stage gradients as the same tuple.
    """
    span: tuple[int, int]
    n_stages: int
    specs: dict[int, Tree]        # per covered stage, keyed by global id
    fwd: Callable                 # jitted
    bwd: Callable                 # jitted
    fwd_flops_per_token: float    # whole-span totals
    bwd_flops_per_token: float
    fwd_fn: Optional[Callable] = None
    bwd_fn: Optional[Callable] = None

    @property
    def stages(self) -> range:
        return range(*self.span)


def _traced(fn: Callable, hook: Optional[Callable], stage, kind: str
            ) -> Callable:
    """Jit ``fn``; if ``hook`` is given, call it once per XLA trace (the
    body side effect runs at trace time only) with the argument shapes —
    the runtime layer's retrace counter hangs off this.  ``stage`` is an
    int for single-stage programs, a ``(lo, hi)`` span tuple for spans."""
    if hook is None:
        return jax.jit(fn)

    def counted(*args):
        hook(stage, kind, tuple(tuple(a.shape) for a in args
                                if hasattr(a, "shape")))
        return fn(*args)
    return jax.jit(counted)


def _stage_runs(cfg: ArchConfig, s: int, n_stages: int):
    """(kinds, [per-run (kind, count)], reps) for one stage — read off
    the canonical :class:`~repro.models.stage_plan.StagePlan` instead of
    re-deriving it from ``cfg.block_kinds`` index math."""
    spec = get_stage_plan(cfg, n_stages).stages[s]
    return spec.kinds, list(spec.runs), spec.reps


def _cast_like(dy: Tree, y: Tree) -> Tree:
    """Cast a boundary cotangent tree to the forward output's dtypes
    (leaf-wise — whisper boundaries are trees, LM boundaries a tensor)."""
    return jax.tree.map(lambda t, yy: t.astype(yy.dtype), dy, y)


# whisper boundary payloads are trees; these keys are integer leaves
# (token ids) that ride the wire but never take gradients — stage fns
# split them out so every vjp runs over floating inputs only.
_INT_KEYS = ("tok",)


def _split_payload(inp: Tree) -> tuple[Tree, Tree]:
    floats = {k: v for k, v in inp.items() if k not in _INT_KEYS}
    ints = {k: v for k, v in inp.items() if k in _INT_KEYS}
    return floats, ints


def _stage_specs(cfg: ArchConfig, s: int, n_stages: int, comp: str,
                 learned: bool) -> Tree:
    """One stage's ParamSpec tree: blocks + edge extras (embed / head) +
    its side(s) of the learned boundary codec."""
    _, runs, _ = _stage_runs(cfg, s, n_stages)
    from repro.models.blocks import REGISTRY
    specs: Tree = {"blocks": [
        model_lib.stack_specs(REGISTRY[k][0](cfg), n) for k, n in runs]}
    if s == 0:
        specs["embed"] = P.ParamSpec(
            (cfg.vocab_size, cfg.d_model), cfg.param_jdtype, "embed",
            ("vocab", "embed"))
    if s == n_stages - 1:
        specs["final_norm"] = L.norm_specs(cfg)
        if not cfg.tie_embeddings or s != 0:
            specs["head"] = P.ParamSpec(
                (cfg.d_model, cfg.vocab_size), cfg.param_jdtype,
                "normal", ("embed", "vocab"))
    if learned:
        # receiving side (w_d) for s > 0, sending side (w_c) for
        # s < S-1; maxout's compress is param-free so its stage-0
        # "boundary" tree is empty and omitted
        bnd: Tree = {}
        if s > 0:
            bnd.update(codecs.receiver_specs(cfg, comp))
        if s < n_stages - 1:
            bnd.update(codecs.sender_specs(cfg, comp))
        if bnd:
            specs["boundary"] = bnd
    return specs


def _make_stage_fwd(cfg: ArchConfig, s: int, n_stages: int, comp: str,
                    learned: bool) -> Callable:
    """Stage ``s``'s wire-to-wire forward: decode the inbound wire tensor
    (embed for stage 0), run the stage's layers through the shared block
    core, emit the outbound wire tensor (hidden for the last stage — the
    head/loss is applied by the caller)."""
    _, runs, reps = _stage_runs(cfg, s, n_stages)
    core = make_block_core(cfg, runs, reps)
    is_first, is_last = s == 0, s == n_stages - 1

    def stage_fwd(params: Tree, inp):
        if is_first:
            tokens = inp
            x = params["embed"][tokens].astype(cfg.compute_jdtype)
            if cfg.scale_embed:
                x = x * (cfg.d_model ** 0.5)
        else:
            x = inp.astype(cfg.compute_jdtype)
            if learned:          # wire tensor arrives c-dim: restore
                x = codecs.decode_wire(cfg, comp,
                                       params.get("boundary"), x)
        positions = jnp.arange(x.shape[1])
        x, _aux = core(params["blocks"], x,
                       jnp.zeros((), jnp.float32), positions)
        if learned and not is_last:    # emit the c-dim wire tensor
            # (fused encode + wire QDQ under cfg.kernels / cfg.wire_quant)
            x = codecs.encode_wire(cfg, comp, params.get("boundary"), x)
        return x

    return stage_fwd


def _head_logits(cfg: ArchConfig, params: Tree, x):
    """Final norm + LM head — the last stage's extra ownership.  Shared
    by the training loss below and the serving session programs
    (``repro.serve.programs``), so staged decode and staged training
    read logits through one code path."""
    x = L.apply_norm(cfg, params["final_norm"], x)
    w = (params["embed"].T if cfg.tie_embeddings and "head" not in
         params else params["head"])
    logits = x @ w.astype(x.dtype)
    return logits.astype(jnp.float32)


def _head_loss(cfg: ArchConfig, params: Tree, x, labels):
    """Logits + token-sum CE (so microbatch gradients add exactly,
    App. E)."""
    logits = _head_logits(cfg, params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.sum(lse - gold)


def _stage_fwd_flops(cfg: ArchConfig, s: int, n_stages: int, seq_len: int,
                     comp: str, learned: bool) -> float:
    is_first, is_last = s == 0, s == n_stages - 1
    codec_f = codecs.codec_flops_per_token(
        cfg, comp, sender=learned and not is_last,
        receiver=learned and not is_first)
    return get_stage_plan(cfg, n_stages).stage_flops(s, seq_len) + codec_f


# --------------------------------------------------- encoder-decoder stages
def _stage_specs_encdec(cfg: ArchConfig, s: int, n_stages: int) -> Tree:
    """Whisper stage specs: stage 0 is the encoder pod, stages
    ``1..n_stages-1`` split the decoder; stage 1 owns the token embed,
    the last stage the final norm + head (plan ownership)."""
    from repro.models import whisper as W
    if s == 0:
        return {"enc_blocks": model_lib.stack_specs(
                    W.enc_block_specs(cfg), cfg.encoder_layers),
                "enc_norm": L.norm_specs(cfg)}
    per = cfg.n_layers // (n_stages - 1)
    specs: Tree = {"dec_blocks": model_lib.stack_specs(
        W.dec_block_specs(cfg), per)}
    if s == 1:
        specs["embed"] = P.ParamSpec(
            (cfg.vocab_size, cfg.d_model), cfg.param_jdtype, "embed",
            ("vocab", "embed"))
    if s == n_stages - 1:
        specs["final_norm"] = L.norm_specs(cfg)
        specs["head"] = P.ParamSpec(
            (cfg.d_model, cfg.vocab_size), cfg.param_jdtype, "normal",
            ("embed", "vocab"))
    return specs


def _make_stage_core_encdec(cfg: ArchConfig, s: int, n_stages: int
                            ) -> Callable:
    """Stage ``s``'s float-to-float core: ``(params, floats, ints) ->
    out_floats``.  Integer token ids ride the boundary tree untouched
    (the wrappers below pass them around every vjp), so cross-attention
    gradients flow stage-to-stage through purely floating cotangent
    trees: boundary 0 ships ``{"enc"}``, interior boundaries
    ``{"x", "enc"}`` — the encoder pod hand-off sits exactly at the
    cross-attention boundary."""
    from repro.models import whisper as W
    is_enc, first_dec = s == 0, s == 1
    is_last = s == n_stages - 1

    def core(params: Tree, floats: Tree, ints: Tree) -> Tree:
        if is_enc:
            return {"enc": W.encode(cfg, params, floats["audio"])}
        enc = floats["enc"].astype(cfg.compute_jdtype)
        if first_dec:
            x = W.embed_tokens(cfg, params["embed"], ints["tok"])
        else:
            x = floats["x"].astype(cfg.compute_jdtype)
        x = W.dec_scan(cfg, params["dec_blocks"], x, enc,
                       jnp.arange(x.shape[1]))
        return {"x": x} if is_last else {"x": x, "enc": enc}

    return core


def _build_stage_programs_encdec(cfg: ArchConfig, n_stages: int,
                                 seq_len: int,
                                 trace_hook: Optional[Callable]
                                 ) -> list[StageProgram]:
    programs = []
    for s in range(n_stages):
        specs = _stage_specs_encdec(cfg, s, n_stages)
        core = _make_stage_core_encdec(cfg, s, n_stages)
        is_enc, is_last = s == 0, s == n_stages - 1

        if is_last:
            def fwd(params, inp, labels, _c=core):
                floats, ints = _split_payload(inp)
                return _head_loss(cfg, params,
                                  _c(params, floats, ints)["x"], labels)

            def bwd(params, inp, labels, _c=core):
                floats, ints = _split_payload(inp)

                def sl(p, f):
                    return _head_loss(cfg, p, _c(p, f, ints)["x"], labels)
                loss, (gp, gf) = jax.value_and_grad(sl, argnums=(0, 1))(
                    params, floats)
                return loss, gf, gp
        elif is_enc:
            def fwd(params, inp, _c=core):
                floats, ints = _split_payload(inp)
                return {**_c(params, floats, ints), **ints}

            def bwd(params, inp, dy, _c=core):
                floats, ints = _split_payload(inp)
                dy_f, _ = _split_payload(dy)
                y, pullback = jax.vjp(lambda p: _c(p, floats, ints), params)
                (gp,) = pullback(_cast_like(dy_f, y))
                return None, gp
        else:
            def fwd(params, inp, _c=core):
                floats, ints = _split_payload(inp)
                return {**_c(params, floats, ints), **ints}

            def bwd(params, inp, dy, _c=core):
                floats, ints = _split_payload(inp)
                dy_f, _ = _split_payload(dy)
                y, pullback = jax.vjp(
                    lambda p, f: _c(p, f, ints), params, floats)
                gp, gf = pullback(_cast_like(dy_f, y))
                return gf, gp

        fwd_f = _stage_fwd_flops(cfg, s, n_stages, seq_len, "none", False)
        programs.append(StageProgram(
            stage=s, n_stages=n_stages, specs=specs,
            fwd=_traced(fwd, trace_hook, s, "fwd"),
            bwd=_traced(bwd, trace_hook, s, "bwd"),
            fwd_flops_per_token=fwd_f, bwd_flops_per_token=3.0 * fwd_f,
            fwd_fn=fwd, bwd_fn=bwd))
    return programs


def _build_span_encdec(cfg: ArchConfig, n_stages: int, seq_len: int,
                       span: tuple[int, int],
                       trace_hook: Optional[Callable]) -> SpanProgram:
    lo, hi = span
    covers_last = hi == n_stages
    plan = get_stage_plan(cfg, n_stages)
    specs = {s: _stage_specs_encdec(cfg, s, n_stages)
             for s in range(lo, hi)}
    cores = {s: _make_stage_core_encdec(cfg, s, n_stages)
             for s in range(lo, hi)}
    fwd_f = sum(_stage_fwd_flops(cfg, s, n_stages, seq_len, "none", False)
                for s in range(lo, hi))
    # plan-driven fusion: contiguous structurally identical decoder
    # stages scan as one group; the encoder/embed/head stages hand off
    # sequentially at their kind boundaries
    groups = [(s0 - lo, c) for s0, c in plan.fusion_groups(span)]

    def span_core(ps, floats, ints):
        cur = floats
        for start, count in groups:
            f = cores[lo + start]
            if count >= 2:
                members = [ps[i] for i in range(start, start + count)]
                stacked = jax.tree.map(
                    lambda *xs: restack(list(xs)), *members)
                stacked = jax.tree.map(
                    lambda a: constrain(a, "pod", *([None] * (a.ndim - 1))),
                    stacked)

                def body(c, p_s, _f=f):
                    return _f(p_s, c, ints), None
                cur, _ = jax.lax.scan(body, cur, stacked)
            else:
                cur = f(ps[start], cur, ints)
        return cur

    if covers_last:
        def span_loss(ps, floats, ints, labels):
            return _head_loss(cfg, ps[-1],
                              span_core(ps, floats, ints)["x"], labels)

        def fwd(ps, inp, labels):
            floats, ints = _split_payload(inp)
            return span_loss(ps, floats, ints, labels)

        if lo == 0:
            def bwd(ps, inp, labels):
                floats, ints = _split_payload(inp)
                loss, gp = jax.value_and_grad(span_loss)(
                    ps, floats, ints, labels)
                return loss, None, gp
        else:
            def bwd(ps, inp, labels):
                floats, ints = _split_payload(inp)
                loss, (gp, gf) = jax.value_and_grad(
                    span_loss, argnums=(0, 1))(ps, floats, ints, labels)
                return loss, gf, gp
    else:
        def fwd(ps, inp):
            floats, ints = _split_payload(inp)
            return {**span_core(ps, floats, ints), **ints}

        if lo == 0:
            def bwd(ps, inp, dy):
                floats, ints = _split_payload(inp)
                dy_f, _ = _split_payload(dy)
                y, pullback = jax.vjp(
                    lambda p: span_core(p, floats, ints), ps)
                (gp,) = pullback(_cast_like(dy_f, y))
                return None, gp
        else:
            def bwd(ps, inp, dy):
                floats, ints = _split_payload(inp)
                dy_f, _ = _split_payload(dy)
                y, pullback = jax.vjp(
                    lambda p, f: span_core(p, f, ints), ps, floats)
                gp, gf = pullback(_cast_like(dy_f, y))
                return gf, gp

    return SpanProgram(
        span=(lo, hi), n_stages=n_stages, specs=specs,
        fwd=_traced(fwd, trace_hook, (lo, hi), "fwd"),
        bwd=_traced(bwd, trace_hook, (lo, hi), "bwd"),
        fwd_flops_per_token=fwd_f, bwd_flops_per_token=3.0 * fwd_f,
        fwd_fn=fwd, bwd_fn=bwd)


def build_stage_programs(cfg: ArchConfig, n_stages: int, seq_len: int,
                         compress: Optional[str] = None,
                         trace_hook: Optional[Callable] = None
                         ) -> list[StageProgram]:
    get_stage_plan(cfg, n_stages)      # validates the split (ValueError)
    comp = codecs.resolve_mode(cfg, compress)
    learned = comp in codecs.LEARNED and n_stages > 1
    if cfg.encoder_layers:
        if learned:
            raise NotImplementedError(
                "learned boundary codecs are unsupported for "
                "encoder-decoder stage programs (tree-valued boundaries)")
        return _build_stage_programs_encdec(cfg, n_stages, seq_len,
                                            trace_hook)
    programs = []
    for s in range(n_stages):
        specs = _stage_specs(cfg, s, n_stages, comp, learned)
        stage_fwd = _make_stage_fwd(cfg, s, n_stages, comp, learned)
        is_first, is_last = s == 0, s == n_stages - 1

        def stage_loss(params, inp, labels, _fwd=stage_fwd):
            return _head_loss(cfg, params, _fwd(params, inp), labels)

        if is_last:
            def fwd(params, inp, labels, _sl=stage_loss):
                return _sl(params, inp, labels)

            def bwd(params, inp, labels, _sl=stage_loss):
                if is_first_and_last := (n_stages == 1):
                    (loss), g = jax.value_and_grad(_sl)(params, inp, labels)
                    return loss, None, g
                (loss), (gp, gx) = jax.value_and_grad(_sl, argnums=(0, 1))(
                    params, inp, labels)
                return loss, gx, gp
        elif is_first:
            def fwd(params, inp, _sf=stage_fwd):
                return _sf(params, inp)

            def bwd(params, inp, dy, _sf=stage_fwd):
                y, pullback = jax.vjp(lambda p: _sf(p, inp), params)
                (gp,) = pullback(dy.astype(y.dtype))
                return None, gp
        else:
            def fwd(params, inp, _sf=stage_fwd):
                return _sf(params, inp)

            def bwd(params, inp, dy, _sf=stage_fwd):
                y, pullback = jax.vjp(_sf, params, inp)
                gp, gx = pullback(dy.astype(y.dtype))
                return gx, gp
        fwd_j = _traced(fwd, trace_hook, s, "fwd")
        bwd_j = _traced(bwd, trace_hook, s, "bwd")

        fwd_f = _stage_fwd_flops(cfg, s, n_stages, seq_len, comp, learned)
        programs.append(StageProgram(
            stage=s, n_stages=n_stages, specs=specs, fwd=fwd_j, bwd=bwd_j,
            fwd_flops_per_token=fwd_f,
            bwd_flops_per_token=3.0 * fwd_f,   # recompute + 2x backward
            fwd_fn=fwd, bwd_fn=bwd,
        ))
    return programs


# ------------------------------------------------------------- span fusion
def _span_fingerprint(cfg: ArchConfig, s: int, n_stages: int, comp: str,
                      learned: bool, specs_s: Tree):
    """Two covered stages may share one scan slot iff this matches: same
    plan structure (runs/reps/edge ownership) and bit-identical
    param-tree geometry."""
    spec = get_stage_plan(cfg, n_stages).stages[s]
    leaves, treedef = jax.tree.flatten(specs_s, is_leaf=P.is_spec)
    return spec.structural_key + (treedef, tuple(leaves))


def _scan_groups(fingerprints: list) -> list[tuple[int, int]]:
    """Maximal runs of consecutive equal fingerprints, as (start, count)
    over span-local indices."""
    groups, i = [], 0
    while i < len(fingerprints):
        j = i + 1
        while j < len(fingerprints) and fingerprints[j] == fingerprints[i]:
            j += 1
        groups.append((i, j - i))
        i = j
    return groups


def build_span_program(cfg: ArchConfig, n_stages: int, seq_len: int,
                       span: tuple[int, int],
                       compress: Optional[str] = None,
                       trace_hook: Optional[Callable] = None
                       ) -> SpanProgram:
    """Fuse stages ``[lo, hi)`` into one jitted fwd/bwd.

    The single-jit span step is what lets a well-provisioned peer hold
    *more of the model* (the paper's square-cube rebalancing; Varuna's
    stage fusion): intra-span boundaries stay on-device — under a learned
    codec the sending stage's in-program compress chains into the
    receiving stage's decompress, reproducing the single-stage math
    exactly, with zero host bytes for the fused boundary.  Runs of
    structurally identical covered stages are stacked along a leading
    stage dim with :func:`repro.dist.pipeline.restack` (constrained to
    ``pod`` when a mesh is ambient — the same sharded stacking the GSPMD
    tick uses, so the XLA-0.4.x concat workaround is load-bearing here
    too) and executed as a ``lax.scan`` over stages.
    """
    lo, hi = span
    if not (0 <= lo < hi <= n_stages):
        raise ValueError(f"span [{lo}, {hi}) outside [0, {n_stages})")
    get_stage_plan(cfg, n_stages)      # validates the split (ValueError)
    comp = codecs.resolve_mode(cfg, compress)
    learned = comp in codecs.LEARNED and n_stages > 1
    if cfg.encoder_layers:
        if learned:
            raise NotImplementedError(
                "learned boundary codecs are unsupported for "
                "encoder-decoder span programs (tree-valued boundaries)")
        return _build_span_encdec(cfg, n_stages, seq_len, span, trace_hook)
    covers_last = hi == n_stages

    specs: dict[int, Tree] = {}
    fwds: dict[int, Callable] = {}
    fprints = []
    fwd_f = 0.0
    for s in range(lo, hi):
        specs[s] = _stage_specs(cfg, s, n_stages, comp, learned)
        fwds[s] = _make_stage_fwd(cfg, s, n_stages, comp, learned)
        fprints.append(_span_fingerprint(cfg, s, n_stages, comp, learned,
                                         specs[s]))
        fwd_f += _stage_fwd_flops(cfg, s, n_stages, seq_len, comp, learned)
    groups = _scan_groups(fprints)

    def span_fwd(params_by_stage, inp):
        """(tuple ordered lo..hi-1, inbound wire) -> hidden (covers_last)
        or outbound wire tensor."""
        x = inp
        for start, count in groups:
            f = fwds[lo + start]
            if count >= 2:
                members = [params_by_stage[i]
                           for i in range(start, start + count)]
                stacked = jax.tree.map(
                    lambda *xs: restack(list(xs)), *members)
                stacked = jax.tree.map(
                    lambda a: constrain(a, "pod", *([None] * (a.ndim - 1))),
                    stacked)

                def body(x, p_s, _f=f):
                    return _f(p_s, x), None
                x, _ = jax.lax.scan(body, x, stacked)
            else:
                x = f(params_by_stage[start], x)
        return x

    if covers_last:
        def span_loss(ps, inp, labels, _sf=span_fwd):
            return _head_loss(cfg, ps[-1], _sf(ps, inp), labels)

        def fwd(ps, inp, labels, _sl=span_loss):
            return _sl(ps, inp, labels)

        if lo == 0:
            def bwd(ps, inp, labels, _sl=span_loss):
                loss, gp = jax.value_and_grad(_sl)(ps, inp, labels)
                return loss, None, gp
        else:
            def bwd(ps, inp, labels, _sl=span_loss):
                loss, (gp, gx) = jax.value_and_grad(_sl, argnums=(0, 1))(
                    ps, inp, labels)
                return loss, gx, gp
    else:
        def fwd(ps, inp, _sf=span_fwd):
            return _sf(ps, inp)

        if lo == 0:
            def bwd(ps, inp, dy, _sf=span_fwd):
                y, pullback = jax.vjp(lambda p: _sf(p, inp), ps)
                (gp,) = pullback(dy.astype(y.dtype))
                return None, gp
        else:
            def bwd(ps, inp, dy, _sf=span_fwd):
                y, pullback = jax.vjp(_sf, ps, inp)
                gp, gx = pullback(dy.astype(y.dtype))
                return gx, gp

    return SpanProgram(
        span=(lo, hi), n_stages=n_stages, specs=specs,
        fwd=_traced(fwd, trace_hook, (lo, hi), "fwd"),
        bwd=_traced(bwd, trace_hook, (lo, hi), "bwd"),
        fwd_flops_per_token=fwd_f, bwd_flops_per_token=3.0 * fwd_f,
        fwd_fn=fwd, bwd_fn=bwd)


def init_stage_params(programs: list[StageProgram], key: jax.Array
                      ) -> list[Tree]:
    keys = jax.random.split(key, len(programs))
    return [P.init(k, p.specs) for k, p in zip(keys, programs)]


def split_whisper_params(cfg: ArchConfig, n_stages: int,
                         params: Tree) -> list[Tree]:
    """Slice a full whisper tree (``models.whisper.whisper_specs``
    layout) into per-stage trees shaped like the enc-dec stage programs
    — exact (every leaf a copy or slice), so the staged pipeline matches
    ``whisper_apply`` bit-for-bit."""
    per = cfg.n_layers // (n_stages - 1)
    out: list[Tree] = [{"enc_blocks": params["enc_blocks"],
                        "enc_norm": params["enc_norm"]}]
    for s in range(1, n_stages):
        lo = (s - 1) * per
        st: Tree = {"dec_blocks": jax.tree.map(
            lambda a, _lo=lo: a[_lo:_lo + per], params["dec_blocks"])}
        if s == 1:
            st["embed"] = params["embed"]
        if s == n_stages - 1:
            st["final_norm"] = params["final_norm"]
            st["head"] = params["head"]
        out.append(st)
    return out


def split_lm_params(cfg: ArchConfig, n_stages: int, params: Tree,
                    compress: Optional[str] = None) -> list[Tree]:
    """Slice a full-model param tree (``repro.models.model.lm_specs``
    layout) into per-stage trees shaped like :func:`_stage_specs` — how
    weights trained or loaded through the single-process path get served
    by a staged swarm.  Exact: every leaf is a copy or a slice of the
    original, so staged forward/decode matches the full model
    bit-for-bit (the serving equivalence test relies on this).

    Learned boundary codecs are unsupported: the single-process tree
    carries the GSPMD pipeline's per-boundary codec stack, not the
    per-stage ``w_c``/``w_d`` split the stage programs own.
    """
    comp = codecs.resolve_mode(cfg, compress)
    if comp in codecs.LEARNED and n_stages > 1:
        raise NotImplementedError(
            "split_lm_params cannot split learned boundary-codec params; "
            "init per-stage codec weights via init_stage_params instead")
    assert cfg.n_layers % n_stages == 0
    per = cfg.n_layers // n_stages
    if not cfg.share_groups:
        per_layer: list[Tree] = []
        for (kind, n), seg in zip(model_lib.segments(cfg.block_kinds),
                                  params["blocks"]):
            for i in range(n):
                per_layer.append(jax.tree.map(lambda a, _i=i: a[_i], seg))
    out: list[Tree] = []
    for s in range(n_stages):
        if cfg.share_groups:
            # one shared group per stage (stage s applies group s
            # `per` times) — slice keeps the leading stack dim of 1
            blocks = [jax.tree.map(lambda a, _s=s: a[_s:_s + 1],
                                   params["blocks"][0])]
        else:
            blocks, idx = [], s * per
            for kind, n in model_lib.segments(
                    cfg.block_kinds[s * per:(s + 1) * per]):
                trees = per_layer[idx:idx + n]
                idx += n
                blocks.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *trees))
        st: Tree = {"blocks": blocks}
        if s == 0:
            st["embed"] = params["embed"]
        if s == n_stages - 1:
            st["final_norm"] = params["final_norm"]
            if not cfg.tie_embeddings:
                st["head"] = params["head"]
            elif s != 0:
                # tied embeddings with the embed table on another stage:
                # the last stage materializes the tied head
                st["head"] = params["embed"].T
        out.append(st)
    return out
