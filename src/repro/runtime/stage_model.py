"""Partition an ArchConfig into SWARM pipeline stages (stage programs).

Stage 0 additionally owns the embedding, the last stage the final norm +
LM head + loss (mirroring the paper's §4.3 placement).  Backward runs via
activation checkpointing: a stage recomputes its forward from the boundary
input it is handed, so backward can be re-routed to *any* peer of the stage
after a failure (App. A).

Under a learned boundary codec (paper App. J: ``compress="bottleneck"`` /
``"maxout"``) each stage's program *includes* its side of the codec: a
sending stage compresses its output (owning ``w_c`` for the bottleneck), a
receiving stage decompresses its input (owning ``w_d``) — so the tensor a
trainer carries between peers IS the c-dim wire tensor, and codec gradients
arrive through the ordinary per-stage ``bwd`` like any other parameter.
``"int8"`` stays outside the programs (the trainer round-trips the wire
tensor), matching SWARM's quantize-on-send.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.compression import codecs
from repro.models.config import ArchConfig
from repro.models import params as P
from repro.models import layers as L
from repro.models import model as model_lib
from repro.models.blocks import REGISTRY
from repro.models import flops as F
from repro.train.steps import cross_entropy

Tree = Any


@dataclasses.dataclass
class StageProgram:
    stage: int
    n_stages: int
    specs: Tree
    fwd: Callable                 # jitted
    bwd: Callable                 # jitted
    fwd_flops_per_token: float
    bwd_flops_per_token: float    # includes checkpoint recompute
    fwd_fn: Optional[Callable] = None   # unjitted (mesh backends re-jit
    bwd_fn: Optional[Callable] = None   # with their own shardings)


def _traced(fn: Callable, hook: Optional[Callable], stage: int, kind: str
            ) -> Callable:
    """Jit ``fn``; if ``hook`` is given, call it once per XLA trace (the
    body side effect runs at trace time only) with the argument shapes —
    the runtime layer's retrace counter hangs off this."""
    if hook is None:
        return jax.jit(fn)

    def counted(*args):
        hook(stage, kind, tuple(tuple(a.shape) for a in args
                                if hasattr(a, "shape")))
        return fn(*args)
    return jax.jit(counted)


def _stage_slice(cfg: ArchConfig, stage: int, n_stages: int):
    per = cfg.n_layers // n_stages
    lo, hi = stage * per, (stage + 1) * per
    if cfg.share_groups:
        # one shared parameter group per stage (paper §4.3: 3 stages x 16
        # shared layers); reuse count = layers per stage
        assert cfg.share_groups == n_stages, (
            "share_groups must equal n_stages for the paper's model")
        return cfg.block_kinds[lo:hi], True
    return cfg.block_kinds[lo:hi], False


def build_stage_programs(cfg: ArchConfig, n_stages: int, seq_len: int,
                         compress: Optional[str] = None,
                         trace_hook: Optional[Callable] = None
                         ) -> list[StageProgram]:
    assert cfg.n_layers % n_stages == 0
    assert cfg.encoder_layers == 0, "enc-dec archs use pod-DP (DESIGN §5)"
    comp = codecs.resolve_mode(cfg, compress)
    learned = comp in codecs.LEARNED and n_stages > 1
    programs = []
    for s in range(n_stages):
        kinds, shared = _stage_slice(cfg, s, n_stages)
        runs = model_lib.segments(kinds)
        if shared:
            runs = [(kinds[0], 1)]          # single shared group
        reps = len(kinds) if shared else 1

        specs: Tree = {"blocks": [
            model_lib.stack_specs(REGISTRY[k][0](cfg), n) for k, n in runs]}
        if s == 0:
            specs["embed"] = P.ParamSpec(
                (cfg.vocab_size, cfg.d_model), cfg.param_jdtype, "embed",
                ("vocab", "embed"))
        if s == n_stages - 1:
            specs["final_norm"] = L.norm_specs(cfg)
            if not cfg.tie_embeddings or s != 0:
                specs["head"] = P.ParamSpec(
                    (cfg.d_model, cfg.vocab_size), cfg.param_jdtype,
                    "normal", ("embed", "vocab"))
        if learned:
            # receiving side (w_d) for s > 0, sending side (w_c) for
            # s < S-1; maxout's compress is param-free so its stage-0
            # "boundary" tree is empty and omitted
            bnd: Tree = {}
            if s > 0:
                bnd.update(codecs.receiver_specs(cfg, comp))
            if s < n_stages - 1:
                bnd.update(codecs.sender_specs(cfg, comp))
            if bnd:
                specs["boundary"] = bnd

        def run_blocks(params, x, _runs=runs, _reps=reps):
            positions = jnp.arange(x.shape[1])
            for (kind, _), seg in zip(_runs, params["blocks"]):
                apply_fn = REGISTRY[kind][1]

                def body(x, p_l, _a=apply_fn, _r=_reps):
                    for _ in range(_r):
                        x, _aux = _a(cfg, p_l, x, positions)
                    return x, None
                x, _ = jax.lax.scan(body, x, seg)
            return x

        is_first, is_last = s == 0, s == n_stages - 1

        def stage_fwd(params, inp, _rb=run_blocks, _first=is_first,
                      _last=is_last):
            if _first:
                tokens = inp
                x = params["embed"][tokens].astype(cfg.compute_jdtype)
                if cfg.scale_embed:
                    x = x * (cfg.d_model ** 0.5)
            else:
                x = inp.astype(cfg.compute_jdtype)
                if learned:          # wire tensor arrives c-dim: restore
                    x = codecs.decompress(cfg, comp,
                                          params.get("boundary"), x)
            x = _rb(params, x)
            if learned and not _last:    # emit the c-dim wire tensor
                x = codecs.compress(cfg, comp, params.get("boundary"), x)
            return x

        def stage_loss(params, inp, labels, _fwd=stage_fwd):
            x = _fwd(params, inp)
            x = L.apply_norm(cfg, params["final_norm"], x)
            w = (params["embed"].T if cfg.tie_embeddings and "head" not in
                 params else params["head"])
            logits = x @ w.astype(x.dtype)
            # token-sum CE so microbatch gradients add exactly (App. E)
            logits = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[..., None],
                                       axis=-1)[..., 0]
            return jnp.sum(lse - gold)

        if is_last:
            def fwd(params, inp, labels, _sl=stage_loss):
                return _sl(params, inp, labels)

            def bwd(params, inp, labels, _sl=stage_loss):
                if is_first_and_last := (n_stages == 1):
                    (loss), g = jax.value_and_grad(_sl)(params, inp, labels)
                    return loss, None, g
                (loss), (gp, gx) = jax.value_and_grad(_sl, argnums=(0, 1))(
                    params, inp, labels)
                return loss, gx, gp
        elif is_first:
            def fwd(params, inp, _sf=stage_fwd):
                return _sf(params, inp)

            def bwd(params, inp, dy, _sf=stage_fwd):
                y, pullback = jax.vjp(lambda p: _sf(p, inp), params)
                (gp,) = pullback(dy.astype(y.dtype))
                return None, gp
        else:
            def fwd(params, inp, _sf=stage_fwd):
                return _sf(params, inp)

            def bwd(params, inp, dy, _sf=stage_fwd):
                y, pullback = jax.vjp(_sf, params, inp)
                gp, gx = pullback(dy.astype(y.dtype))
                return gx, gp
        fwd_j = _traced(fwd, trace_hook, s, "fwd")
        bwd_j = _traced(bwd, trace_hook, s, "bwd")

        ctx = F._ctx_for(cfg, seq_len, causal_avg=True)
        layer_f = sum(F.per_token_layer_flops(cfg, k, ctx) for k in kinds)
        head_f = 2 * cfg.d_model * cfg.vocab_size if is_last else 0.0
        codec_f = codecs.codec_flops_per_token(
            cfg, comp, sender=learned and not is_last,
            receiver=learned and not is_first)
        fwd_f = layer_f + head_f + codec_f
        programs.append(StageProgram(
            stage=s, n_stages=n_stages, specs=specs, fwd=fwd_j, bwd=bwd_j,
            fwd_flops_per_token=fwd_f,
            bwd_flops_per_token=3.0 * fwd_f,   # recompute + 2x backward
            fwd_fn=fwd, bwd_fn=bwd,
        ))
    return programs


def init_stage_params(programs: list[StageProgram], key: jax.Array
                      ) -> list[Tree]:
    keys = jax.random.split(key, len(programs))
    return [P.init(k, p.specs) for k, p in zip(keys, programs)]
