"""Stage-runtime layer: one executor protocol, many peer backends.

Layering (see README "Architecture"):

    repro.core  (elastic scheduler: wiring / ledger / rebalance)
        │   routes microbatches + lifecycle events to peers
        ▼
    repro.runtime.StageExecutor   (this package: the protocol)
        ├── NumericExecutor  — single-device stage math, process-wide
        │                      compile cache (one jit per stage, shared
        │                      by every peer of that stage)
        └── MeshExecutor     — the same stage step sharded over a device
                               mesh via repro.dist sharding rules
                               (data-parallel within the peer)
"""
from repro.runtime.base import StageExecutor, StageState, host_snapshot
from repro.runtime.stage_model import (StageProgram, build_stage_programs,
                                       init_stage_params)
from repro.runtime.numeric import (NumericExecutor, build_numeric_executors,
                                   compile_stats, get_stage_programs,
                                   reset_compile_stats)
from repro.runtime.mesh import MeshExecutor

__all__ = [
    "StageExecutor", "StageState", "host_snapshot",
    "StageProgram", "build_stage_programs", "init_stage_params",
    "NumericExecutor", "MeshExecutor", "build_numeric_executors",
    "get_stage_programs", "compile_stats", "reset_compile_stats",
]
