"""Stage-runtime layer: one executor protocol, many peer backends.

Layering (see README "Architecture"):

    repro.core  (elastic scheduler: wiring / ledger / rebalance)
        │   routes microbatches + lifecycle events to peers
        ▼
    repro.runtime.StageExecutor   (this package: the protocol)
        ├── NumericExecutor   — single-device stage math, process-wide
        │                       compile cache (one jit per stage, shared
        │                       by every peer of that stage)
        ├── MeshExecutor      — the same stage step sharded over a device
        │                       mesh via repro.dist sharding rules
        │                       (data-parallel within the peer)
        ├── PipelineExecutor  — a contiguous SPAN of stages [lo, hi)
        │                       fused into one jit (square-cube: strong
        │                       peers hold more of the model); intra-span
        │                       boundaries never cross the host
        └── MeshSpanExecutor  — span fusion × mesh backing: the fused
                                span step sharded over a device mesh,
                                intra-span boundaries device-to-device
"""
from repro.runtime.base import StageExecutor, StageState, host_snapshot
from repro.runtime.stage_model import (SpanProgram, StageProgram,
                                       build_span_program,
                                       build_stage_programs,
                                       init_stage_params)
from repro.runtime.numeric import (NumericExecutor, build_numeric_executors,
                                   compile_stats, get_span_program,
                                   get_stage_programs, reset_compile_stats)
from repro.runtime.mesh import MeshExecutor, MeshSpanExecutor
from repro.runtime.pipeline import PipelineExecutor

__all__ = [
    "StageExecutor", "StageState", "host_snapshot",
    "StageProgram", "SpanProgram", "build_stage_programs",
    "build_span_program", "init_stage_params",
    "NumericExecutor", "MeshExecutor", "MeshSpanExecutor",
    "PipelineExecutor",
    "build_numeric_executors", "get_stage_programs", "get_span_program",
    "compile_stats", "reset_compile_stats",
]
