"""PipelineExecutor — a SWARM peer serving a contiguous *span* of stages.

SWARM's square-cube argument (paper §3.1) says a well-provisioned peer
should hold *more of the model*, not more replicas of one slice; Varuna
reaches the same conclusion for preemptible fleets by fusing consecutive
pipeline stages on one worker and re-partitioning on membership change.
This backend is that lever: one peer serves stages ``[lo, hi)`` in a
SINGLE jitted step (:class:`repro.runtime.stage_model.SpanProgram`,
which reuses the ``repro.dist`` stage core and restack/stage-scan
machinery), so

* intra-span boundaries stay on-device — under a learned codec the
  in-program compress/decompress pair still runs (the math is identical
  to single-stage peers, which is what the span churn-equivalence test
  asserts), but zero bytes cross the host;
* the wire codec (``wire_fwd``/``wire_bwd``, e.g. SWARM's int8
  quantize-on-send) applies only at span *edges*, where the activation
  really crosses the network;
* one fwd + one bwd compile per (span, codec) process-wide — N span
  peers of one span share the jits, same discipline as the per-stage
  cache (``benchmarks/bench_swarm.py`` asserts it).

State is *per-stage-keyed* (``StageState.per_stage``): every covered
stage keeps its own params/opt/accumulator/version, so

* the All-Reduce groups per stage still work — a span peer joins one
  group per covered stage, exporting/adopting per-stage trees;
* checkpoint cuts write ordinary single-stage snapshots;
* a dying or shrinking span peer hands per-stage snapshots to
  single-stage peers, and a merge pulls them back — numeric ↔ mesh ↔
  pipeline state downloads all interoperate through the same
  single-stage host-tree wire format.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compression import codecs
from repro.models.config import ArchConfig
from repro.models import params as P
from repro.runtime.base import StageState, fold_into, host_snapshot, \
    install_snapshot, slot_export, slot_install, wire_bwd_codec, \
    wire_fwd_codec
from repro.runtime import numeric as numeric_rt

Tree = Any


class PipelineExecutor:
    """Run stages ``[lo, hi)`` fused in one jit on a single device."""

    device_count = 1

    def __init__(self, cfg: ArchConfig, n_stages: int, seq_len: int,
                 span: tuple[int, int], compress: Optional[str] = None,
                 quant_block: int = 64):
        lo, hi = span
        if not (0 <= lo < hi <= n_stages):
            raise ValueError(f"span [{lo}, {hi}) outside [0, {n_stages})")
        self.cfg = cfg
        self.n_stages = n_stages
        self.seq_len = seq_len
        self.span = (lo, hi)
        self.stage = lo                       # entry stage
        from repro.models.stage_plan import get_stage_plan
        self.plan = get_stage_plan(cfg, n_stages)
        self.compress_mode = codecs.resolve_mode(cfg, compress)
        self.quant_block = quant_block
        self.prog = numeric_rt.get_span_program(
            cfg, n_stages, seq_len, (lo, hi), self.compress_mode)
        self.fwd_flops_per_token = self.prog.fwd_flops_per_token
        self.bwd_flops_per_token = self.prog.bwd_flops_per_token

    @property
    def stages(self) -> range:
        return range(*self.span)

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState:
        state = StageState(per_stage={})
        keys = jax.random.split(key, len(self.stages))
        for k, s in zip(keys, self.stages):
            sub = StageState(params=P.init(k, self.prog.specs[s]))
            sub.reset_progress()
            state.per_stage[s] = sub
        return state

    def for_span(self, span: range) -> "StageExecutor":
        if (span.start, span.stop) == self.span:
            return self
        if len(span) == 1:
            from repro.runtime.numeric import build_numeric_executors
            return build_numeric_executors(
                self.cfg, self.n_stages, self.seq_len,
                compress=self.compress_mode,
                quant_block=self.quant_block)[span.start]
        return PipelineExecutor(self.cfg, self.n_stages, self.seq_len,
                                (span.start, span.stop),
                                compress=self.compress_mode,
                                quant_block=self.quant_block)

    def for_stage(self, stage: int) -> "StageExecutor":
        return self.for_span(range(stage, stage + 1))

    def dp_shards(self, batch: int) -> int:
        del batch
        return 1

    def session_program(self, total_len: int):
        from repro.serve.programs import get_session_program
        return get_session_program(self.cfg, self.n_stages, self.span,
                                   total_len, compress=self.compress_mode)

    # ------------------------------------------------------------ helpers
    def _params_tuple(self, state: StageState) -> tuple:
        return tuple(state.per_stage[s].params for s in self.stages)

    def _covers_last(self) -> bool:
        return self.span[1] == self.n_stages

    def _require(self, stage: Optional[int]) -> int:
        if stage is None:
            raise ValueError(
                f"span executor [{self.span[0]}, {self.span[1]}) needs an "
                "explicit covered stage for per-stage state operations")
        if stage not in self.stages:
            raise ValueError(f"stage {stage} outside span {self.span}")
        return stage

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        ps = self._params_tuple(state)
        if self._covers_last():
            return self.prog.fwd(ps, inp, labels)
        return self.prog.fwd(ps, inp)

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None):
        ps = self._params_tuple(state)
        if self._covers_last():
            loss, gx, gp = self.prog.bwd(ps, inp, labels)
        else:
            loss = None
            gx, gp = self.prog.bwd(ps, inp, dy)
        # per-stage grads keyed by GLOBAL stage id: the scheduler folds
        # each covered stage independently (the ledger may admit a
        # subset of them on a re-issued attempt)
        gp = {s: g for s, g in zip(self.stages, gp)}
        return loss, gx, gp

    # ------------------------------------------------- dispatch / collect
    def dispatch_fwd(self, state: StageState, inp: Tree,
                     labels: Optional[jax.Array] = None):
        # the fused span jit dispatches asynchronously; collect hands
        # over the in-flight futures
        y = self.run_fwd(state, inp, labels)
        return lambda: y

    def dispatch_bwd(self, state: StageState, inp: Tree,
                     dy: Optional[Tree] = None,
                     labels: Optional[jax.Array] = None):
        out = self.run_bwd(state, inp, dy, labels)
        return lambda: out

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        return wire_fwd_codec(self, y)          # span-edge only

    def wire_bwd(self, gx: Tree) -> Tree:
        return wire_bwd_codec(self, gx)

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int,
                   stage: Optional[int] = None) -> None:
        s = self._require(stage)
        fold_into(state.per_stage[s], gp, loss, n_tokens)

    def export_grads(self, state: StageState,
                     stage: Optional[int] = None) -> Tree:
        return state.per_stage[self._require(stage)].grad_acc

    def export_state(self, state: StageState,
                     stage: Optional[int] = None):
        sub = state.per_stage[self._require(stage)]
        return sub.params, sub.opt

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree, stage: Optional[int] = None) -> None:
        sub = state.per_stage[self._require(stage)]
        sub.params = new_params
        sub.opt = new_opt
        sub.version += 1
        sub.reset_progress()

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState, stage: Optional[int] = None,
                 slots=()) -> Tree:
        """Single-stage-format snapshot of one covered stage, or (with
        ``stage=None``) the whole span as ``{"per_stage": {s: snap}}`` —
        the former is the interop format every hand-off uses."""
        if stage is None:
            return {"per_stage": {
                s: host_snapshot(state.per_stage[s], slots=slots)
                for s in self.stages}}
        return host_snapshot(state.per_stage[self._require(stage)],
                             slots=slots)

    def restore(self, state: StageState, snap: Tree,
                stage: Optional[int] = None, slots=()) -> None:
        if state.per_stage is None:
            state.per_stage = {}
        if stage is None:
            for s, sub_snap in snap["per_stage"].items():
                self.restore(state, sub_snap, stage=int(s), slots=slots)
            return
        s = self._require(stage)
        sub = state.per_stage.setdefault(s, StageState())
        install_snapshot(sub, snap, slots=slots)

    # ------------------------------------------------------ keyed slots
    def export_slot(self, state: StageState, name: str, key,
                    stage: Optional[int] = None) -> Tree:
        return slot_export(state.per_stage[self._require(stage)], name, key)

    def install_slot(self, state: StageState, name: str, key, value: Tree,
                     stage: Optional[int] = None) -> None:
        slot_install(state.per_stage[self._require(stage)], name, key,
                     value)

    def drop_slot(self, state: StageState, name: str, key=None,
                  stage: Optional[int] = None) -> None:
        if stage is None:
            for sub in state.views():
                sub.drop_slot(name, key)
            return
        state.per_stage[self._require(stage)].drop_slot(name, key)
