"""MeshExecutor — a SWARM peer backed by a device mesh.

The paper's swarms are heterogeneous (§3, and the pooled-hardware
setting of Diskin et al.): one "peer" may be a lone preemptible T4,
another an 8-device node.  This executor makes the latter a first-class
pipeline citizen: the peer's stage step runs *sharded* over its mesh via
the ``repro.dist`` sharding rules — parameters placed by their logical
axes (:class:`repro.dist.sharding.ShardingRules`), the microbatch split
over the mesh's ``data`` axis — while the elastic scheduler above
remains oblivious: routing, the microbatch ledger, warm joins and
migrations all speak the same :class:`~repro.runtime.base.StageExecutor`
protocol as single-device peers.

The wire is the host: ``wire_fwd``/``wire_bwd`` gather the boundary
tensor off the mesh (after the int8 round-trip, when active), exactly
modelling SWARM's network crossing — so a mesh-backed peer can hand
activations to a single-device peer and vice versa, and state downloads
(``snapshot``/``restore``) recommit the replicated stage state onto
whichever backend the receiving peer runs.

Jitted stage functions are cached process-wide per ``(program, mesh)``
with the same retrace counters as the numeric backend (tagged
``"mesh"``), so N mesh peers of a stage on equal meshes compile once.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import codecs
from repro.dist.constrain import resolve_spec
from repro.dist.sharding import ShardingRules, DEFAULT_RULES, \
    stage_param_shardings
from repro.models.config import ArchConfig
from repro.runtime.base import StageState, fold_into, host_snapshot, \
    install_snapshot, single_stage, slot_export, slot_install, \
    wire_bwd_codec, wire_fwd_codec
from repro.runtime.stage_model import _traced, init_stage_params
from repro.runtime import numeric as numeric_rt

Tree = Any

# (program-cache key, stage, mesh fingerprint) -> (fwd_j, bwd_j)
_MESH_JITS: dict[tuple, tuple] = {}
_LOCK = threading.Lock()


def _mesh_fingerprint(mesh: jax.sharding.Mesh) -> tuple:
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


class MeshExecutor:
    """Run one pipeline stage data-parallel over a device mesh."""

    def __init__(self, cfg: ArchConfig, n_stages: int, seq_len: int,
                 stage: int, mesh: jax.sharding.Mesh,
                 compress: Optional[str] = None, quant_block: int = 64,
                 rules: Optional[ShardingRules] = None,
                 batch_axis: str = "data"):
        self.cfg = cfg
        self.stage = stage
        self.n_stages = n_stages
        self.seq_len = seq_len
        self.mesh = mesh
        self.rules = rules or DEFAULT_RULES
        self.batch_axis = batch_axis
        self.compress_mode = codecs.resolve_mode(cfg, compress)
        self.quant_block = quant_block
        self.device_count = int(np.prod(
            [mesh.shape[a] for a in mesh.axis_names]))
        # shared program: same math object the numeric backend runs, so
        # numeric and mesh peers of one stage are bitwise siblings
        progs = numeric_rt.get_stage_programs(
            cfg, n_stages, seq_len, self.compress_mode)
        self.prog = progs[stage]
        self.fwd_flops_per_token = self.prog.fwd_flops_per_token
        self.bwd_flops_per_token = self.prog.bwd_flops_per_token
        self.param_shardings = stage_param_shardings(
            self.prog.specs, mesh, self.rules)
        self._repl = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        self._params_treedef = jax.tree.structure(self.param_shardings)
        self._fwd_j, self._bwd_j = self._get_jits()

    # ------------------------------------------------------------ helpers
    def _get_jits(self):
        key = ((self.cfg, self.n_stages, self.seq_len, self.compress_mode),
               self.stage, _mesh_fingerprint(self.mesh))
        with _LOCK:
            hit = _MESH_JITS.get(key)
        if hit is not None:
            return hit
        tag = (self.cfg.name, self.n_stages, self.seq_len,
               self.compress_mode)

        def hook(stage, kind, shapes):     # same wrapper as the numeric
            # backend (stage_model._traced); "mesh" tags the backend
            numeric_rt.record_trace(tag + (stage, "mesh", kind, shapes))

        jits = (_traced(self.prog.fwd_fn, hook, self.stage, "fwd"),
                _traced(self.prog.bwd_fn, hook, self.stage, "bwd"))
        with _LOCK:
            jits = _MESH_JITS.setdefault(key, jits)
        return jits

    def _batch_sharding(self, x) -> jax.sharding.NamedSharding:
        x = np.asarray(x) if not hasattr(x, "shape") else x
        axes = [self.batch_axis] + [None] * (x.ndim - 1)
        return jax.sharding.NamedSharding(
            self.mesh, resolve_spec(axes, x.shape, self.mesh))

    def _place_batch(self, x):
        if x is None:
            return None
        return jax.device_put(jnp.asarray(x), self._batch_sharding(x))

    def _place_params(self, params: Tree) -> Tree:
        return jax.tree.map(
            lambda x, sh: jax.device_put(jnp.asarray(x), sh),
            params, self.param_shardings)

    def _place_opt(self, opt: Tree) -> Tree:
        """Optimizer state placement: any subtree shaped exactly like the
        params tree (adam's m/v moments, DPU's banked grads) gets the
        params' shardings leaf-for-leaf; everything else (count flags,
        scalars) replicates."""
        if opt is None:
            return None

        def place(sub):
            if jax.tree.structure(sub) == self._params_treedef:
                return self._place_params(sub)
            if isinstance(sub, dict):
                return {k: place(v) for k, v in sub.items()}
            return jax.device_put(jnp.asarray(sub), self._repl)

        return place(opt)

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState:
        state = StageState(params=self._place_params(
            init_stage_params([self.prog], key)[0]))
        state.reset_progress()
        return state

    @property
    def stages(self) -> range:
        return range(self.stage, self.stage + 1)

    def for_stage(self, stage: int) -> "MeshExecutor":
        if stage == self.stage:
            return self
        return MeshExecutor(self.cfg, self.n_stages, self.seq_len, stage,
                            self.mesh, self.compress_mode,
                            self.quant_block, self.rules, self.batch_axis)

    def for_span(self, span: range) -> "MeshExecutor":
        if len(span) != 1:
            raise NotImplementedError(
                "mesh-backed span serving is pending the async/DPU "
                "overlap work (ROADMAP) — fuse spans on the "
                "PipelineExecutor backend instead")
        return self.for_stage(span.start)

    def dp_shards(self, batch: int) -> int:
        """Actual data-parallel split of a ``batch``-sized microbatch —
        mirrors ``resolve_spec``'s divisibility fallback: a batch that
        does not divide the data axis replicates (no speedup)."""
        n = int(self.mesh.shape.get(self.batch_axis, 1))
        return n if n > 1 and batch % n == 0 else 1

    def session_program(self, total_len: int):
        raise NotImplementedError(
            "mesh-backed serving is pending the sharded-decode work "
            "(ROADMAP) — serve spans on the numeric/pipeline backends")

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        inp = self._place_batch(inp)
        if self.stage == self.n_stages - 1:
            return self._fwd_j(state.params, inp, self._place_batch(labels))
        return self._fwd_j(state.params, inp)

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None):
        inp = self._place_batch(inp)
        if self.stage == self.n_stages - 1:
            loss, gx, gp = self._bwd_j(state.params, inp,
                                       self._place_batch(labels))
            return loss, gx, gp
        gx, gp = self._bwd_j(state.params, inp, self._place_batch(dy))
        return None, gx, gp

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        # the wire IS the host: gather off the mesh so any backend (a
        # single-device peer, another mesh) can ingest the tensor
        return jax.device_get(wire_fwd_codec(self, y))

    def wire_bwd(self, gx: Tree) -> Tree:
        gx = wire_bwd_codec(self, gx)
        return None if gx is None else jax.device_get(gx)

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int,
                   stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        fold_into(state, gp, loss, n_tokens)

    def export_grads(self, state: StageState,
                     stage: Optional[int] = None) -> Tree:
        # host-gathered: addable with any other backend's accumulator
        single_stage(self, stage)
        return jax.device_get(state.grad_acc)

    def export_state(self, state: StageState,
                     stage: Optional[int] = None):
        single_stage(self, stage)
        return jax.device_get(state.params), jax.device_get(state.opt)

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree, stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        state.params = self._place_params(new_params)
        state.opt = self._place_opt(new_opt)
        state.version += 1
        state.reset_progress()

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState, stage: Optional[int] = None,
                 slots=()) -> Tree:
        single_stage(self, stage)
        return host_snapshot(state, slots=slots)

    def restore(self, state: StageState, snap: Tree,
                stage: Optional[int] = None, slots=()) -> None:
        single_stage(self, stage)
        # mesh placement for params; opt follows the params shardings
        # (install_snapshot's generic placement can't know them)
        placed = dict(snap)
        placed["params"] = self._place_params(snap["params"])
        placed["opt"] = self._place_opt(snap.get("opt"))
        install_snapshot(state, placed, slots=slots,
                         place=lambda t: t)

    # ------------------------------------------------------ keyed slots
    def export_slot(self, state: StageState, name: str, key,
                    stage: Optional[int] = None) -> Tree:
        single_stage(self, stage)
        return slot_export(state, name, key)

    def install_slot(self, state: StageState, name: str, key, value: Tree,
                     stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        slot_install(state, name, key, value)

    def drop_slot(self, state: StageState, name: str, key=None,
                  stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        state.drop_slot(name, key)
