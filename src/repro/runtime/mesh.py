"""MeshExecutor — a SWARM peer backed by a device mesh.

The paper's swarms are heterogeneous (§3, and the pooled-hardware
setting of Diskin et al.): one "peer" may be a lone preemptible T4,
another an 8-device node.  This executor makes the latter a first-class
pipeline citizen: the peer's stage step runs *sharded* over its mesh via
the ``repro.dist`` sharding rules — parameters placed by their logical
axes (:class:`repro.dist.sharding.ShardingRules`), the microbatch split
over the mesh's ``data`` axis — while the elastic scheduler above
remains oblivious: routing, the microbatch ledger, warm joins and
migrations all speak the same :class:`~repro.runtime.base.StageExecutor`
protocol as single-device peers.

The wire is the host: ``wire_fwd``/``wire_bwd`` gather the boundary
tensor off the mesh (after the int8 round-trip, when active), exactly
modelling SWARM's network crossing — so a mesh-backed peer can hand
activations to a single-device peer and vice versa, and state downloads
(``snapshot``/``restore``) recommit the replicated stage state onto
whichever backend the receiving peer runs.

Jitted stage functions are cached process-wide per ``(program, mesh)``
with the same retrace counters as the numeric backend (tagged
``"mesh"``), so N mesh peers of a stage on equal meshes compile once.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import codecs
from repro.dist.constrain import resolve_spec
from repro.dist.sharding import ShardingRules, DEFAULT_RULES, \
    stage_param_shardings
from repro.models.config import ArchConfig
from repro.models.stage_plan import get_stage_plan
from repro.models import params as P
from repro.runtime.base import StageState, fold_into, host_snapshot, \
    install_snapshot, single_stage, slot_export, slot_install, \
    wire_bwd_codec, wire_fwd_codec
from repro.runtime.stage_model import _traced, init_stage_params
from repro.runtime import numeric as numeric_rt

Tree = Any

# (program-cache key, stage, mesh fingerprint) -> (fwd_j, bwd_j)
_MESH_JITS: dict[tuple, tuple] = {}
_LOCK = threading.Lock()


def _mesh_fingerprint(mesh: jax.sharding.Mesh) -> tuple:
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


class MeshExecutor:
    """Run one pipeline stage data-parallel over a device mesh."""

    def __init__(self, cfg: ArchConfig, n_stages: int, seq_len: int,
                 stage: int, mesh: jax.sharding.Mesh,
                 compress: Optional[str] = None, quant_block: int = 64,
                 rules: Optional[ShardingRules] = None,
                 batch_axis: str = "data"):
        self.cfg = cfg
        self.stage = stage
        self.n_stages = n_stages
        self.seq_len = seq_len
        self.plan = get_stage_plan(cfg, n_stages)
        self.mesh = mesh
        self.rules = rules or DEFAULT_RULES
        self.batch_axis = batch_axis
        self.compress_mode = codecs.resolve_mode(cfg, compress)
        self.quant_block = quant_block
        self.device_count = int(np.prod(
            [mesh.shape[a] for a in mesh.axis_names]))
        # shared program: same math object the numeric backend runs, so
        # numeric and mesh peers of one stage are bitwise siblings
        progs = numeric_rt.get_stage_programs(
            cfg, n_stages, seq_len, self.compress_mode)
        self.prog = progs[stage]
        self.fwd_flops_per_token = self.prog.fwd_flops_per_token
        self.bwd_flops_per_token = self.prog.bwd_flops_per_token
        self.param_shardings = stage_param_shardings(
            self.prog.specs, mesh, self.rules)
        self._repl = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        self._params_treedef = jax.tree.structure(self.param_shardings)
        self._fwd_j, self._bwd_j = self._get_jits()

    # ------------------------------------------------------------ helpers
    def _get_jits(self):
        key = ((self.cfg, self.n_stages, self.seq_len, self.compress_mode),
               self.stage, _mesh_fingerprint(self.mesh))
        with _LOCK:
            hit = _MESH_JITS.get(key)
        if hit is not None:
            return hit
        tag = (self.cfg.name, self.n_stages, self.seq_len,
               self.compress_mode)

        def hook(stage, kind, shapes):     # same wrapper as the numeric
            # backend (stage_model._traced); "mesh" tags the backend
            numeric_rt.record_trace(tag + (stage, "mesh", kind, shapes))

        jits = (_traced(self.prog.fwd_fn, hook, self.stage, "fwd"),
                _traced(self.prog.bwd_fn, hook, self.stage, "bwd"))
        with _LOCK:
            jits = _MESH_JITS.setdefault(key, jits)
        return jits

    def _batch_sharding(self, x) -> jax.sharding.NamedSharding:
        x = np.asarray(x) if not hasattr(x, "shape") else x
        axes = [self.batch_axis] + [None] * (x.ndim - 1)
        return jax.sharding.NamedSharding(
            self.mesh, resolve_spec(axes, x.shape, self.mesh))

    def _place_batch(self, x):
        if x is None:
            return None
        return jax.device_put(jnp.asarray(x), self._batch_sharding(x))

    def _place_params(self, params: Tree) -> Tree:
        return jax.tree.map(
            lambda x, sh: jax.device_put(jnp.asarray(x), sh),
            params, self.param_shardings)

    def _place_opt(self, opt: Tree) -> Tree:
        """Optimizer state placement: any subtree shaped exactly like the
        params tree (adam's m/v moments, DPU's banked grads) gets the
        params' shardings leaf-for-leaf; everything else (count flags,
        scalars) replicates."""
        if opt is None:
            return None

        def place(sub):
            if jax.tree.structure(sub) == self._params_treedef:
                return self._place_params(sub)
            if isinstance(sub, dict):
                return {k: place(v) for k, v in sub.items()}
            return jax.device_put(jnp.asarray(sub), self._repl)

        return place(opt)

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState:
        state = StageState(params=self._place_params(
            init_stage_params([self.prog], key)[0]))
        state.reset_progress()
        return state

    @property
    def stages(self) -> range:
        return range(self.stage, self.stage + 1)

    def for_stage(self, stage: int) -> "MeshExecutor":
        if stage == self.stage:
            return self
        return MeshExecutor(self.cfg, self.n_stages, self.seq_len, stage,
                            self.mesh, self.compress_mode,
                            self.quant_block, self.rules, self.batch_axis)

    def for_span(self, span: range):
        if len(span) == 1:
            return self.for_stage(span.start)
        return MeshSpanExecutor(self.cfg, self.n_stages, self.seq_len,
                                (span.start, span.stop), self.mesh,
                                self.compress_mode, self.quant_block,
                                self.rules, self.batch_axis)

    def dp_shards(self, batch: int) -> int:
        """Actual data-parallel split of a ``batch``-sized microbatch —
        mirrors ``resolve_spec``'s divisibility fallback: a batch that
        does not divide the data axis replicates (no speedup)."""
        n = int(self.mesh.shape.get(self.batch_axis, 1))
        return n if n > 1 and batch % n == 0 else 1

    def session_program(self, total_len: int):
        raise NotImplementedError(
            "mesh-backed serving is pending the sharded-decode work "
            "(ROADMAP) — serve spans on the numeric/pipeline backends")

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        inp = self._place_batch(inp)
        if self.stage == self.n_stages - 1:
            return self._fwd_j(state.params, inp, self._place_batch(labels))
        return self._fwd_j(state.params, inp)

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None):
        inp = self._place_batch(inp)
        if self.stage == self.n_stages - 1:
            loss, gx, gp = self._bwd_j(state.params, inp,
                                       self._place_batch(labels))
            return loss, gx, gp
        gx, gp = self._bwd_j(state.params, inp, self._place_batch(dy))
        return None, gx, gp

    # ------------------------------------------------- dispatch / collect
    def dispatch_fwd(self, state: StageState, inp: Tree,
                     labels: Optional[jax.Array] = None):
        # the sharded jit dispatches asynchronously across the mesh;
        # collect hands over the in-flight futures
        y = self.run_fwd(state, inp, labels)
        return lambda: y

    def dispatch_bwd(self, state: StageState, inp: Tree,
                     dy: Optional[Tree] = None,
                     labels: Optional[jax.Array] = None):
        out = self.run_bwd(state, inp, dy, labels)
        return lambda: out

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        # the wire IS the host: gather off the mesh so any backend (a
        # single-device peer, another mesh) can ingest the tensor
        return jax.device_get(wire_fwd_codec(self, y))

    def wire_bwd(self, gx: Tree) -> Tree:
        gx = wire_bwd_codec(self, gx)
        return None if gx is None else jax.device_get(gx)

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int,
                   stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        fold_into(state, gp, loss, n_tokens)

    def export_grads(self, state: StageState,
                     stage: Optional[int] = None) -> Tree:
        # host-gathered: addable with any other backend's accumulator
        single_stage(self, stage)
        return jax.device_get(state.grad_acc)

    def export_state(self, state: StageState,
                     stage: Optional[int] = None):
        single_stage(self, stage)
        return jax.device_get(state.params), jax.device_get(state.opt)

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree, stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        state.params = self._place_params(new_params)
        state.opt = self._place_opt(new_opt)
        state.version += 1
        state.reset_progress()

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState, stage: Optional[int] = None,
                 slots=()) -> Tree:
        single_stage(self, stage)
        return host_snapshot(state, slots=slots)

    def restore(self, state: StageState, snap: Tree,
                stage: Optional[int] = None, slots=()) -> None:
        single_stage(self, stage)
        # mesh placement for params; opt follows the params shardings
        # (install_snapshot's generic placement can't know them)
        placed = dict(snap)
        placed["params"] = self._place_params(snap["params"])
        placed["opt"] = self._place_opt(snap.get("opt"))
        install_snapshot(state, placed, slots=slots,
                         place=lambda t: t)

    # ------------------------------------------------------ keyed slots
    def export_slot(self, state: StageState, name: str, key,
                    stage: Optional[int] = None) -> Tree:
        single_stage(self, stage)
        return slot_export(state, name, key)

    def install_slot(self, state: StageState, name: str, key, value: Tree,
                     stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        slot_install(state, name, key, value)

    def drop_slot(self, state: StageState, name: str, key=None,
                  stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        state.drop_slot(name, key)


class MeshSpanExecutor:
    """Stages ``[lo, hi)`` fused in ONE jit, sharded over a device mesh.

    Combines :class:`~repro.runtime.pipeline.PipelineExecutor`'s span
    fusion with :class:`MeshExecutor`'s placement: intra-span boundaries
    stay device-to-device *inside* the sharded jit (no host round-trip
    between covered stages), while state remains per-stage-keyed — each
    covered stage keeps mesh-placed params/opt/accumulator of exactly
    the single-stage shape, so All-Reduce groups, checkpoint cuts, and
    span ↔ single hand-offs interoperate unchanged (the span
    snapshot-interop tests run against this backend too)."""

    def __init__(self, cfg: ArchConfig, n_stages: int, seq_len: int,
                 span: tuple[int, int], mesh: jax.sharding.Mesh,
                 compress: Optional[str] = None, quant_block: int = 64,
                 rules: Optional[ShardingRules] = None,
                 batch_axis: str = "data"):
        lo, hi = span
        if not (0 <= lo < hi <= n_stages):
            raise ValueError(f"span [{lo}, {hi}) outside [0, {n_stages})")
        self.cfg = cfg
        self.n_stages = n_stages
        self.seq_len = seq_len
        self.span = (lo, hi)
        self.stage = lo                       # entry stage
        self.plan = get_stage_plan(cfg, n_stages)
        self.mesh = mesh
        self.rules = rules or DEFAULT_RULES
        self.batch_axis = batch_axis
        self.compress_mode = codecs.resolve_mode(cfg, compress)
        self.quant_block = quant_block
        self.device_count = int(np.prod(
            [mesh.shape[a] for a in mesh.axis_names]))
        # the same fused program object PipelineExecutor runs — mesh
        # span peers are bitwise siblings of single-device span peers
        self.prog = numeric_rt.get_span_program(
            cfg, n_stages, seq_len, (lo, hi), self.compress_mode)
        self.fwd_flops_per_token = self.prog.fwd_flops_per_token
        self.bwd_flops_per_token = self.prog.bwd_flops_per_token
        self.param_shardings = {
            s: stage_param_shardings(self.prog.specs[s], mesh, self.rules)
            for s in self.stages}
        self._repl = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        self._treedefs = {s: jax.tree.structure(self.param_shardings[s])
                          for s in self.stages}
        self._fwd_j, self._bwd_j = self._get_jits()

    @property
    def stages(self) -> range:
        return range(*self.span)

    # ------------------------------------------------------------ helpers
    def _get_jits(self):
        key = ((self.cfg, self.n_stages, self.seq_len, self.compress_mode),
               self.span, _mesh_fingerprint(self.mesh))
        with _LOCK:
            hit = _MESH_JITS.get(key)
        if hit is not None:
            return hit
        tag = (self.cfg.name, self.n_stages, self.seq_len,
               self.compress_mode)

        def hook(span_id, kind, shapes):
            numeric_rt.record_trace(tag + (span_id, "mesh", kind, shapes))

        jits = (_traced(self.prog.fwd_fn, hook, self.span, "fwd"),
                _traced(self.prog.bwd_fn, hook, self.span, "bwd"))
        with _LOCK:
            jits = _MESH_JITS.setdefault(key, jits)
        return jits

    def _batch_sharding(self, x) -> jax.sharding.NamedSharding:
        x = np.asarray(x) if not hasattr(x, "shape") else x
        axes = [self.batch_axis] + [None] * (x.ndim - 1)
        return jax.sharding.NamedSharding(
            self.mesh, resolve_spec(axes, x.shape, self.mesh))

    def _place_batch(self, x):
        if x is None:
            return None
        return jax.device_put(jnp.asarray(x), self._batch_sharding(x))

    def _place_params(self, params: Tree, stage: int) -> Tree:
        return jax.tree.map(
            lambda x, sh: jax.device_put(jnp.asarray(x), sh),
            params, self.param_shardings[stage])

    def _place_opt(self, opt: Tree, stage: int) -> Tree:
        if opt is None:
            return None

        def place(sub):
            if jax.tree.structure(sub) == self._treedefs[stage]:
                return self._place_params(sub, stage)
            if isinstance(sub, dict):
                return {k: place(v) for k, v in sub.items()}
            return jax.device_put(jnp.asarray(sub), self._repl)

        return place(opt)

    def _params_tuple(self, state: StageState) -> tuple:
        return tuple(state.per_stage[s].params for s in self.stages)

    def _covers_last(self) -> bool:
        return self.span[1] == self.n_stages

    def _require(self, stage: Optional[int]) -> int:
        if stage is None:
            raise ValueError(
                f"span executor [{self.span[0]}, {self.span[1]}) needs an "
                "explicit covered stage for per-stage state operations")
        if stage not in self.stages:
            raise ValueError(f"stage {stage} outside span {self.span}")
        return stage

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState:
        state = StageState(per_stage={})
        keys = jax.random.split(key, len(self.stages))
        for k, s in zip(keys, self.stages):
            sub = StageState(params=self._place_params(
                P.init(k, self.prog.specs[s]), s))
            sub.reset_progress()
            state.per_stage[s] = sub
        return state

    def for_span(self, span: range):
        if (span.start, span.stop) == self.span:
            return self
        if len(span) == 1:
            return MeshExecutor(self.cfg, self.n_stages, self.seq_len,
                                span.start, self.mesh, self.compress_mode,
                                self.quant_block, self.rules,
                                self.batch_axis)
        return MeshSpanExecutor(self.cfg, self.n_stages, self.seq_len,
                                (span.start, span.stop), self.mesh,
                                self.compress_mode, self.quant_block,
                                self.rules, self.batch_axis)

    def for_stage(self, stage: int):
        return self.for_span(range(stage, stage + 1))

    def dp_shards(self, batch: int) -> int:
        n = int(self.mesh.shape.get(self.batch_axis, 1))
        return n if n > 1 and batch % n == 0 else 1

    def session_program(self, total_len: int):
        raise NotImplementedError(
            "mesh-backed serving is pending the sharded-decode work "
            "(ROADMAP) — serve spans on the numeric/pipeline backends")

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        ps = self._params_tuple(state)
        inp = self._place_batch(inp)
        if self._covers_last():
            return self._fwd_j(ps, inp, self._place_batch(labels))
        return self._fwd_j(ps, inp)

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None):
        ps = self._params_tuple(state)
        inp = self._place_batch(inp)
        if self._covers_last():
            loss, gx, gp = self._bwd_j(ps, inp, self._place_batch(labels))
        else:
            loss = None
            gx, gp = self._bwd_j(ps, inp, self._place_batch(dy))
        gp = {s: g for s, g in zip(self.stages, gp)}
        return loss, gx, gp

    # ------------------------------------------------- dispatch / collect
    def dispatch_fwd(self, state: StageState, inp: Tree,
                     labels: Optional[jax.Array] = None):
        y = self.run_fwd(state, inp, labels)
        return lambda: y

    def dispatch_bwd(self, state: StageState, inp: Tree,
                     dy: Optional[Tree] = None,
                     labels: Optional[jax.Array] = None):
        out = self.run_bwd(state, inp, dy, labels)
        return lambda: out

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        return jax.device_get(wire_fwd_codec(self, y))

    def wire_bwd(self, gx: Tree) -> Tree:
        gx = wire_bwd_codec(self, gx)
        return None if gx is None else jax.device_get(gx)

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int,
                   stage: Optional[int] = None) -> None:
        s = self._require(stage)
        fold_into(state.per_stage[s], gp, loss, n_tokens)

    def export_grads(self, state: StageState,
                     stage: Optional[int] = None) -> Tree:
        return jax.device_get(
            state.per_stage[self._require(stage)].grad_acc)

    def export_state(self, state: StageState,
                     stage: Optional[int] = None):
        sub = state.per_stage[self._require(stage)]
        return jax.device_get(sub.params), jax.device_get(sub.opt)

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree, stage: Optional[int] = None) -> None:
        s = self._require(stage)
        sub = state.per_stage[s]
        sub.params = self._place_params(new_params, s)
        sub.opt = self._place_opt(new_opt, s)
        sub.version += 1
        sub.reset_progress()

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState, stage: Optional[int] = None,
                 slots=()) -> Tree:
        if stage is None:
            return {"per_stage": {
                s: host_snapshot(state.per_stage[s], slots=slots)
                for s in self.stages}}
        return host_snapshot(state.per_stage[self._require(stage)],
                             slots=slots)

    def restore(self, state: StageState, snap: Tree,
                stage: Optional[int] = None, slots=()) -> None:
        if state.per_stage is None:
            state.per_stage = {}
        if stage is None:
            for s, sub_snap in snap["per_stage"].items():
                self.restore(state, sub_snap, stage=int(s), slots=slots)
            return
        s = self._require(stage)
        sub = state.per_stage.setdefault(s, StageState())
        placed = dict(snap)
        placed["params"] = self._place_params(snap["params"], s)
        placed["opt"] = self._place_opt(snap.get("opt"), s)
        install_snapshot(sub, placed, slots=slots, place=lambda t: t)

    # ------------------------------------------------------ keyed slots
    def export_slot(self, state: StageState, name: str, key,
                    stage: Optional[int] = None) -> Tree:
        return slot_export(state.per_stage[self._require(stage)], name, key)

    def install_slot(self, state: StageState, name: str, key, value: Tree,
                     stage: Optional[int] = None) -> None:
        slot_install(state.per_stage[self._require(stage)], name, key,
                     value)

    def drop_slot(self, state: StageState, name: str, key=None,
                  stage: Optional[int] = None) -> None:
        if stage is None:
            for sub in state.views():
                sub.drop_slot(name, key)
            return
        state.per_stage[self._require(stage)].drop_slot(name, key)
