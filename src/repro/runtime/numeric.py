"""NumericExecutor — single-device stage math behind a shared jit cache.

Absorbs the ``StageProgram`` machinery (``repro.runtime.stage_model``)
behind a *process-wide* compile cache keyed on ``(arch config, stage
count, sequence length, codec mode)``: every peer of a stage — across
runners, across the churn tests' seed matrix, across benchmark repeats —
shares one jitted ``fwd``/``bwd`` per stage instead of re-tracing its
own.  A retrace counter (a trace-time side effect inside the jitted
body) records every actual XLA trace per ``(stage, kind, argument
shapes)``; ``compile_stats()`` is what the fairness/retrace tests and
``benchmarks/bench_swarm.py`` read.

Gradient accumulation donates the accumulator buffer (``grad_acc`` is
exclusively owned by its :class:`StageState`), so the fold is in-place
at the XLA level — no second gradient-sized live buffer per microbatch.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compression import codecs
from repro.models.config import ArchConfig
from repro.runtime.base import StageState, fold_into, host_snapshot, \
    install_snapshot, single_stage, slot_export, slot_install, \
    wire_bwd_codec, wire_fwd_codec
from repro.models.stage_plan import get_stage_plan
from repro.runtime.stage_model import (SpanProgram, StageProgram,
                                       build_span_program,
                                       build_stage_programs,
                                       init_stage_params)

Tree = Any

# ---------------------------------------------------------------- caches
# (cfg, n_stages, seq_len, comp) -> list[StageProgram]; ArchConfig is a
# frozen dataclass, hence hashable — identical configs share programs.
_PROGRAMS: dict[tuple, list[StageProgram]] = {}
# (cfg, n_stages, seq_len, comp, (lo, hi)) -> SpanProgram: one fused jit
# per (span, codec), shared by every peer serving that span
_SPANS: dict[tuple, SpanProgram] = {}
# (stage, kind, shapes) per program-cache key -> number of XLA traces
_TRACES: dict[tuple, int] = {}
_LOCK = threading.Lock()


def record_trace(key: tuple) -> None:
    """Count one XLA trace under ``key`` — the single counter store for
    every backend (numeric programs and mesh jits both report here)."""
    with _LOCK:
        _TRACES[key] = _TRACES.get(key, 0) + 1


def reset_compile_stats() -> None:
    """Clear retrace counters AND every jit cache — numeric programs,
    mesh jits, and serving session programs alike — so tests/benchmarks
    that assert compile counts start from a genuinely cold cache."""
    import sys
    from repro.runtime import mesh as mesh_rt   # lazy: mesh imports us
    with _LOCK:
        _TRACES.clear()
        _PROGRAMS.clear()
        _SPANS.clear()
    with mesh_rt._LOCK:
        mesh_rt._MESH_JITS.clear()
    serve_progs = sys.modules.get("repro.serve.programs")
    if serve_progs is not None:
        serve_progs.reset_session_cache()


def compile_stats() -> dict:
    """``{"programs_cached", "traces", "per_key"}`` — ``traces`` is the
    total number of XLA traces since the last reset; ``per_key`` maps
    ``(cfg_name, n_stages, seq, comp, stage, kind, shapes)`` -> count."""
    with _LOCK:
        return {"programs_cached": len(_PROGRAMS),
                "traces": sum(_TRACES.values()),
                "per_key": dict(_TRACES)}


def get_stage_programs(cfg: ArchConfig, n_stages: int, seq_len: int,
                       compress: Optional[str] = None
                       ) -> list[StageProgram]:
    """The shared, counted stage programs for this configuration."""
    comp = codecs.resolve_mode(cfg, compress)
    key = (cfg, n_stages, seq_len, comp)
    with _LOCK:
        progs = _PROGRAMS.get(key)
    if progs is not None:
        return progs
    tag = (cfg.name, n_stages, seq_len, comp)

    def hook(stage: int, kind: str, shapes: tuple):
        record_trace(tag + (stage, kind, shapes))

    progs = build_stage_programs(cfg, n_stages, seq_len, compress=comp,
                                 trace_hook=hook)
    with _LOCK:
        # first build wins if two threads raced; both lists are equivalent
        progs = _PROGRAMS.setdefault(key, progs)
    return progs


def get_span_program(cfg: ArchConfig, n_stages: int, seq_len: int,
                     span: tuple[int, int],
                     compress: Optional[str] = None) -> SpanProgram:
    """The shared, counted fused program for a ``[lo, hi)`` span: one
    fwd/bwd jit per (configuration, span, codec) process-wide, so N span
    peers of one span compile once and a second same-shape runner
    re-traces nothing (same discipline as the per-stage cache)."""
    comp = codecs.resolve_mode(cfg, compress)
    key = (cfg, n_stages, seq_len, comp, tuple(span))
    with _LOCK:
        prog = _SPANS.get(key)
    if prog is not None:
        return prog
    tag = (cfg.name, n_stages, seq_len, comp)

    def hook(span_id, kind: str, shapes: tuple):
        record_trace(tag + (span_id, kind, shapes))

    prog = build_span_program(cfg, n_stages, seq_len, tuple(span),
                              compress=comp, trace_hook=hook)
    with _LOCK:
        prog = _SPANS.setdefault(key, prog)
    return prog


class NumericExecutor:
    """Single-device stage execution (today's eager-ish SWARM peer)."""

    device_count = 1

    def __init__(self, cfg: ArchConfig, prog: StageProgram,
                 compress_mode: str, quant_block: int = 64,
                 family: Optional[list["NumericExecutor"]] = None,
                 seq_len: Optional[int] = None):
        self.cfg = cfg
        self.prog = prog
        self.stage = prog.stage
        self.n_stages = prog.n_stages
        self.plan = get_stage_plan(cfg, prog.n_stages)
        self.seq_len = seq_len              # lets for_span build fused kin
        self.compress_mode = compress_mode
        self.quant_block = quant_block
        self.fwd_flops_per_token = prog.fwd_flops_per_token
        self.bwd_flops_per_token = prog.bwd_flops_per_token
        # all executors of one pipeline, so migrations can swap stages
        self._family = family if family is not None else [self]

    @property
    def stages(self) -> range:
        return range(self.stage, self.stage + 1)

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState:
        state = StageState(params=init_stage_params([self.prog], key)[0])
        state.reset_progress()
        return state

    def for_stage(self, stage: int) -> "NumericExecutor":
        return self._family[stage]

    def for_span(self, span: range) -> "StageExecutor":
        """Width-1 spans stay in the numeric family; wider spans swap the
        peer onto the fused :class:`~repro.runtime.pipeline
        .PipelineExecutor` backend (how a merge turns a single-stage
        peer into a span peer)."""
        if len(span) == 1:
            return self._family[span.start]
        if self.seq_len is None:
            raise ValueError("NumericExecutor built without seq_len "
                             "cannot widen to a span")
        from repro.runtime.pipeline import PipelineExecutor
        return PipelineExecutor(self.cfg, self.n_stages, self.seq_len,
                                (span.start, span.stop),
                                compress=self.compress_mode,
                                quant_block=self.quant_block)

    def dp_shards(self, batch: int) -> int:
        del batch
        return 1

    def session_program(self, total_len: int):
        from repro.serve.programs import get_session_program
        return get_session_program(
            self.cfg, self.n_stages, (self.stage, self.stage + 1),
            total_len, compress=self.compress_mode)

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        if self.stage == self.n_stages - 1:
            return self.prog.fwd(state.params, inp, labels)
        return self.prog.fwd(state.params, inp)

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None):
        if self.stage == self.n_stages - 1:
            loss, gx, gp = self.prog.bwd(state.params, inp, labels)
            return loss, gx, gp
        gx, gp = self.prog.bwd(state.params, inp, dy)
        return None, gx, gp

    # ------------------------------------------------- dispatch / collect
    def dispatch_fwd(self, state: StageState, inp: Tree,
                     labels: Optional[jax.Array] = None):
        # jax dispatches asynchronously: run_fwd returns device futures
        # with the work already in flight, so issuing now and collecting
        # later is a genuine overlap on real hardware
        y = self.run_fwd(state, inp, labels)
        return lambda: y

    def dispatch_bwd(self, state: StageState, inp: Tree,
                     dy: Optional[Tree] = None,
                     labels: Optional[jax.Array] = None):
        out = self.run_bwd(state, inp, dy, labels)
        return lambda: out

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        return wire_fwd_codec(self, y)

    def wire_bwd(self, gx: Tree) -> Tree:
        return wire_bwd_codec(self, gx)

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int,
                   stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        fold_into(state, gp, loss, n_tokens)

    def export_grads(self, state: StageState,
                     stage: Optional[int] = None) -> Tree:
        single_stage(self, stage)
        return state.grad_acc                   # already scheduler-local

    def export_state(self, state: StageState,
                     stage: Optional[int] = None):
        single_stage(self, stage)
        return state.params, state.opt

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree, stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        state.params = new_params
        state.opt = new_opt
        state.version += 1
        state.reset_progress()

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState, stage: Optional[int] = None,
                 slots=()) -> Tree:
        single_stage(self, stage)
        return host_snapshot(state, slots=slots)

    def restore(self, state: StageState, snap: Tree,
                stage: Optional[int] = None, slots=()) -> None:
        single_stage(self, stage)
        install_snapshot(state, snap, slots=slots)

    # ------------------------------------------------------ keyed slots
    def export_slot(self, state: StageState, name: str, key,
                    stage: Optional[int] = None) -> Tree:
        single_stage(self, stage)
        return slot_export(state, name, key)

    def install_slot(self, state: StageState, name: str, key, value: Tree,
                     stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        slot_install(state, name, key, value)

    def drop_slot(self, state: StageState, name: str, key=None,
                  stage: Optional[int] = None) -> None:
        single_stage(self, stage)
        state.drop_slot(name, key)


def build_numeric_executors(cfg: ArchConfig, n_stages: int, seq_len: int,
                            compress: Optional[str] = None,
                            quant_block: int = 64,
                            programs: Optional[list[StageProgram]] = None
                            ) -> list[NumericExecutor]:
    """One executor per stage, all sharing the cached programs (or an
    injected pre-built list, e.g. the churn tests' shared seed matrix)."""
    comp = codecs.resolve_mode(cfg, compress)
    progs = programs if programs is not None else \
        get_stage_programs(cfg, n_stages, seq_len, comp)
    family: list[NumericExecutor] = []
    for p in progs:
        family.append(NumericExecutor(cfg, p, comp, quant_block,
                                      family=family, seq_len=seq_len))
    return family
