"""The stage-runtime layer: what a SWARM "peer" runs.

The elastic scheduler (``repro.core``) decides *where* a microbatch goes;
a :class:`StageExecutor` decides *how* the chosen peer executes its
stages.  Unifying the previously-disjoint stage implementations — the
eager per-peer ``StageProgram`` math and the compiled GSPMD path of
``repro.dist`` — behind this protocol is what lets a heterogeneous swarm
(paper §3, and Diskin et al.'s pooled-hardware setting) mix peers that
are a lone T4 with peers that are an 8-device mesh slice, inside one
pipeline:

* :class:`~repro.runtime.numeric.NumericExecutor` — single-device stage
  math behind a process-wide compile cache (one jit per stage shared by
  every peer of that stage, instead of per-peer re-tracing);
* :class:`~repro.runtime.mesh.MeshExecutor` — the stage step sharded
  over a device mesh via the ``repro.dist`` rules (data-parallel within
  the peer);
* :class:`~repro.runtime.pipeline.PipelineExecutor` — a contiguous
  *span* of stages ``[lo, hi)`` fused into one jitted step (the paper's
  square-cube rebalancing: well-provisioned peers hold more of the
  model), intra-span boundaries never crossing the host.

An executor's identity is its ``stages`` range — ``range(s, s+1)`` for
the single-stage backends.  Every state operation that the scheduler
performs per pipeline stage (gradient export, the optimizer-step install,
snapshot/restore) takes an explicit ``stage`` so a span peer is
per-stage addressable: it occupies one All-Reduce group per covered
stage, its checkpoint cuts are ordinary single-stage snapshots, and a
dying span peer hands per-stage state to single-stage peers (and vice
versa for merges).

Executors are *stateless* with respect to training progress: all mutable
state lives in the :class:`StageState` the scheduler hands in, so N
peers of one stage share one executor, and a peer migrating between
stages (or resizing its span) just swaps executors via ``for_span``.
``snapshot``/``restore`` speak host-side (numpy) trees — the common wire
format for peer-to-peer state downloads (numeric ↔ mesh ↔ pipeline in
any direction) and for ``repro.ckpt``, which is how a stage that lost
all its peers resumes from the latest completed step instead of step 0
(Varuna-style elastic restart).
"""
from __future__ import annotations

from typing import Any, Callable, Hashable, Iterable, Optional, Protocol, \
    runtime_checkable

import jax
import jax.numpy as jnp

Tree = Any

# slot names with executor-protocol semantics of their own: "grads" is
# the per-stage gradient accumulator (accumulate / export_grads /
# zero_grads), "opt" the optimizer state (export_state / adopt_step).
# They travel in the snapshot's TOP-LEVEL fields ("opt"; grads never
# travel — a download or step never imports gradients), not under
# "slots", which keeps the single-stage snapshot format bit-compatible
# with every pre-slot checkpoint and hand-off.
GRADS_SLOT = "grads"
OPT_SLOT = "opt"
CORE_SLOTS = (GRADS_SLOT, OPT_SLOT)


class StageState:
    """Replicated executor-owned state for one pipeline stage — or, for
    a span backend, the per-stage-keyed bundle of them (``per_stage``).

    Owned by the executor protocol: schedulers treat it as an opaque
    handle and go through executor methods (``accumulate``, ``snapshot``,
    ``restore``, ``adopt_step``) for every mutation that touches device
    memory.  ``stage_view(s)`` is the read path the scheduler uses for
    per-stage bookkeeping (token counts for the All-Reduce weighting,
    the last stage's loss sum): it returns ``self`` on single-stage
    states and the stage-``s`` sub-state on span states, so span peers
    keep exact per-stage accounting (the ledger may admit one covered
    stage of a microbatch and skip another).

    Besides ``params``, everything an executor owns for a stage lives in
    named *keyed slots* — ``slots[name]`` is a ``{key: tree}`` dict.
    Training uses two of them: ``slots["grads"]["acc"]`` (the gradient
    accumulator) and ``slots["opt"]["state"]`` (optimizer state), still
    reachable through the ``grad_acc``/``opt`` properties every caller
    already uses.  Serving adds ``slots["kv"]`` keyed by session id (a
    decode cache per live session) — the same churn machinery
    (snapshot/restore, warm joins, per-stage hand-offs) moves any slot,
    which is what lets KV caches ride peer lifecycle events exactly like
    grads and opt do.
    """

    def __init__(self, params: Tree = None, opt: Tree = None,
                 grad_acc: Tree = None, loss_sum: float = 0.0,
                 token_count: int = 0, version: int = 0,
                 per_stage: Optional[dict[int, "StageState"]] = None):
        self.params = params
        self.slots: dict[str, dict[Hashable, Tree]] = {}
        if opt is not None:
            self.opt = opt
        if grad_acc is not None:
            self.grad_acc = grad_acc
        self.loss_sum = loss_sum
        self.token_count = token_count
        self.version = version
        # span backends: global stage id -> per-stage StageState; the
        # outer object then carries no tensors of its own
        self.per_stage = per_stage

    # ------------------------------------------------------------- slots
    def slot(self, name: str) -> dict[Hashable, Tree]:
        """The named keyed slot, created empty on first touch."""
        return self.slots.setdefault(name, {})

    def drop_slot(self, name: str, key: Optional[Hashable] = None) -> None:
        """Forget one entry (``key``) or the whole slot (``key=None``)."""
        if key is None:
            self.slots.pop(name, None)
            return
        ent = self.slots.get(name)
        if ent is not None:
            ent.pop(key, None)
            if not ent:
                del self.slots[name]

    @property
    def opt(self) -> Tree:
        return self.slots.get(OPT_SLOT, {}).get("state")

    @opt.setter
    def opt(self, value: Tree) -> None:
        if value is None:
            self.slots.pop(OPT_SLOT, None)
        else:
            self.slot(OPT_SLOT)["state"] = value

    @property
    def grad_acc(self) -> Tree:
        return self.slots.get(GRADS_SLOT, {}).get("acc")

    @grad_acc.setter
    def grad_acc(self, value: Tree) -> None:
        if value is None:
            self.slots.pop(GRADS_SLOT, None)
        else:
            self.slot(GRADS_SLOT)["acc"] = value

    # ------------------------------------------------------------- views
    def stage_view(self, stage: Optional[int] = None) -> "StageState":
        if self.per_stage is None or stage is None:
            return self
        return self.per_stage[stage]

    def views(self) -> list["StageState"]:
        return (list(self.per_stage.values()) if self.per_stage is not None
                else [self])

    def zero_grads(self):
        if self.per_stage is not None:
            for st in self.per_stage.values():
                st.zero_grads()
        if self.grad_acc is not None:
            self.grad_acc = jax.tree.map(jnp.zeros_like, self.grad_acc)
        self.loss_sum = 0.0
        self.token_count = 0

    def reset_progress(self):
        """Fresh accumulator (zeros shaped/placed like ``params``) and
        cleared loss/token counters — the tail of every state install
        (restore, adopt_step): a download or step never imports grads.
        Non-core slots (e.g. serving KV) are untouched: adopting an
        optimizer step must not evict live sessions."""
        self.grad_acc = jax.tree.map(jnp.zeros_like, self.params)
        self.loss_sum = 0.0
        self.token_count = 0


@runtime_checkable
class StageExecutor(Protocol):
    """How a peer runs its pipeline stages (init / fwd / bwd / accumulate
    / snapshot / restore / wire-codec handling).

    ``run_fwd``/``run_bwd`` consume and produce *wire* tensors: whatever
    representation crosses between peers (the learned codecs' c-dim
    tensor, or the d-dim activation for ``none``/``int8``).  The int8
    round-trip that used to be special-cased in the trainer lives in
    ``wire_fwd``/``wire_bwd`` — the trainer is codec-agnostic.  Span
    backends apply the wire codec only at span *edges*; fused boundaries
    stay on-device inside ``run_fwd``/``run_bwd``.

    Per-stage state operations take ``stage=None`` meaning "the
    executor's sole stage" — single-stage backends accept only that (or
    their own stage id); span backends require an explicit covered
    stage for ``export_grads``/``export_state``/``adopt_step`` and for
    single-stage-formatted ``snapshot``/``restore``.
    """

    stage: int                     # entry stage (== stages.start)
    stages: range                  # contiguous span served, [lo, hi)
    n_stages: int
    compress_mode: str
    quant_block: int               # int8 wire codec block size
    device_count: int              # relative capacity of this backend
    fwd_flops_per_token: float     # whole-span totals
    bwd_flops_per_token: float

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState: ...

    def for_span(self, span: range) -> "StageExecutor":
        """The sibling executor serving ``span`` on the same backend —
        how a peer migrates between stages, and how span peers split
        into single-stage peers and merge back (``for_stage`` is the
        width-1 shorthand)."""
        ...

    def for_stage(self, stage: int) -> "StageExecutor": ...

    def dp_shards(self, batch: int) -> int:
        """How many ways this backend actually splits a ``batch``-sized
        microbatch (the cost model's compute speedup).  1 whenever the
        placement would replicate instead of shard."""
        ...

    def session_program(self, total_len: int):
        """The serving :class:`repro.serve.programs.SessionProgram` for
        this executor's span at horizon ``total_len`` (prompt +
        generated tokens): fused prefill/decode whose KV caches live in
        the state's ``"kv"`` keyed slot.  Backends that cannot serve
        raise ``NotImplementedError``."""
        ...

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        """Span forward from the boundary input.  A span covering the
        last stage returns the token-sum loss; others return the
        outbound wire tensor."""
        ...

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None
                ) -> tuple[Optional[float], Optional[Tree], Tree]:
        """Span backward (recomputes forward from ``inp``, App. A).
        Returns ``(loss, gx, gp)``; ``loss`` only when the span covers
        the last stage, ``gx`` None when it starts at 0.  Single-stage
        backends return ``gp`` as the stage's param tree; span backends
        return a dict keyed by *global stage id* so the scheduler can
        fold each covered stage independently (the ledger may admit a
        subset)."""
        ...

    # ------------------------------------------------- dispatch / collect
    def dispatch_fwd(self, state: StageState, inp: Tree,
                     labels: Optional[jax.Array] = None
                     ) -> Callable[[], Tree]:
        """Issue the span forward NOW and return a zero-arg *collect*
        thunk for its result.  The async tick's executor-side lever: on
        real hardware JAX dispatches the computation and returns before
        it finishes, so the peer can start microbatch ``k+1`` (or put
        ``k``'s boundary on the wire) while ``k`` still runs; calling
        the thunk blocks until the result is materialized.  Semantically
        ``collect()`` must equal ``run_fwd(state, inp, labels)`` —
        backends where dispatch is synchronous just close over the
        finished value."""
        ...

    def dispatch_bwd(self, state: StageState, inp: Tree,
                     dy: Optional[Tree] = None,
                     labels: Optional[jax.Array] = None
                     ) -> Callable[[], tuple[Optional[float],
                                             Optional[Tree], Tree]]:
        """Issue the span backward NOW; the returned thunk yields
        ``(loss, gx, gp)`` exactly as ``run_bwd`` would."""
        ...

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        """Transform the forward output into what crosses the wire."""
        ...

    def wire_bwd(self, gx: Tree) -> Tree:
        """Transform the boundary cotangent into what crosses back."""
        ...

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int,
                   stage: Optional[int] = None) -> None:
        """Fold one microbatch gradient into the (per-stage) accumulator."""
        ...

    def export_grads(self, state: StageState,
                     stage: Optional[int] = None) -> Tree:
        """Stage ``stage``'s accumulator in a form addable across that
        stage's peers on the scheduler's device (identity for
        single-device backends, host-gathered for mesh backends)."""
        ...

    def export_state(self, state: StageState,
                     stage: Optional[int] = None) -> tuple[Tree, Tree]:
        """``(params, opt)`` in scheduler-local form, for the optimizer
        step at the All-Reduce barrier."""
        ...

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree, stage: Optional[int] = None) -> None:
        """Install post-optimizer-step state for one stage (placing it
        onto this backend's devices) and zero that stage's accumulator."""
        ...

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState, stage: Optional[int] = None,
                 slots: Iterable[str] = ()) -> Tree:
        """Host-side (numpy) ``{"params", "opt", "version"}`` tree — the
        wire format for peer-to-peer downloads and ``repro.ckpt``.  With
        an explicit ``stage``, span backends emit that covered stage in
        the SAME single-stage format, so span ↔ single hand-offs (and
        checkpoint cuts) are interchangeable.  ``slots`` names the extra
        keyed slots (e.g. ``"kv"``) to carry under a ``"slots"`` key;
        the default carries none, so training hand-offs and checkpoint
        cuts keep the historical format byte-for-byte and serving state
        never leaks into them."""
        ...

    def restore(self, state: StageState, snap: Tree,
                stage: Optional[int] = None,
                slots: Iterable[str] = ()) -> None:
        """Install a snapshot (device placement is the executor's job).
        A restore is a FULL state install: non-core slots not named in
        ``slots`` (or absent from the snapshot) are dropped — restoring
        a kv-carrying snapshot into a training-only peer sheds the kv
        slot, and restoring a training snapshot into a serving peer
        evicts its stale sessions."""
        ...

    # ------------------------------------------------------ keyed slots
    def export_slot(self, state: StageState, name: str, key: Hashable,
                    stage: Optional[int] = None) -> Tree:
        """One slot entry as a host (numpy) tree — the wire format for
        per-session hand-offs (e.g. prefill → decode KV transfer)."""
        ...

    def install_slot(self, state: StageState, name: str, key: Hashable,
                     value: Tree, stage: Optional[int] = None) -> None:
        """Place one slot entry onto this backend's devices."""
        ...

    def drop_slot(self, state: StageState, name: str,
                  key: Optional[Hashable] = None,
                  stage: Optional[int] = None) -> None:
        """Forget one slot entry (or, with ``key=None``, the slot)."""
        ...


def host_snapshot(state: StageState, slots: Iterable[str] = ()) -> Tree:
    """Default single-stage ``snapshot``: pull params/opt to host numpy,
    plus any requested non-core ``slots`` present on the state."""
    snap = {"params": jax.device_get(state.params),
            "opt": jax.device_get(state.opt),
            "version": state.version}
    extra = {name: {k: jax.device_get(v)
                    for k, v in state.slots[name].items()}
             for name in slots
             if name not in CORE_SLOTS and name in state.slots}
    if extra:
        snap["slots"] = extra
    return snap


def install_snapshot(state: StageState, snap: Tree,
                     slots: Iterable[str] = (),
                     place=None) -> None:
    """Default single-stage ``restore`` body: install params/opt/version
    (placed via ``place``, default ``jnp.asarray``), replace the state's
    non-core slots with the requested ones from the snapshot, and reset
    training progress.  Executors with their own placement (mesh) pass
    ``place``; the slot entries always place via ``jnp.asarray`` (KV
    trees are per-peer, never sharded)."""
    place = place or (lambda t: jax.tree.map(jnp.asarray, t))
    state.params = place(snap["params"])
    state.opt = (place(snap["opt"])
                 if snap.get("opt") is not None else None)
    state.version = int(snap.get("version", 0))
    for name in [n for n in state.slots if n not in CORE_SLOTS]:
        del state.slots[name]
    carried = snap.get("slots", {})
    for name in slots:
        if name in CORE_SLOTS or name not in carried:
            continue
        state.slot(name).update(
            {k: jax.tree.map(jnp.asarray, v)
             for k, v in carried[name].items()})
    state.reset_progress()


def slot_export(view: StageState, name: str, key: Hashable) -> Tree:
    """Default ``export_slot`` body over one stage view."""
    return jax.device_get(view.slot(name)[key])


def slot_install(view: StageState, name: str, key: Hashable,
                 value: Tree) -> None:
    """Default ``install_slot`` body over one stage view."""
    view.slot(name)[key] = jax.tree.map(jnp.asarray, value)


# donated-accumulator fold shared by every backend: one jit object, jax
# caches the compiled fold per (tree structure, shapes, shardings).
# Donating arg 0 makes the add in-place — the old grad_acc buffer is
# dead the moment it returns (StageState owns it exclusively).
_accumulate = jax.jit(
    lambda acc, g: jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g),
    donate_argnums=(0,))


def fold_into(state: StageState, gp: Optional[Tree],
              loss: Optional[float], n_tokens: int) -> None:
    """Default ``accumulate``: fold one microbatch gradient + bookkeeping
    into ``state`` (identical for single-device and mesh backends — the
    donated jit respects whatever placement the trees carry)."""
    if gp is not None:
        state.grad_acc = _accumulate(state.grad_acc, gp)
    state.token_count += n_tokens
    if loss is not None:
        state.loss_sum += loss


def single_stage(ex: StageExecutor, stage: Optional[int]) -> None:
    """Guard for single-stage backends' ``stage=`` keywords."""
    if stage is not None and stage != ex.stage:
        raise ValueError(
            f"{type(ex).__name__} serves stage {ex.stage}, not {stage}")


def _int8_roundtrip_tree(tree: Tree, quant_block: int,
                         use_kernel: bool = False) -> Tree:
    """int8-round-trip every floating leaf of a wire payload, passing
    integer leaves (e.g. the token ids riding a whisper boundary tree)
    through untouched.  Plain activations are the single-leaf case.
    ``use_kernel`` routes through the fused single-launch Pallas round
    trip (same codes)."""
    if use_kernel:
        from repro.kernels.boundary.ops import int8_roundtrip
        rt = lambda a: int8_roundtrip(a, quant_block, quant_block, True)
    else:
        from repro.compression.quant8 import _roundtrip
        rt = lambda a: _roundtrip(a, quant_block)
    return jax.tree.map(
        lambda a: rt(a)
        if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating) else a,
        tree)


def _wire_use_kernel(ex: StageExecutor) -> bool:
    return getattr(getattr(ex, "cfg", None), "kernels", "jnp") == "pallas"


def wire_fwd_codec(ex: StageExecutor, y: Tree) -> Tree:
    """Shared ``wire_fwd`` codec step: int8 quantize-on-send on live
    span-edge boundaries.  Learned codecs already emitted the c-dim wire
    tensor inside the stage program; ``none`` crosses raw; a span whose
    last covered stage is the pipeline's last emits a loss, not a
    boundary — and fused (intra-span) boundaries never reach here."""
    if ex.compress_mode == "int8" and ex.stages.stop < ex.n_stages:
        return _int8_roundtrip_tree(y, ex.quant_block, _wire_use_kernel(ex))
    return y


def wire_bwd_codec(ex: StageExecutor, gx: Optional[Tree]
                   ) -> Optional[Tree]:
    """Shared ``wire_bwd`` codec step: int8 quantizes the boundary
    cotangent (None when the span starts at stage 0 — nothing crosses
    back)."""
    if gx is not None and ex.compress_mode == "int8":
        return _int8_roundtrip_tree(gx, ex.quant_block,
                                    _wire_use_kernel(ex))
    return gx
