"""The stage-runtime layer: what a SWARM "peer" runs.

The elastic scheduler (``repro.core``) decides *where* a microbatch goes;
a :class:`StageExecutor` decides *how* the chosen peer executes its stage.
Unifying the two previously-disjoint stage implementations — the eager
per-peer ``StageProgram`` math and the compiled GSPMD path of
``repro.dist`` — behind this protocol is what lets a heterogeneous swarm
(paper §3, and Diskin et al.'s pooled-hardware setting) mix peers that
are a lone T4 with peers that are an 8-device mesh slice, inside one
pipeline:

* :class:`~repro.runtime.numeric.NumericExecutor` — single-device stage
  math behind a process-wide compile cache (one jit per stage shared by
  every peer of that stage, instead of per-peer re-tracing);
* :class:`~repro.runtime.mesh.MeshExecutor` — the stage step sharded
  over a device mesh via the ``repro.dist`` rules (data-parallel within
  the peer).

Executors are *stateless* with respect to training progress: all mutable
state lives in the :class:`StageState` the scheduler hands in, so N
peers of one stage share one executor, and a peer migrating between
stages just swaps executors.  ``snapshot``/``restore`` speak host-side
(numpy) trees — the common wire format for peer-to-peer state downloads
(numeric ↔ mesh in either direction) and for ``repro.ckpt``, which is
how a stage that lost all its peers resumes from the latest completed
step instead of step 0 (Varuna-style elastic restart).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass
class StageState:
    """Replicated training state for one pipeline stage.

    Owned by the executor protocol: schedulers treat it as an opaque
    handle and go through executor methods (``accumulate``, ``snapshot``,
    ``restore``, ``adopt_step``) for every mutation that touches device
    memory.
    """
    params: Tree = None
    opt: Tree = None
    grad_acc: Tree = None
    loss_sum: float = 0.0
    token_count: int = 0
    version: int = 0

    def zero_grads(self):
        if self.grad_acc is not None:
            self.grad_acc = jax.tree.map(jnp.zeros_like, self.grad_acc)
        self.loss_sum = 0.0
        self.token_count = 0

    def reset_progress(self):
        """Fresh accumulator (zeros shaped/placed like ``params``) and
        cleared loss/token counters — the tail of every state install
        (restore, adopt_step): a download or step never imports grads."""
        self.grad_acc = jax.tree.map(jnp.zeros_like, self.params)
        self.loss_sum = 0.0
        self.token_count = 0


@runtime_checkable
class StageExecutor(Protocol):
    """How a peer runs one pipeline stage (init / fwd / bwd / accumulate /
    snapshot / restore / wire-codec handling).

    ``run_fwd``/``run_bwd`` consume and produce *wire* tensors: whatever
    representation crosses between peers (the learned codecs' c-dim
    tensor, or the d-dim activation for ``none``/``int8``).  The int8
    round-trip that used to be special-cased in the trainer lives in
    ``wire_fwd``/``wire_bwd`` — the trainer is codec-agnostic.
    """

    stage: int
    n_stages: int
    compress_mode: str
    quant_block: int               # int8 wire codec block size
    device_count: int              # relative capacity of this backend
    fwd_flops_per_token: float
    bwd_flops_per_token: float

    # ---------------------------------------------------------- lifecycle
    def init_state(self, key: jax.Array) -> StageState: ...

    def for_stage(self, stage: int) -> "StageExecutor":
        """The sibling executor serving ``stage`` on the same backend
        (used when a peer migrates between stages)."""
        ...

    def dp_shards(self, batch: int) -> int:
        """How many ways this backend actually splits a ``batch``-sized
        microbatch (the cost model's compute speedup).  1 whenever the
        placement would replicate instead of shard."""
        ...

    # ---------------------------------------------------------- execution
    def run_fwd(self, state: StageState, inp: Tree,
                labels: Optional[jax.Array] = None) -> Tree:
        """Stage forward from the boundary input.  Last stage returns the
        token-sum loss; others return the outbound wire tensor."""
        ...

    def run_bwd(self, state: StageState, inp: Tree,
                dy: Optional[Tree] = None,
                labels: Optional[jax.Array] = None
                ) -> tuple[Optional[float], Optional[Tree], Tree]:
        """Stage backward (recomputes forward from ``inp``, App. A).
        Returns ``(loss, gx, gp)``; ``loss`` only on the last stage,
        ``gx`` None on the first."""
        ...

    # --------------------------------------------------------- wire codec
    def wire_fwd(self, y: Tree) -> Tree:
        """Transform the forward output into what crosses the wire."""
        ...

    def wire_bwd(self, gx: Tree) -> Tree:
        """Transform the boundary cotangent into what crosses back."""
        ...

    # -------------------------------------------------------- accumulation
    def accumulate(self, state: StageState, gp: Optional[Tree],
                   loss: Optional[float], n_tokens: int) -> None:
        """Fold one microbatch gradient into the state's accumulator."""
        ...

    def export_grads(self, state: StageState) -> Tree:
        """The accumulator in a form addable across this stage's peers
        on the scheduler's device (identity for single-device backends,
        host-gathered for mesh backends)."""
        ...

    def export_state(self, state: StageState) -> tuple[Tree, Tree]:
        """``(params, opt)`` in scheduler-local form, for the optimizer
        step at the All-Reduce barrier."""
        ...

    def adopt_step(self, state: StageState, new_params: Tree,
                   new_opt: Tree) -> None:
        """Install post-optimizer-step state (placing it onto this
        backend's devices) and zero the accumulator."""
        ...

    # ---------------------------------------------------- state transfer
    def snapshot(self, state: StageState) -> Tree:
        """Host-side (numpy) ``{"params", "opt", "version"}`` tree — the
        wire format for peer-to-peer downloads and ``repro.ckpt``."""
        ...

    def restore(self, state: StageState, snap: Tree) -> None:
        """Install a snapshot (device placement is the executor's job)."""
        ...


def host_snapshot(state: StageState) -> Tree:
    """Default ``snapshot``: pull params/opt to host numpy."""
    return {"params": jax.device_get(state.params),
            "opt": jax.device_get(state.opt),
            "version": state.version}


# donated-accumulator fold shared by every backend: one jit object, jax
# caches the compiled fold per (tree structure, shapes, shardings).
# Donating arg 0 makes the add in-place — the old grad_acc buffer is
# dead the moment it returns (StageState owns it exclusively).
_accumulate = jax.jit(
    lambda acc, g: jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g),
    donate_argnums=(0,))


def fold_into(state: StageState, gp: Optional[Tree],
              loss: Optional[float], n_tokens: int) -> None:
    """Default ``accumulate``: fold one microbatch gradient + bookkeeping
    into ``state`` (identical for single-device and mesh backends — the
    donated jit respects whatever placement the trees carry)."""
    if gp is not None:
        state.grad_acc = _accumulate(state.grad_acc, gp)
    state.token_count += n_tokens
    if loss is not None:
        state.loss_sum += loss


def wire_fwd_codec(ex: StageExecutor, y: Tree) -> Tree:
    """Shared ``wire_fwd`` codec step: int8 quantize-on-send on live
    boundaries.  Learned codecs already emitted the c-dim wire tensor
    inside the stage program; ``none`` crosses raw; the last stage
    emits a loss, not a boundary."""
    if ex.compress_mode == "int8" and ex.stage < ex.n_stages - 1:
        from repro.compression.quant8 import _roundtrip
        return _roundtrip(y, ex.quant_block)
    return y


def wire_bwd_codec(ex: StageExecutor, gx: Optional[Tree]
                   ) -> Optional[Tree]:
    """Shared ``wire_bwd`` codec step: int8 quantizes the boundary
    cotangent (None on the first stage — nothing crosses back)."""
    if gx is not None and ex.compress_mode == "int8":
        from repro.compression.quant8 import _roundtrip
        return _roundtrip(gx, ex.quant_block)
    return gx
