"""Deterministic synthetic LM data.

A learnable-but-nontrivial stream: order-2 Markov chain over a small state
space embedded into the vocab, so tiny models visibly reduce loss within a
few hundred steps (used by the convergence-parity tests, Fig. 4 analogue).
Batches are a pure function of (seed, step) — any peer can regenerate any
microbatch, which is exactly the property SWARM's fault tolerance relies on
("the data loader state can be recomputed from the last known SGD step",
App. A).  Host-sharding slices the batch deterministically by host index.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64          # markov states (mapped into vocab)
    curriculum_steps: int = 0   # paper App. G: linear seq-len warmup

    def _seq_len_at(self, step: int) -> int:
        if self.curriculum_steps and step < self.curriculum_steps:
            frac = (step + 1) / self.curriculum_steps
            s = max(16, int(self.seq_len * frac))
            return max(16, 1 << (s - 1).bit_length() >> 1)  # pow2 floor
        return self.seq_len

    def batch(self, step: int, host_index: int = 0,
              host_count: int = 1) -> Tree:
        assert self.global_batch % host_count == 0
        b = self.global_batch // host_count
        seq = self._seq_len_at(step)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            host_index)
        k1, k2 = jax.random.split(key)
        n = min(self.n_states, self.vocab_size)
        # order-2 markov: next = (a*prev + b*prev2 + noise) mod n
        x0 = jax.random.randint(k1, (b, 2), 0, n)
        noise = jax.random.randint(k2, (b, seq + 1), 0, 3)

        def step_fn(carry, eps):
            p1, p2 = carry
            nxt = (5 * p1 + 3 * p2 + eps) % n
            return (nxt, p1), nxt

        _, toks = jax.lax.scan(step_fn, (x0[:, 0], x0[:, 1]),
                               noise.swapaxes(0, 1))
        toks = toks.swapaxes(0, 1).astype(jnp.int32)   # [b, seq+1]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch(vocab_size: int, seq_len: int, batch: int, step: int = 0,
               seed: int = 0) -> Tree:
    return SyntheticLM(vocab_size, seq_len, batch, seed).batch(step)
