"""Blockwise dynamic 8-bit quantization (Dettmers et al., 2021) — the
compression SWARM applies to activations *and* gradients at pipeline-stage
boundaries (§4.3, App. J: "a reliable default ... does not degrade
per-iteration convergence").

Tensors are flattened into blocks of ``block_size``; each block is scaled by
its absmax and rounded to int8.  ``compress_boundary`` is the autodiff-aware
wrapper: the forward pass sends quantized activations, the backward pass
quantizes the cotangent too (what actually crosses the wire in SWARM both
ways), with a straight-through estimator around the rounding itself.

The TPU hot path lives in ``repro/kernels/quant8`` (Pallas); this module is
the pure-jnp oracle and CPU fallback — ``use_kernel=True`` routes through
the Pallas op.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 64  # paper-faithful default (Dettmers 2021 blockwise state)


def _pad_to_block(flat: jax.Array, block: int) -> tuple[jax.Array, int]:
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def blockwise_quantize(x: jax.Array, block: int = BLOCK):
    """x (any shape) -> (int8 codes [n_blocks, block], f32 scales, meta)."""
    shape, dtype = x.shape, x.dtype
    flat, pad = _pad_to_block(x.reshape(-1).astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)   # [nb, 1]
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12) * 127.0)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale, (shape, dtype, pad)


def blockwise_dequantize(q: jax.Array, scale: jax.Array, meta) -> jax.Array:
    shape, dtype, pad = meta
    flat = (q.astype(jnp.float32) * scale / 127.0).reshape(-1)
    if pad:
        flat = flat[:flat.shape[0] - pad]
    return flat.reshape(shape).astype(dtype)


def _roundtrip(x: jax.Array, block: int) -> jax.Array:
    if jnp.issubdtype(x.dtype, jnp.integer):
        return x
    q, s, meta = blockwise_quantize(x, block)
    return blockwise_dequantize(q, s, meta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def compress_boundary(x: jax.Array, block: int = BLOCK,
                      grad_block: int = BLOCK) -> jax.Array:
    """8-bit compress what crosses a SWARM stage boundary, both directions."""
    return _roundtrip(x, block)


def _fwd(x, block, grad_block):
    return _roundtrip(x, block), None


def _bwd(block, grad_block, _, g):
    return (_roundtrip(g, grad_block),)


compress_boundary.defvjp(_fwd, _bwd)


def quantization_error(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """Relative L2 roundtrip error — property-tested bound: for absmax
    scaling the per-element error is <= scale/254, so relative block error
    is <= ~1/127 for non-degenerate blocks."""
    q, s, meta = blockwise_quantize(x, block)
    xr = blockwise_dequantize(q, s, meta)
    return jnp.linalg.norm(xr - x) / jnp.maximum(jnp.linalg.norm(x), 1e-12)


def compressed_nbytes(n: int, block: int = BLOCK) -> int:
    """Wire size of an ``n``-element tensor after 8-bit compression: one
    int8 code per element + one f32 scale per (ceil-divided) block.  The
    analytic cost model (``repro.models.flops.boundary_bytes``) delegates
    here so simulated bytes always match this module's output."""
    nb = -(-n // block)
    return n + 4 * nb


def compressed_bytes(x: jax.Array, block: int = BLOCK) -> int:
    """Wire size after 8-bit compression (codes + per-block f32 scales)."""
    return compressed_nbytes(x.size, block)
