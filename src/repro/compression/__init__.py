from repro.compression.quant8 import (
    blockwise_quantize, blockwise_dequantize, compress_boundary,
    quantization_error, compressed_nbytes,
)
from repro.compression.bottleneck import bottleneck_specs, apply_bottleneck
from repro.compression.maxout import maxout_specs, apply_maxout
from repro.compression import codecs

__all__ = [
    "blockwise_quantize", "blockwise_dequantize", "compress_boundary",
    "quantization_error", "compressed_nbytes", "bottleneck_specs",
    "apply_bottleneck", "maxout_specs", "apply_maxout", "codecs",
]
