from repro.compression.quant8 import (
    blockwise_quantize, blockwise_dequantize, compress_boundary,
    quantization_error,
)
from repro.compression.bottleneck import bottleneck_specs, apply_bottleneck
from repro.compression.maxout import maxout_specs, apply_maxout

__all__ = [
    "blockwise_quantize", "blockwise_dequantize", "compress_boundary",
    "quantization_error", "bottleneck_specs", "apply_bottleneck",
    "maxout_specs", "apply_maxout",
]
