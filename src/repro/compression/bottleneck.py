"""Linear bottleneck compression layer (paper App. J.1).

``Bottleneck(x) = LayerNorm(LayerNorm(MLP(x)) @ w_c) @ w_d`` — ``w_c`` lives
on the sending stage, ``w_d`` on the receiving stage; the wire carries the
``c``-dim tensor, an ``m/c``× reduction.  The paper finds LayerNorm around
the projection critical for stable training.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

Tree = Any


def _ln(x: jax.Array) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def bottleneck_specs(d_model: int, d_compress: int,
                     dtype=jnp.float32) -> Tree:
    return {
        "w_c": ParamSpec((d_model, d_compress), dtype,
                         axes=("embed", "bottleneck")),
        "w_d": ParamSpec((d_compress, d_model), dtype,
                         axes=("bottleneck", "embed")),
    }


def compress(p: Tree, x: jax.Array) -> jax.Array:
    """Sending stage: [.., m] -> [.., c] (this is what crosses the wire)."""
    return _ln(_ln(x) @ p["w_c"].astype(x.dtype))


def decompress(p: Tree, z: jax.Array) -> jax.Array:
    """Receiving stage: [.., c] -> [.., m]."""
    return z @ p["w_d"].astype(z.dtype)


def apply_bottleneck(p: Tree, x: jax.Array) -> jax.Array:
    return decompress(p, compress(p, x))


def wire_ratio(d_model: int, d_compress: int) -> float:
    return d_compress / d_model
