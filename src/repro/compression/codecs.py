"""Boundary-codec dispatch — ONE source of truth for what crosses a SWARM
stage boundary under each ``cfg.boundary_compression`` mode (paper App. J).

Four modes:

* ``none``        — raw activations (2-byte wire elements, bf16 convention);
* ``int8``        — blockwise 8-bit roundtrip (:mod:`repro.compression.quant8`),
                    parameter-free, applied to the wire tensor both ways;
* ``bottleneck``  — learned linear bottleneck (App. J.1): the sending stage
                    owns ``w_c`` ([m, c]), the receiving stage ``w_d``
                    ([c, m]); the wire carries the ``c``-dim tensor;
* ``maxout``      — maxout_k feature pooling (parameter-free compress) + a
                    learned ``w_d`` ([m/k, m]) on the receiving stage.

The geometry is keyed off the config: ``cfg.bottleneck_dim`` is the wire
width ``c`` for the bottleneck (default ``d_model // 2`` — the paper's "2x
feature compression"); ``cfg.maxout_k`` is the maxout pool width (default
derived as ``d_model // bottleneck_dim``, else 2).  Both execution paths
(the GSPMD pipeline in :mod:`repro.dist.pipeline` and the elastic stage
programs in :mod:`repro.runtime.stage_model`) and the analytic cost model
(:func:`repro.models.flops.boundary_bytes`) resolve shapes through here, so
simulated wire bytes always match what the real codecs emit.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.compression import bottleneck as bn
from repro.compression import maxout as mx
from repro.models.config import ArchConfig
from repro.models.params import ParamSpec

Tree = Any

MODES = ("none", "int8", "bottleneck", "maxout")
LEARNED = ("bottleneck", "maxout")


def resolve_mode(cfg: ArchConfig, compress: Optional[str] = None) -> str:
    """``compress`` overrides ``cfg.boundary_compression``; validate."""
    mode = cfg.boundary_compression if compress is None else compress
    if mode not in MODES:
        raise ValueError(f"unknown boundary compression {mode!r}; "
                         f"expected one of {MODES}")
    return mode


def maxout_k(cfg: ArchConfig) -> int:
    """Maxout pool width ``k``: explicit ``cfg.maxout_k``, else derived from
    ``cfg.bottleneck_dim``, else the paper's default 2x."""
    if cfg.maxout_k:
        k = cfg.maxout_k
    elif cfg.bottleneck_dim:
        k = max(1, cfg.d_model // cfg.bottleneck_dim)
    else:
        k = 2
    if cfg.d_model % k:
        raise ValueError(f"maxout k={k} must divide d_model={cfg.d_model}")
    return k


def wire_dim(cfg: ArchConfig, compress: Optional[str] = None) -> int:
    """Feature width of the tensor that actually crosses the wire."""
    mode = resolve_mode(cfg, compress)
    if mode == "bottleneck":
        c = cfg.bottleneck_dim or cfg.d_model // 2
        if not 0 < c <= cfg.d_model:
            raise ValueError(f"bottleneck_dim={c} outside (0, d_model="
                             f"{cfg.d_model}]")
        return c
    if mode == "maxout":
        return cfg.d_model // maxout_k(cfg)
    return cfg.d_model


# ------------------------------------------------------------ ParamSpecs
def sender_specs(cfg: ArchConfig, compress: Optional[str] = None) -> Tree:
    """Codec params owned by a SENDING stage (compress side)."""
    mode = resolve_mode(cfg, compress)
    if mode == "bottleneck":
        return {"w_c": ParamSpec((cfg.d_model, wire_dim(cfg, mode)),
                                 cfg.param_jdtype,
                                 axes=("embed", "bottleneck"))}
    return {}                                # maxout compress is param-free


def receiver_specs(cfg: ArchConfig, compress: Optional[str] = None) -> Tree:
    """Codec params owned by a RECEIVING stage (decompress side)."""
    mode = resolve_mode(cfg, compress)
    if mode in LEARNED:
        return {"w_d": ParamSpec((wire_dim(cfg, mode), cfg.d_model),
                                 cfg.param_jdtype,
                                 axes=("bottleneck", "embed"))}
    return {}


def pipeline_boundary_specs(cfg: ArchConfig) -> Optional[Tree]:
    """Stage-stacked codec specs for the GSPMD pipeline: leading dim is the
    boundary index ``b`` in ``0..pipeline_stages-2`` (``w_c[b]`` owned by
    sending stage ``b``, ``w_d[b]`` by receiving stage ``b+1``).  ``None``
    unless the config declares a learned codec AND a pipeline depth."""
    mode = cfg.boundary_compression
    if mode not in LEARNED or cfg.pipeline_stages <= 1:
        return None
    nb = cfg.pipeline_stages - 1
    d, c = cfg.d_model, wire_dim(cfg, mode)
    specs: Tree = {"w_d": ParamSpec((nb, c, d), cfg.param_jdtype,
                                    axes=("stage", "bottleneck", "embed"))}
    if mode == "bottleneck":
        specs["w_c"] = ParamSpec((nb, d, c), cfg.param_jdtype,
                                 axes=("stage", "embed", "bottleneck"))
    return specs


# ------------------------------------------------------------ apply
def compress(cfg: ArchConfig, mode: str, p: Tree, x: jax.Array) -> jax.Array:
    """[.., d_model] -> [.., wire_dim]: what the sending stage emits."""
    if mode == "bottleneck":
        return bn.compress(p, x)
    if mode == "maxout":
        return mx.compress(x, maxout_k(cfg))
    return x


def decompress(cfg: ArchConfig, mode: str, p: Tree, z: jax.Array
               ) -> jax.Array:
    """[.., wire_dim] -> [.., d_model]: what the receiving stage restores."""
    if mode == "bottleneck":
        return bn.decompress(p, z)
    if mode == "maxout":
        return mx.decompress(p, z)
    return z


def wire_qblock(cfg: ArchConfig, compress: Optional[str] = None) -> int:
    """Quantization block for the wire tensor under ``cfg.wire_quant`` —
    the paper's 64, gcd-aligned down so it divides the wire width."""
    from repro.kernels.boundary import ref as bref
    return bref.wire_qblock(wire_dim(cfg, compress))


def encode_wire(cfg: ArchConfig, mode: str, p: Tree,
                x: jax.Array) -> jax.Array:
    """Sending side of a boundary crossing, routed by ``cfg.kernels``:
    the legacy two-pass jnp path when nothing is fused, else the fused
    :mod:`repro.kernels.boundary` op (codec encode + optional blockwise
    int8 wire QDQ in one launch; gradients identical by construction)."""
    if mode not in LEARNED:
        return x
    pallas = cfg.kernels == "pallas"
    if not pallas and not cfg.wire_quant:
        return compress(cfg, mode, p, x)
    from repro.kernels.boundary import ops as bops
    w = (p or {}).get("w_c") if mode == "bottleneck" else None
    k = maxout_k(cfg) if mode == "maxout" else 1
    return bops.encode_wire(x, w, mode, k, wire_qblock(cfg, mode),
                            cfg.wire_quant, pallas)


def decode_wire(cfg: ArchConfig, mode: str, p: Tree,
                z: jax.Array) -> jax.Array:
    """Receiving side of a boundary crossing (mirror of
    :func:`encode_wire`; the wire QDQ lives on the sending side only,
    so each direction quantizes exactly once)."""
    if mode not in LEARNED:
        return z
    if cfg.kernels != "pallas":
        return decompress(cfg, mode, p, z)
    from repro.kernels.boundary import ops as bops
    return bops.decode_wire(z, p["w_d"], mode, True)


def int8_boundary(cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """The parameter-free ``int8`` boundary mode, routed by
    ``cfg.kernels``: quant8's two-launch quantize/dequantize pair, or
    the fused single-launch Pallas round trip (same codes, same STE
    backward)."""
    from repro.compression import quant8
    if cfg.kernels == "pallas":
        from repro.kernels.boundary import ops as bops
        return bops.int8_roundtrip(x, quant8.BLOCK, quant8.BLOCK, True)
    return quant8.compress_boundary(x)


def codec_flops_per_token(cfg: ArchConfig, mode: str, *, sender: bool,
                          receiver: bool) -> float:
    """Forward matmul FLOPs the codec adds to one stage, per token."""
    if mode not in LEARNED:
        return 0.0
    c = wire_dim(cfg, mode)
    f = 0.0
    if sender and mode == "bottleneck":
        f += 2.0 * cfg.d_model * c           # x @ w_c
    if receiver:
        f += 2.0 * c * cfg.d_model           # z @ w_d
    return f
