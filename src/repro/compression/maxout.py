"""Maxout compression layer (paper App. J.1, Goodfellow et al. 2013).

``Maxout_k`` reduces the hidden dim by k by taking the max over
non-overlapping windows of k features; a decompression matrix ``w_d`` on the
receiving stage restores ``m``.  Autodiff through ``max`` is the standard
subgradient (winner-takes-all), matching the original.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec
from repro.compression.bottleneck import _ln

Tree = Any


def maxout_specs(d_model: int, k: int, dtype=jnp.float32) -> Tree:
    assert d_model % k == 0
    return {
        "w_d": ParamSpec((d_model // k, d_model), dtype,
                         axes=("bottleneck", "embed")),
    }


def compress(x: jax.Array, k: int) -> jax.Array:
    """[.., m] -> [.., m/k]: maxout_k(LayerNorm(x)) (crosses the wire)."""
    x = _ln(x)
    m = x.shape[-1]
    return x.reshape(*x.shape[:-1], m // k, k).max(-1)


def decompress(p: Tree, z: jax.Array) -> jax.Array:
    return _ln(z) @ p["w_d"].astype(z.dtype)


def apply_maxout(p: Tree, x: jax.Array, k: int) -> jax.Array:
    return decompress(p, compress(x, k))
