"""swarm-1b with the paper's strongest learned boundary codec (App. J.1):
a linear bottleneck 4096 -> 1024 at each of the two stage boundaries.  The
wire carries 2-byte c-dim activations — 4x fewer bytes than bf16 and ~2x
fewer than blockwise int8 — which is what makes the paper's headline
"train 1B on < 200 Mb/s" scenario viable.

``pipeline_stages=3`` (the paper's 3 stages of 16 shared layers) attaches
one trainable ``(w_c, w_d)`` pair per boundary to ``model_specs``; the
GSPMD pipeline trains them jointly with the model.
"""
from repro.configs.swarm1b import CONFIG as _BASE

CONFIG = _BASE.with_overrides(
    name="swarm-1b-bottleneck",
    boundary_compression="bottleneck",
    bottleneck_dim=1024,
    pipeline_stages=3,
)
