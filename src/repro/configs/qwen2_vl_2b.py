"""qwen2-vl-2b — M-RoPE, dynamic-resolution vision (frontend stub)
[arXiv:2409.12191; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936, head_dim=128,
    rope="mrope", rope_theta=1_000_000.0, qkv_bias=True,
    act="swiglu", norm="rmsnorm", tie_embeddings=True,
    frontend="vision_stub",
)
