"""swarm-1b with maxout_2 boundary compression (App. J.1, Goodfellow et
al. 2013): the sending stage pools non-overlapping pairs of features
(param-free, 2x fewer wire bytes), the receiving stage restores d_model
with a learned ``w_d``.  Paper Table 7 puts its convergence cost on par
with the 2x bottleneck at the same wire ratio.
"""
from repro.configs.swarm1b import CONFIG as _BASE

CONFIG = _BASE.with_overrides(
    name="swarm-1b-maxout",
    boundary_compression="maxout",
    maxout_k=2,
    pipeline_stages=3,
)
