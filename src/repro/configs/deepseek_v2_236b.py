"""deepseek-v2-236b — MLA (kv_lora 512) + MoE 160e top-6 + 2 shared
[arXiv:2405.04434; hf].

Uniform mla_moe pattern: the original's first-layer dense FFN (<0.1% of
parameters) is folded into the uniform stack so SWARM pipeline stages are
structurally identical (DESIGN.md §5). bf16 params at this scale.
"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=1536,
    vocab_size=102400, head_dim=128,
    rope="rope", rope_theta=10_000.0, act="swiglu", norm="rmsnorm",
    block_pattern=("mla_moe",) * 60,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, num_shared=2, top_k=6, d_ff_expert=1536,
                  capacity_factor=1.25),
    param_dtype="bfloat16",
)
