"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

bf16 parameters: at 512-way dry-run scale the fp32 copy lives only in the
optimizer state (see DESIGN.md §6).
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128,
    rope="rope", rope_theta=500_000.0, act="swiglu", norm="rmsnorm",
    moe=MoEConfig(num_experts=16, num_shared=1, top_k=1, d_ff_expert=8192,
                  capacity_factor=1.25),
    param_dtype="bfloat16",
)
