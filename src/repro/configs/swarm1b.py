"""The paper's own model (§4.3): 1.01B-param Transformer LM, 3 stages of 16
shared layers each (ALBERT-style), d_model=4096, RoPE + GeGLU, trained with
8-bit compressed activations on preemptible T4s.

Because of layer sharing this is compute-equivalent to a 13B model
(Brown et al., 2020) — `share_groups=3` stores one parameter group per SWARM
pipeline stage.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="swarm-1b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=16384,
    vocab_size=50257, head_dim=128,
    rope="rope", act="geglu", norm="layernorm",
    share_groups=3,
    boundary_compression="int8",
)
