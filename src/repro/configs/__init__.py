"""Architecture registry: ``get_config(name)`` / ``--arch <id>`` resolution."""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, reduced

from repro.configs import (
    yi_6b, h2o_danube_3_4b, qwen15_4b, gemma_2b, qwen2_vl_2b, xlstm_125m,
    whisper_large_v3, hymba_1_5b, llama4_scout_17b_a16e, deepseek_v2_236b,
    swarm1b, swarm1b_bottleneck, swarm1b_maxout, swarm1b_span,
)

_MODULES = [yi_6b, h2o_danube_3_4b, qwen15_4b, gemma_2b, qwen2_vl_2b,
            xlstm_125m, whisper_large_v3, hymba_1_5b, llama4_scout_17b_a16e,
            deepseek_v2_236b, swarm1b, swarm1b_bottleneck, swarm1b_maxout,
            swarm1b_span]

REGISTRY: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The ten assigned architectures (the paper's own model is extra).
ASSIGNED = [
    "yi-6b", "h2o-danube-3-4b", "qwen1.5-4b", "gemma-2b", "qwen2-vl-2b",
    "xlstm-125m", "whisper-large-v3", "hymba-1.5b", "llama4-scout-17b-a16e",
    "deepseek-v2-236b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_reduced(name: str) -> ArchConfig:
    return reduced(get_config(name))


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is (arch x shape) runnable? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 500k context is "
                       "unservable (DESIGN.md §5)")
    return True, ""
