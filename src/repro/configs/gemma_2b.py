"""gemma-2b — GeGLU, head_dim 256, MQA (kv=1) [arXiv:2403.08295; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=256000, head_dim=256,
    rope="rope", rope_theta=10_000.0, act="geglu", norm="rmsnorm",
    tie_embeddings=True, scale_embed=True,
)
