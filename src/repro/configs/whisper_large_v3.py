"""whisper-large-v3 — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, head_dim=64,
    rope="none", act="gelu", norm="layernorm",
    encoder_layers=32, encoder_max_len=1500,
    frontend="audio_stub",
)
