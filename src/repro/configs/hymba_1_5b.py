"""hymba-1.5b — parallel attention + mamba heads [arXiv:2411.13676; hf].

Meta-tokens are omitted (orthogonal to the systems study); the attention
path uses a 2048-token sliding window as in the bulk of Hymba's layers,
which is what makes the arch servable at 500k context (DESIGN.md §5).
"""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64,
    rope="rope", act="swiglu", norm="rmsnorm",
    sliding_window=2048,
    ssm=SSMConfig(state_dim=16, expand=2, chunk=128),
)
