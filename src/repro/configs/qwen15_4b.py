"""qwen1.5-4b — MHA with QKV bias [hf:Qwen/Qwen1.5-4B; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab_size=151936, head_dim=128,
    rope="rope", rope_theta=5_000_000.0, qkv_bias=True,
    act="swiglu", norm="rmsnorm",
)
