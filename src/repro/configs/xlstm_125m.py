"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

The 7:1 mLSTM:sLSTM mix is arranged as two identical (5×mLSTM, 1×sLSTM)
halves so that the 2-stage SWARM pipeline has structurally identical stages
(DESIGN.md §5); d_ff=0 — xLSTM blocks carry their own projections.
"""
from repro.models.config import ArchConfig, SSMConfig

_PATTERN = ("mlstm",) * 5 + ("slstm",) + ("mlstm",) * 5 + ("slstm",)

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, head_dim=192,
    rope="none", act="gelu", norm="layernorm",
    block_pattern=_PATTERN,
    ssm=SSMConfig(state_dim=16, chunk=128),
    tie_embeddings=True,
)
