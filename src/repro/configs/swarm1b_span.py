"""swarm-1b for span-peer serving: the learned bottleneck codec plus a
pipeline depth sized so well-provisioned peers fuse *several consecutive
stages* in one jit (``repro.runtime.PipelineExecutor``) — the paper's
square-cube rebalancing made literal.  Every fused boundary keeps its
compress/decompress pair on-device, so the c-dim wire tensor only exists
at span edges: a peer serving 2 of the 3 stages moves HALF the boundary
bytes of three single-stage peers at identical numerics (the span
churn-equivalence tests pin this at 2e-4).

Used by ``benchmarks/bench_swarm.py``'s span-vs-single comparison and by
``SwarmConfig(spans=True)`` runs, where Alg. 2 proposes span splits and
merges on membership change.
"""
from repro.configs.swarm1b import CONFIG as _BASE

CONFIG = _BASE.with_overrides(
    name="swarm-1b-span",
    boundary_compression="bottleneck",
    bottleneck_dim=1024,
    pipeline_stages=3,
)
