"""Recurrent sequence mixers: Mamba selective SSM, xLSTM (mLSTM + sLSTM).

Training paths are chunkwise-parallel (memory O(chunk), FLOPs linear in T);
decode paths are O(1)-state single-step recurrences — this is what makes the
``long_500k`` shape servable for the ssm/hybrid architectures (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamSpec
from repro.models.probe import probe_enabled

Tree = Any


# ================================================================ Mamba
def mamba_specs(cfg: ArchConfig, d: int | None = None) -> Tree:
    s = cfg.ssm
    d = d or cfg.d_model
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    pd = cfg.param_jdtype
    return {
        "w_in": ParamSpec((d, 2 * di), pd, axes=("embed", "mlp")),
        "conv_w": ParamSpec((s.conv_kernel, di), pd, axes=("conv", "mlp")),
        "conv_b": ParamSpec((di,), pd, "zeros", ("mlp",)),
        "w_x": ParamSpec((di, dtr + 2 * s.state_dim), pd, axes=("mlp", "state")),
        "w_dt": ParamSpec((dtr, di), pd, axes=("state", "mlp")),
        "b_dt": ParamSpec((di,), pd, "zeros", ("mlp",)),
        "a_log": ParamSpec((di, s.state_dim), jnp.float32, "zeros",
                           ("mlp", "state")),
        "d_skip": ParamSpec((di,), jnp.float32, "ones", ("mlp",)),
        "w_out": ParamSpec((di, d), pd, axes=("mlp", "embed")),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. u [B, T, C], w [K, C]."""
    K = w.shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for k in range(K):
        out = out + up[:, k:k + u.shape[1]] * w[k]
    return out + b


def _mamba_inner(cfg, p, x):
    """Shared pre-processing: returns (u, z, dt, Bm, Cm, A)."""
    s, cd = cfg.ssm, x.dtype
    d = x.shape[-1]
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    uz = x @ p["w_in"].astype(cd)
    u, z = uz[..., :di], uz[..., di:]
    return u, z, dtr, di


def apply_mamba(cfg: ArchConfig, p: Tree, x: jax.Array,
                return_state: bool = False):
    """Training path. x [B, T, d] -> [B, T, d] (opt. final decode state)."""
    s, cd = cfg.ssm, x.dtype
    B, T, d = x.shape
    u, z, dtr, di = _mamba_inner(cfg, p, x)
    u_raw = u
    u = jax.nn.silu(_causal_conv(u, p["conv_w"].astype(cd),
                                 p["conv_b"].astype(cd)))
    xp = u @ p["w_x"].astype(cd)
    dt_lr, Bm, Cm = (xp[..., :dtr], xp[..., dtr:dtr + s.state_dim],
                     xp[..., dtr + s.state_dim:])
    dt = jax.nn.softplus(dt_lr @ p["w_dt"].astype(cd)
                         + p["b_dt"].astype(cd)).astype(jnp.float32)
    A = -jnp.exp(p["a_log"])                                  # [di, N]

    c = T if probe_enabled() else min(s.chunk, T)
    nc = T // c
    assert nc * c == T, (T, c)

    def chunk_step(h, args):
        uc, dtc, Bc, Cc = args   # [B, c, ...]
        # decay factors a [B, c, di, N], inputs bx [B, c, di, N]
        a = jnp.exp(dt[..., None][:, 0:0] if False else
                    (dtc[..., None] * A))                     # [B,c,di,N]
        bx = (dtc * uc.astype(jnp.float32))[..., None] * \
            Bc.astype(jnp.float32)[:, :, None, :]             # [B,c,di,N]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2
        a_acc, h_in = jax.lax.associative_scan(combine, (a, bx), axis=1)
        hs = a_acc * h[:, None] + h_in                        # [B,c,di,N]
        y = jnp.einsum("bcdn,bcn->bcd", hs, Cc.astype(jnp.float32))
        return hs[:, -1], y

    resh = lambda t: t.reshape(B, nc, c, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((B, di, s.state_dim), jnp.float32)
    # nested remat: keep only the O(B*di*N) carry per chunk in backward —
    # without it the [B,c,di,N] discretized tensors of every chunk persist.
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                              (resh(u), resh(dt), resh(Bm), resh(Cm)))
    y = ys.swapaxes(0, 1).reshape(B, T, di)
    y = (y + u.astype(jnp.float32) * p["d_skip"]).astype(cd)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(cd)
    if return_state:
        K = s.conv_kernel
        tail = jnp.pad(u_raw, ((0, 0), (max(0, K - 1 - T), 0), (0, 0))
                       )[:, -(K - 1):]
        return out, {"h": h_last,
                     "conv": tail.astype(cfg.compute_jdtype)}
    return out


def mamba_cache_specs(cfg: ArchConfig, batch: int, d: int | None = None) -> Tree:
    s = cfg.ssm
    d = d or cfg.d_model
    di = s.expand * d
    return {
        "h": ParamSpec((batch, di, s.state_dim), jnp.float32, "zeros",
                       ("batch", "mlp", "state")),
        "conv": ParamSpec((batch, s.conv_kernel - 1, di), cfg.compute_jdtype,
                          "zeros", ("batch", "conv", "mlp")),
    }


def apply_mamba_decode(cfg: ArchConfig, p: Tree, x: jax.Array, cache: Tree):
    """One-step decode. x [B, 1, d]."""
    s, cd = cfg.ssm, x.dtype
    B = x.shape[0]
    u, z, dtr, di = _mamba_inner(cfg, p, x)
    u, z = u[:, 0], z[:, 0]
    # conv over cached tail + current
    tail = cache["conv"].astype(cd)                           # [B, K-1, di]
    window = jnp.concatenate([tail, u[:, None]], axis=1)      # [B, K, di]
    uc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(cd))
                     + p["conv_b"].astype(cd))
    xp = uc @ p["w_x"].astype(cd)
    dt_lr, Bm, Cm = (xp[..., :dtr], xp[..., dtr:dtr + s.state_dim],
                     xp[..., dtr + s.state_dim:])
    dt = jax.nn.softplus(dt_lr @ p["w_dt"].astype(cd)
                         + p["b_dt"].astype(cd)).astype(jnp.float32)
    A = -jnp.exp(p["a_log"])
    a = jnp.exp(dt[..., None] * A)                            # [B, di, N]
    h = a * cache["h"] + (dt * uc.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32))
    y = (y + uc.astype(jnp.float32) * p["d_skip"]).astype(cd)
    y = y * jax.nn.silu(z)
    out = (y @ p["w_out"].astype(cd))[:, None]
    new_cache = {"h": h, "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache


# ================================================================ mLSTM
# Matrix-memory LSTM == decay-gated linear attention; the normalizer n is
# folded in as an extra value column of ones.
def mlstm_specs(cfg: ArchConfig) -> Tree:
    d, H, pd = cfg.d_model, cfg.n_heads, cfg.param_jdtype
    hd = d // H
    return {
        "wq": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "w_if": ParamSpec((d, H, 2), pd, "zeros", ("embed", "heads", "null")),
        "b_if": ParamSpec((H, 2), pd, "zeros", ("heads", "null")),
        "w_og": ParamSpec((d, d), pd, axes=("embed", "embed2")),
        "wo": ParamSpec((H, hd, d), pd, axes=("heads", "head_dim", "embed")),
    }


def apply_mlstm(cfg: ArchConfig, p: Tree, x: jax.Array,
                return_state: bool = False):
    """Chunkwise-parallel mLSTM. x [B, T, d]."""
    cd = x.dtype
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    c = T if probe_enabled() else min(cfg.ssm.chunk if cfg.ssm else 128, T)
    nc = T // c
    assert nc * c == T

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(cd)) * hd ** -0.5
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(cd)) * hd ** -0.5
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(cd))
    v = jnp.concatenate([v, jnp.ones((B, T, H, 1), cd)], -1)  # normalizer col
    gates = jnp.einsum("btd,dhg->bthg", x, p["w_if"].astype(cd)) \
        + p["b_if"].astype(cd)
    logi = gates[..., 0].astype(jnp.float32)                  # [B,T,H]
    logf = jax.nn.log_sigmoid(gates[..., 1].astype(jnp.float32))

    resh = lambda t: t.reshape(B, nc, c, *t.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = resh(q), resh(k), resh(v)
    lic, lfc = resh(logi), resh(logf)

    def chunk_step(carry, args):
        C_in, m_in = carry          # [B,H,hd,hd+1], [B,H]
        qb, kb, vb, li, lf = args
        csum = jnp.cumsum(lf, axis=1)                         # [B,c,H]
        total = csum[:, -1]
        # stabilizer: running max of (csum_i + max future contribution)
        m_intra = jnp.max(li - csum, axis=1)                  # [B,H]
        m_new = jnp.maximum(m_in + total, m_intra + total)
        # inter-chunk: y_inter_i = (q_i * exp(csum_i + m_in - m_new')) C_in
        # use per-chunk stabilizer m_new for all positions (safe: exps <= 1)
        d_q = jnp.exp(csum + (m_in - m_new)[:, None])         # [B,c,H]
        y_inter = jnp.einsum("bihk,bhkv,bih->bihv", qb, C_in, d_q)
        # intra-chunk: score_ij = q_i k_j exp(csum_i - csum_j + li_j - m_new)
        gk = jnp.exp(li - csum - m_new[:, None])              # [B,c,H]
        s = jnp.einsum("bihk,bjhk->bhij", qb, kb)
        # d_ij = exp(csum_i - csum_j + li_j - m_new) = exp(csum_i) * gk_j, j<=i
        dmat = jnp.exp(csum).transpose(0, 2, 1)[:, :, :, None] \
            * gk.transpose(0, 2, 1)[:, :, None, :]            # [B,H,i,j]
        mask = jnp.tril(jnp.ones((c, c), bool))
        s = jnp.where(mask, s * dmat, 0.0)
        y_intra = jnp.einsum("bhij,bjhv->bihv", s.astype(cd), vb)
        y = y_inter.astype(jnp.float32) + y_intra.astype(jnp.float32)
        # state update: C' = exp(total + m_in - m_new) C_in + sum_j gk'_j k_j v_j
        gk_state = jnp.exp(li + (total[:, None] - csum) - m_new[:, None])
        C_new = jnp.exp(m_in + total - m_new)[:, :, None, None] * C_in + \
            jnp.einsum("bjhk,bjhv,bjh->bhkv", kb, vb, gk_state.astype(cd))
        return (C_new, m_new), y

    C0 = jnp.zeros((B, H, hd, hd + 1), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    (C_f, m_f), ys = jax.lax.scan(jax.checkpoint(chunk_step), (C0, m0),
                                  (qc, kc, vc, lic, lfc))
    y = ys.swapaxes(0, 1).reshape(B, T, H, hd + 1)
    num, den = y[..., :hd], y[..., hd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    og = jax.nn.silu(x @ p["w_og"].astype(cd))
    out = jnp.einsum("bthk,hkd->btd", y.astype(cd), p["wo"].astype(cd)) * og
    if return_state:
        return out, {"C": C_f, "m": m_f}
    return out


def mlstm_cache_specs(cfg: ArchConfig, batch: int) -> Tree:
    H = cfg.n_heads
    hd = cfg.d_model // H
    return {
        "C": ParamSpec((batch, H, hd, hd + 1), jnp.float32, "zeros",
                       ("batch", "heads", "head_dim", "v_dim")),
        "m": ParamSpec((batch, H), jnp.float32, "zeros", ("batch", "heads")),
    }


def apply_mlstm_decode(cfg: ArchConfig, p: Tree, x: jax.Array, cache: Tree):
    cd = x.dtype
    B = x.shape[0]
    H = cfg.n_heads
    hd = cfg.d_model // H
    xt = x[:, 0]
    q = jnp.einsum("bd,dhk->bhk", xt, p["wq"].astype(cd)) * hd ** -0.5
    k = jnp.einsum("bd,dhk->bhk", xt, p["wk"].astype(cd)) * hd ** -0.5
    v = jnp.einsum("bd,dhk->bhk", xt, p["wv"].astype(cd))
    v = jnp.concatenate([v, jnp.ones((B, H, 1), cd)], -1)
    gates = jnp.einsum("bd,dhg->bhg", xt, p["w_if"].astype(cd)) \
        + p["b_if"].astype(cd)
    logi = gates[..., 0].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(gates[..., 1].astype(jnp.float32))
    m_new = jnp.maximum(logf + cache["m"], logi)
    fp = jnp.exp(logf + cache["m"] - m_new)
    ip = jnp.exp(logi - m_new)
    C = fp[..., None, None] * cache["C"] + \
        ip[..., None, None] * jnp.einsum("bhk,bhv->bhkv", k, v
                                         ).astype(jnp.float32)
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), C)
    num, den = y[..., :hd], y[..., hd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    og = jax.nn.silu(xt @ p["w_og"].astype(cd))
    out = jnp.einsum("bhk,hkd->bd", y.astype(cd), p["wo"].astype(cd)) * og
    return out[:, None], {"C": C, "m": m_new}


# ================================================================ sLSTM
def slstm_specs(cfg: ArchConfig) -> Tree:
    d, H, pd = cfg.d_model, cfg.n_heads, cfg.param_jdtype
    hd = d // H
    return {
        "w": ParamSpec((d, H, 4 * hd), pd, axes=("embed", "heads", "head_dim")),
        "r": ParamSpec((H, hd, 4 * hd), pd, axes=("heads", "head_dim", "null")),
        "b": ParamSpec((H, 4 * hd), pd, "zeros", ("heads", "head_dim")),
        "wo": ParamSpec((d, d), pd, axes=("embed", "embed2")),
    }


def _slstm_cell(p_r, p_b, hd, wx_t, state):
    """One sLSTM step. wx_t [B,H,4hd]; state (c,n,h,m) each [B,H,hd]."""
    c, n, h, m = state
    pre = wx_t + jnp.einsum("bhk,hkg->bhg", h, p_r) + p_b
    zi, ii, fi, oi = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    logf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(logf + m, ii)
    ip = jnp.exp(ii - m_new)
    fp = jnp.exp(logf + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new.astype(wx_t.dtype), m_new)


def apply_slstm(cfg: ArchConfig, p: Tree, x: jax.Array,
                return_state: bool = False):
    """Sequential sLSTM (memory mixing forbids parallel scan). x [B,T,d]."""
    cd = x.dtype
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    wx = jnp.einsum("btd,dhg->bthg", x, p["w"].astype(cd))
    r, b = p["r"].astype(cd), p["b"].astype(cd)

    def step(state, wx_t):
        new = _slstm_cell(r, b, hd, wx_t, state)
        return new, new[2]

    z = jnp.zeros((B, H, hd), jnp.float32)
    state0 = (z, z, jnp.zeros((B, H, hd), cd), z)
    (c, n, h, m), hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, T, d)
    out = y @ p["wo"].astype(cd)
    if return_state:
        return out, {"c": c, "n": n, "h": h, "m": m}
    return out


def slstm_cache_specs(cfg: ArchConfig, batch: int) -> Tree:
    H = cfg.n_heads
    hd = cfg.d_model // H
    f32 = jnp.float32
    mk = lambda dt: ParamSpec((batch, H, hd), dt, "zeros",
                              ("batch", "heads", "head_dim"))
    return {"c": mk(f32), "n": mk(f32), "h": mk(cfg.compute_jdtype),
            "m": mk(f32)}


def apply_slstm_decode(cfg: ArchConfig, p: Tree, x: jax.Array, cache: Tree):
    cd = x.dtype
    H = cfg.n_heads
    hd = cfg.d_model // H
    wx = jnp.einsum("bd,dhg->bhg", x[:, 0], p["w"].astype(cd))
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p["r"].astype(cd), p["b"].astype(cd), hd, wx,
                             state)
    y = h.reshape(x.shape[0], -1) @ p["wo"].astype(cd)
    return y[:, None], {"c": c, "n": n, "h": h, "m": m}
