"""Unified decoder-LM assembly: embed -> segmented block scan -> head.

Layer patterns are grouped into runs of identical block kinds; each run is a
single ``lax.scan`` over stacked parameters (fast compile, small HLO — the
dry-run relies on this).  ALBERT-style layer sharing (the paper's 1B model,
§4.3) stores ``share_groups`` parameter groups and re-applies each group
``n_layers / share_groups`` times.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamSpec
from repro.models import layers as L
from repro.models import rope as rope_lib
from repro.models.blocks import REGISTRY
from repro.dist.constrain import constrain

Tree = Any


def segments(pattern: tuple[str, ...]) -> list[tuple[str, int]]:
    runs: list[tuple[str, int]] = []
    for k in pattern:
        if runs and runs[-1][0] == k:
            runs[-1] = (k, runs[-1][1] + 1)
        else:
            runs.append((k, 1))
    return runs


def _shared_kind(cfg: ArchConfig) -> str:
    """The single block kind of an ALBERT-shared stack.  Sharing one
    parameter group across structurally different blocks is undefined —
    fail loudly instead of silently applying ``block_kinds[0]`` to the
    whole stack."""
    kinds = set(cfg.block_kinds)
    if len(kinds) > 1:
        raise ValueError(
            f"{cfg.name}: share_groups={cfg.share_groups} requires "
            f"uniform block_kinds, got {sorted(kinds)}")
    return cfg.block_kinds[0]


def _shared_runs(cfg: ArchConfig) -> list[tuple[str, int]]:
    return [(_shared_kind(cfg), cfg.share_groups)]


def stack_specs(tree: Tree, n: int) -> Tree:
    def s(p: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + p.shape, p.dtype, p.init,
                         ("layers",) + p.axes, p.scale)
    return jax.tree.map(s, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def lm_specs(cfg: ArchConfig) -> Tree:
    d, V, pd = cfg.d_model, cfg.vocab_size, cfg.param_jdtype
    specs: Tree = {
        "embed": ParamSpec((V, d), pd, "embed", ("vocab", "embed")),
        "final_norm": L.norm_specs(cfg),
    }
    if cfg.share_groups:
        per = cfg.n_layers // cfg.share_groups
        assert per * cfg.share_groups == cfg.n_layers
        specs["blocks"] = [stack_specs(REGISTRY[_shared_kind(cfg)][0](cfg),
                                       cfg.share_groups)]
    else:
        specs["blocks"] = [stack_specs(REGISTRY[k][0](cfg), n)
                           for k, n in segments(cfg.block_kinds)]
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((d, V), pd, "normal", ("embed", "vocab"))
    return specs


def embed(cfg: ArchConfig, params: Tree, tokens: jax.Array,
          batch_axes=("pod", "data")) -> jax.Array:
    """Token embedding.  ``batch_axes``: mesh axes of the batch dim — the
    default folds ``pod`` into data parallelism; the GSPMD pipeline passes
    ``("data",)`` because there ``pod`` carries stages, not batch."""
    x = params["embed"][tokens].astype(cfg.compute_jdtype)
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    if x.ndim == 3:
        x = constrain(x, batch_axes, None, None)
    return x


def head(cfg: ArchConfig, params: Tree, x: jax.Array,
         batch_axes=("pod", "data")) -> jax.Array:
    x = L.apply_norm(cfg, params["final_norm"], x)
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ w.astype(x.dtype)
    if logits.ndim == 3:
        logits = constrain(logits, batch_axes, None, "model")
    return logits

def default_positions(cfg: ArchConfig, batch: int, seq: int,
                      offset=0) -> jax.Array:
    if cfg.rope == "mrope":
        return rope_lib.default_mrope_positions(batch, seq, offset)
    return jnp.arange(seq) + offset


def _sqrt_divisor(n: int) -> int:
    n1 = max(1, int(n ** 0.5))
    while n % n1:
        n1 -= 1
    return n1


def remat_scan(body, carry, xs, mode: str):
    """Layer scan with selectable checkpointing structure.

    ``block``  — paper-faithful per-block remat: the scan saves one carry
                 per layer (O(L) boundary activations).
    ``2level`` — sqrt(L) nesting: an outer checkpointed scan over ~sqrt(L)
                 groups saves only group-boundary carries; inner carries
                 are rematerialized per group in backward.  O(2*sqrt(L))
                 live carries — the dominant memory lever for deep stacks
                 (EXPERIMENTS.md §Perf).
    """
    n = jax.tree.leaves(xs)[0].shape[0]
    if mode != "2level" or n < 4:
        return jax.lax.scan(body, carry, xs)
    n1 = _sqrt_divisor(n)
    xs2 = jax.tree.map(lambda a: a.reshape(n1, n // n1, *a.shape[1:]), xs)

    def outer(c, xg):
        c2, _ = jax.lax.scan(body, c, xg)
        return c2, None

    carry, _ = jax.lax.scan(jax.checkpoint(outer), carry, xs2)
    return carry, None


def lm_apply(cfg: ArchConfig, params: Tree, tokens: jax.Array,
             positions: Optional[jax.Array] = None,
             *, remat: bool | str = True) -> tuple[jax.Array, jax.Array]:
    """Training / prefill forward. tokens [B, S] -> (logits [B,S,V], aux)."""
    B, S = tokens.shape
    mode = remat if isinstance(remat, str) else ("block" if remat else "none")
    if positions is None:
        positions = default_positions(cfg, B, S)
    x = embed(cfg, params, tokens)
    aux = jnp.zeros((), jnp.float32)

    runs = (_shared_runs(cfg) if cfg.share_groups
            else segments(cfg.block_kinds))
    reps = cfg.n_layers // cfg.share_groups if cfg.share_groups else 1

    for (kind, _), seg_params in zip(runs, params["blocks"]):
        apply_fn = REGISTRY[kind][1]

        def body(carry, p_l, _apply=apply_fn):
            x, aux = carry
            y, a = _apply(cfg, p_l, x, positions)
            return (y, aux + a), None

        if mode != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.share_groups:
            def group_body(carry, p_g, _body=body):
                for _ in range(reps):
                    carry, _ = _body(carry, p_g)
                return carry, None
            (x, aux), _ = jax.lax.scan(group_body, (x, aux), seg_params)
        else:
            (x, aux), _ = remat_scan(body, (x, aux), seg_params, mode)

    return head(cfg, params, x), aux


def lm_prefill(cfg: ArchConfig, params: Tree, tokens: jax.Array,
               positions: Optional[jax.Array] = None,
               *, cache_len: Optional[int] = None, remat: bool = True,
               last_only: bool = True):
    """Prefill: forward pass + decode-cache emission.

    ``last_only`` computes logits for the final position only — serving
    needs just the next token, and a full [B,S,V] logits tensor at 32k x
    202k vocab is tens of GiB plus 2·T·d·V useless head FLOPs
    (EXPERIMENTS.md §Perf, whisper/llama4 prefill iterations).
    Returns (logits [B,S|1,V], caches); caches hand off to
    ``lm_decode_step`` at ``pos = S``.
    """
    B, S = tokens.shape
    cache_len = cache_len or S
    if positions is None:
        positions = default_positions(cfg, B, S)
    x = embed(cfg, params, tokens)

    runs = (_shared_runs(cfg) if cfg.share_groups
            else segments(cfg.block_kinds))
    reps = cfg.n_layers // cfg.share_groups if cfg.share_groups else 1
    caches = []
    for (kind, _), seg_params in zip(runs, params["blocks"]):
        prefill_fn = REGISTRY[kind][4]

        def body(x, p_l, _pf=prefill_fn):
            y, _, cache = _pf(cfg, p_l, x, positions, cache_len)
            return y, cache

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.share_groups:
            def group_body(x, p_g, _body=body):
                cs = []
                for _ in range(reps):
                    x, c = _body(x, p_g)
                    cs.append(c)
                return x, jax.tree.map(lambda *a: jnp.stack(a), *cs)
            x, cs = jax.lax.scan(group_body, x, seg_params)
            cs = jax.tree.map(
                lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), cs)
        else:
            x, cs = jax.lax.scan(body, x, seg_params)
        caches.append(cs)
    if last_only:
        x = x[:, -1:]
    return head(cfg, params, x), caches


def lm_cache_specs(cfg: ArchConfig, batch: int, seq: int) -> Tree:
    if cfg.share_groups:
        kind = _shared_kind(cfg)
        return [stack_specs(REGISTRY[kind][3](cfg, batch, seq), cfg.n_layers)]
    return [stack_specs(REGISTRY[k][3](cfg, batch, seq), n)
            for k, n in segments(cfg.block_kinds)]


def lm_decode_step(cfg: ArchConfig, params: Tree, token: jax.Array,
                   caches: Tree, pos: jax.Array,
                   positions: Optional[jax.Array] = None):
    """One-token decode. token [B,1] -> (logits [B,1,V], new caches)."""
    B = token.shape[0]
    if positions is None:
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(pos, (3, B, 1))
        else:
            positions = jnp.broadcast_to(pos, (B, 1))
    x = embed(cfg, params, token)

    runs = (_shared_runs(cfg) if cfg.share_groups
            else segments(cfg.block_kinds))
    new_caches = []
    for (kind, _), seg_params, seg_cache in zip(runs, params["blocks"],
                                                caches):
        decode_fn = REGISTRY[kind][2]
        if cfg.share_groups:
            reps = cfg.n_layers // cfg.share_groups

            def body(x, pc, _decode=decode_fn):
                p_g, c_ls = pc           # c_ls: caches for this group [reps,..]
                def inner(x, c_l):
                    y, c = _decode(cfg, p_g, x, c_l, pos, positions)
                    return y, c
                return jax.lax.scan(inner, x, c_ls)

            # regroup stacked caches [L, ...] -> [G, reps, ...]
            c_regrouped = jax.tree.map(
                lambda a: a.reshape(cfg.share_groups, reps, *a.shape[1:]),
                seg_cache)
            x, cs = jax.lax.scan(lambda x, pc: body(x, pc),
                                 x, (seg_params, c_regrouped))
            cs = jax.tree.map(lambda a: a.reshape(cfg.n_layers,
                                                  *a.shape[2:]), cs)
        else:
            def body(x, pc, _decode=decode_fn):
                p_l, c_l = pc
                y, c = _decode(cfg, p_l, x, c_l, pos, positions)
                return y, c
            x, cs = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(cs)

    return head(cfg, params, x), new_caches
