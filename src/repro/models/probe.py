"""FLOP-probe mode.

XLA's cost analysis counts a ``while``-loop body once (verified in
DESIGN.md §6), so the dry-run under-counts FLOPs inside ``lax.scan``.  The
probe lowers a *single layer* with every inner scan collapsed to one chunk
(chunked attention -> one block, chunkwise SSMs -> one chunk): everything is
then in-graph and fully counted, and total FLOPs are reconstructed as
``graph + (L-1) x layer``.  Lowering is symbolic — the giant single-chunk
intermediates are never allocated.

The only loop that cannot be collapsed is the sLSTM time recurrence
(sequential by construction); its contribution is added analytically from
:mod:`repro.models.flops`.
"""
from __future__ import annotations

import contextlib

_FLAGS = {"probe": False}


def probe_enabled() -> bool:
    return _FLAGS["probe"]


@contextlib.contextmanager
def probe_mode():
    _FLAGS["probe"] = True
    try:
        yield
    finally:
        _FLAGS["probe"] = False
