"""First-class stage plans: the single source of stage structure.

A ``StagePlan`` is computed once per ``(cfg, n_stages)`` and threaded
through every layer that previously re-derived stage structure from
``cfg.block_kinds`` index math: ``dist/pipeline.py`` (reference loss +
GSPMD periodicity), ``runtime/`` (stage/span program builders and all
executors), and ``core/`` (trainer routing and rebalance pricing).

Three stage shapes exist:

* **LM** — ``n_layers`` decoder blocks split evenly over ``n_stages``;
  a stage's ``runs`` are the maximal same-kind segments of its slice.
* **shared (ALBERT)** — ``share_groups`` parameter groups split evenly;
  each group re-applies ``reps = n_layers / share_groups`` times.
* **encoder-decoder (whisper)** — stage 0 is the encoder pod
  (``whisper_enc``); stages ``1..n_stages-1`` split the decoder layers
  (``whisper_dec``).  The pod boundary sits exactly at the
  cross-attention hand-off: boundary 0 ships encoder output + tokens,
  interior boundaries ship hidden state + encoder output + tokens.

Pricing lives here too: ``stage_flops`` gives per-kind forward FLOPs
per token for one stage (summing over stages reproduces
``flops.forward_flops_per_token`` exactly), and ``boundary_bytes``
prices each boundary individually — MoE stages with
``moe.expert_sharded`` charge per-token-routed bytes (``top_k`` copies
of each token cross into the expert-sharded stage), and whisper
boundaries price their composite payload trees.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.models.config import ArchConfig

#: kinds whose decode/state carry is recurrent (not recomputable from a
#: KV ring alone) — their stages own the "kv" executor slot so churn
#: recovery goes through the slot ledger like grads/KV.
RECURRENT_KINDS = frozenset({"mlstm", "slstm", "mamba", "hymba"})
MOE_KINDS = frozenset({"moe", "mla_moe"})
WHISPER_ENC = "whisper_enc"
WHISPER_DEC = "whisper_dec"


def segments(pattern: tuple[str, ...]) -> list[tuple[str, int]]:
    """Maximal same-kind runs of a layer pattern (moved-up twin of
    ``models.model.segments``; kept import-light for the planners)."""
    runs: list[tuple[str, int]] = []
    for k in pattern:
        if runs and runs[-1][0] == k:
            runs[-1] = (k, runs[-1][1] + 1)
        else:
            runs.append((k, 1))
    return runs


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """Structure of one pipeline stage.

    ``runs`` are ``(kind, count)`` segments executed in order; each run
    is one ``lax.scan`` in the stage program.  ``reps`` > 1 means every
    run re-applies its parameter group that many times (ALBERT sharing).
    ``aux_slots`` names the keyed executor slots (beyond the core
    grads/opt pair) this stage's executor owns — recurrent-state stages
    declare ``("kv",)`` so serving carry survives churn via the ledger.
    """
    index: int
    kinds: tuple[str, ...]
    runs: tuple[tuple[str, int], ...]
    reps: int = 1
    owns_embed: bool = False
    owns_head: bool = False
    aux_slots: tuple[str, ...] = ()

    @property
    def n_layers(self) -> int:
        return sum(n for _, n in self.runs) * self.reps

    @property
    def structural_key(self):
        """Stages with equal keys compile to structurally identical
        programs and may fuse into one scanned span group."""
        return (self.runs, self.reps, self.owns_embed, self.owns_head)


@dataclasses.dataclass(frozen=True)
class StagePlan:
    cfg: ArchConfig
    n_stages: int
    stages: tuple[StageSpec, ...]

    @property
    def is_encdec(self) -> bool:
        return self.cfg.encoder_layers > 0

    @property
    def periodic(self) -> bool:
        """True iff every stage runs the same block structure — the
        precondition for the GSPMD shifting-buffer pipeline (embed/head
        live outside the stage fns there, so ownership is excluded)."""
        if self.is_encdec:
            return False
        return len({(st.runs, st.reps) for st in self.stages}) == 1

    # ---- pricing -----------------------------------------------------
    def stage_flops(self, s: int, seq_len: int) -> float:
        """Forward FLOPs per (decoder) token for stage ``s``.  Summing
        over all stages reproduces ``flops.forward_flops_per_token``."""
        from repro.models import flops as F
        cfg, spec = self.cfg, self.stages[s]
        ctx = F._ctx_for(cfg, seq_len, causal_avg=True)
        enc_ctx = float(min(seq_len, cfg.encoder_max_len))
        fpt = 0.0
        for kind, n in spec.runs:
            c = enc_ctx if kind == WHISPER_ENC else ctx
            fpt += n * spec.reps * F.per_token_layer_flops(
                cfg, kind, c, enc_ctx=enc_ctx)
        if spec.owns_head:
            fpt += 2.0 * cfg.d_model * cfg.vocab_size
        return fpt

    def stage_costs(self, seq_len: int) -> tuple[float, ...]:
        """Per-stage relative compute rates (fwd FLOPs/token) for the
        rebalance planner."""
        return tuple(self.stage_flops(s, seq_len)
                     for s in range(self.n_stages))

    def boundary_bytes(self, b: int, batch: int, seq_len: int,
                       compression: str = "none") -> float:
        """Bytes crossing boundary ``b`` (between stages b and b+1),
        one direction.  Whisper boundaries price the composite payload
        tree; a boundary *entering* an expert-sharded MoE stage prices
        ``top_k`` routed copies of every token."""
        from repro.models import flops as F
        cfg = self.cfg
        if not 0 <= b < self.n_stages - 1:
            raise ValueError(f"boundary {b} out of range "
                             f"[0, {self.n_stages - 1})")
        if self.is_encdec:
            enc_elems = batch * cfg.encoder_max_len * cfg.d_model
            enc_b = F.wire_nbytes(enc_elems, compression)
            tok_b = 4.0 * batch * seq_len          # int32 tokens ride along
            if b == 0:
                return enc_b + tok_b
            return (F.boundary_bytes(cfg, batch, seq_len, compression)
                    + enc_b + tok_b)
        base = F.boundary_bytes(cfg, batch, seq_len, compression)
        recv = self.stages[b + 1]
        if (cfg.moe is not None and cfg.moe.expert_sharded
                and any(k in MOE_KINDS for k in recv.kinds)):
            base *= float(cfg.moe.top_k)
        return base

    def boundary_costs(self, batch: int, seq_len: int,
                       compression: str = "none") -> tuple[float, ...]:
        return tuple(self.boundary_bytes(b, batch, seq_len, compression)
                     for b in range(self.n_stages - 1))

    def link_boundary_costs(self, batch: int, seq_len: int, *,
                            regions, links,
                            compression: str = "none"
                            ) -> tuple[float, ...]:
        """Per-boundary transfer SECONDS under an inter-region link
        model: boundary ``b``'s bytes priced over the link between the
        regions homing stages ``b`` and ``b+1`` (``links`` is a
        :class:`repro.core.square_cube.LinkTable`, ``regions`` one
        region name per stage).  This is what makes the span planners
        region-aware — a boundary straddling a trans-ocean pair costs
        its real wire time, so ``optimal_assignment`` fuses across slow
        links first."""
        return tuple(links.edge_costs(
            [self.boundary_bytes(b, batch, seq_len, compression)
             for b in range(self.n_stages - 1)], list(regions)))

    # ---- span fusion -------------------------------------------------
    def fusion_groups(self, span=None) -> list[tuple[int, int]]:
        """``(start, count)`` groups of structurally identical
        consecutive stages within ``span`` (default: whole pipeline).
        A fused span scans each group as one jit; groups never cross a
        kind boundary — execution falls back to sequential hand-off
        there."""
        lo, hi = (0, self.n_stages) if span is None else (span[0], span[1])
        groups: list[list] = []
        for s in range(lo, hi):
            key = self.stages[s].structural_key
            if groups and groups[-1][2] == key:
                groups[-1][1] += 1
            else:
                groups.append([s, 1, key])
        return [(s, c) for s, c, _ in groups]


def make_stage_plan(cfg: ArchConfig, n_stages: int) -> StagePlan:
    """Build the plan, validating divisibility up front.

    Raises ``ValueError`` (never silently mis-assigns layers) when the
    stack cannot split: indivisible layer counts, ``share_groups`` with
    mixed ``block_kinds``, or an encoder-decoder at fewer than 2 stages.
    """
    if n_stages < 1:
        raise ValueError(f"{cfg.name}: n_stages must be >= 1, "
                         f"got {n_stages}")
    if cfg.encoder_layers:
        if n_stages < 2:
            raise ValueError(
                f"{cfg.name}: encoder-decoder needs >= 2 stages "
                "(encoder pod + decoder split)")
        dec_stages = n_stages - 1
        if cfg.n_layers % dec_stages:
            raise ValueError(
                f"{cfg.name}: {cfg.n_layers} decoder layers not "
                f"divisible over {dec_stages} decoder stages")
        per = cfg.n_layers // dec_stages
        stages = [StageSpec(
            index=0, kinds=(WHISPER_ENC,) * cfg.encoder_layers,
            runs=((WHISPER_ENC, cfg.encoder_layers),))]
        for s in range(dec_stages):
            stages.append(StageSpec(
                index=s + 1, kinds=(WHISPER_DEC,) * per,
                runs=((WHISPER_DEC, per),),
                owns_embed=(s == 0), owns_head=(s == dec_stages - 1),
                aux_slots=("kv",)))
        return StagePlan(cfg, n_stages, tuple(stages))

    kinds = cfg.block_kinds
    if cfg.share_groups:
        if len(set(kinds)) > 1:
            raise ValueError(
                f"{cfg.name}: share_groups={cfg.share_groups} requires "
                f"uniform block_kinds, got {sorted(set(kinds))} — "
                "parameter sharing across mixed kinds is undefined")
        if cfg.n_layers % cfg.share_groups:
            raise ValueError(
                f"{cfg.name}: n_layers={cfg.n_layers} not divisible by "
                f"share_groups={cfg.share_groups}")
        if cfg.share_groups % n_stages:
            raise ValueError(
                f"{cfg.name}: share_groups={cfg.share_groups} not "
                f"divisible over {n_stages} stages")
        per_groups = cfg.share_groups // n_stages
        reps = cfg.n_layers // cfg.share_groups
        per_stage = [((kinds[0], per_groups),)] * n_stages
        rep_list = [reps] * n_stages
    else:
        if cfg.n_layers % n_stages:
            raise ValueError(
                f"{cfg.name}: n_layers={cfg.n_layers} not divisible by "
                f"n_stages={n_stages}")
        per = cfg.n_layers // n_stages
        per_stage = [tuple(segments(kinds[s * per:(s + 1) * per]))
                     for s in range(n_stages)]
        rep_list = [1] * n_stages

    stages = []
    for s, runs in enumerate(per_stage):
        stage_kinds = tuple(k for k, n in runs for _ in range(n))
        aux = (("kv",) if any(k in RECURRENT_KINDS for k in stage_kinds)
               else ())
        stages.append(StageSpec(
            index=s, kinds=stage_kinds, runs=runs, reps=rep_list[s],
            owns_embed=(s == 0), owns_head=(s == n_stages - 1),
            aux_slots=aux))
    return StagePlan(cfg, n_stages, tuple(stages))


@functools.lru_cache(maxsize=None)
def get_stage_plan(cfg: ArchConfig, n_stages: int) -> StagePlan:
    """Process-wide cached plan — every layer shares one instance per
    ``(cfg, n_stages)`` so plan identity can key compile caches."""
    return make_stage_plan(cfg, n_stages)
