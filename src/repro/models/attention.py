"""Attention: chunked (flash-style) pure-jnp implementation + decode paths.

The chunked implementation is the memory-safe reference used for CPU dry-runs
and as the oracle for the Pallas kernel in ``repro/kernels/flash_attention``.
Online-softmax over key chunks keeps the working set at
``O(chunk_q * chunk_k)`` instead of ``O(S^2)``.

Supports: GQA/MQA (kv-head broadcast), causal & bidirectional, sliding
window, logit soft-capping, distinct qk/v head dims (for MLA), query offset
(for chunked prefill / decode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.probe import probe_enabled

NEG_INF = -1e30


def _mask_bias(qpos: jax.Array, kpos: jax.Array, *, causal: bool,
               window: int, kv_len: Optional[jax.Array]) -> jax.Array:
    """Additive bias [Sq, Sk] from position comparisons."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        ok &= kpos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(
    q: jax.Array,            # [B, Sq, H, Dq]
    k: jax.Array,            # [B, Sk, KV, Dq]
    v: jax.Array,            # [B, Sk, KV, Dv]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    chunk_q: int = 512,
    chunk_k: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Flash-style attention, returns [B, Sq, H, Dv]."""
    B, Sq, H, Dq = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = scale if scale is not None else Dq ** -0.5

    if probe_enabled():           # collapse chunking for FLOP probing
        chunk_q, chunk_k = Sq, Sk
    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    # pad to multiples
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    pq, pk = nq * cq - Sq, nk * ck - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    # [nq, B, cq, KV, G, Dq]
    qc = q.reshape(B, nq, cq, KV, G, Dq).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, ck, KV, Dq).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, KV, Dv).transpose(1, 0, 2, 3, 4)

    kv_valid = Sk  # mask out key padding

    def q_chunk(qi_q):
        qi, qblk = qi_q                       # qblk [B, cq, KV, G, Dq]
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def kv_chunk(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            bias = _mask_bias(qpos, kpos, causal=causal, window=window,
                              kv_len=jnp.asarray(kv_valid))
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_chunk, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)     # [B, cq, KV, G, Dv]

    outs = jax.lax.map(q_chunk, (jnp.arange(nq), qc))  # [nq, B, cq, KV, G, Dv]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * cq, H, Dv)
    return out[:, :Sq].astype(v.dtype)


def decode_attention(
    q: jax.Array,            # [B, 1, H, Dq]
    k_cache: jax.Array,      # [B, S, KV, Dq]
    v_cache: jax.Array,      # [B, S, KV, Dv]
    pos: jax.Array,          # [] current position (number of valid cache slots)
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention over a KV cache. Returns [B, 1, H, Dv]."""
    B, _, H, Dq = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else Dq ** -0.5
    qg = q.reshape(B, KV, G, Dq)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(S)
    ok = kpos[None, :] <= pos  # attend to cache + current token
    if window > 0:
        ok &= kpos[None, :] > (pos - window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(v_cache.dtype)


def naive_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, scale=None):
    """O(S^2)-memory oracle used in tests only."""
    B, Sq, H, Dq = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else Dq ** -0.5
    qg = q.reshape(B, Sq, KV, G, Dq)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_offset + jnp.arange(Sq)
    bias = _mask_bias(qpos, jnp.arange(Sk), causal=causal, window=window,
                      kv_len=None)
    s = s + bias[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, v.shape[-1]).astype(v.dtype)
