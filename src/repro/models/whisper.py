"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a STUB per the shape contract: ``input_specs``
supplies precomputed frame embeddings ``[B, S_enc, d]``.  Sinusoidal
positions are added to both streams (the learned-positions detail of the
original is immaterial to the systems study).  Decode caches the decoder
self-attention KV ring plus the *precomputed* cross-attention K/V.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamSpec
from repro.models import layers as L
from repro.models import attention as attn_lib
from repro.models import model as model_lib

Tree = Any


def sinusoid(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


def _xattn_specs(cfg: ArchConfig) -> Tree:
    d, H, hd, pd = cfg.d_model, cfg.n_heads, cfg.hd, cfg.param_jdtype
    return {
        "wq": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), pd, axes=("heads", "head_dim", "embed")),
    }


def enc_block_specs(cfg: ArchConfig) -> Tree:
    return {"ln1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.ffn_specs(cfg)}


def dec_block_specs(cfg: ArchConfig) -> Tree:
    return {"ln1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
            "lnx": L.norm_specs(cfg), "xattn": _xattn_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.ffn_specs(cfg)}


def whisper_specs(cfg: ArchConfig) -> Tree:
    from repro.models.model import stack_specs
    d, V, pd = cfg.d_model, cfg.vocab_size, cfg.param_jdtype
    return {
        "embed": ParamSpec((V, d), pd, "embed", ("vocab", "embed")),
        "enc_blocks": stack_specs(enc_block_specs(cfg), cfg.encoder_layers),
        "enc_norm": L.norm_specs(cfg),
        "dec_blocks": stack_specs(dec_block_specs(cfg), cfg.n_layers),
        "final_norm": L.norm_specs(cfg),
        "head": ParamSpec((d, V), pd, "normal", ("embed", "vocab")),
    }


def _cross_kv(cfg, p, enc_out):
    cd = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(cd))
    return k, v


def _cross_attend(cfg, p, x, k, v):
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    from repro.models import flash as flash_lib
    out = flash_lib.flash_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


def encode(cfg: ArchConfig, params: Tree, audio_embed: jax.Array,
           remat: bool = True) -> jax.Array:
    """audio_embed [B, S_enc, d] (frontend stub output)."""
    B, S, d = audio_embed.shape
    x = audio_embed + sinusoid(S, d, audio_embed.dtype)
    positions = jnp.arange(S)

    def body(x, p_l):
        h = L.apply_norm(cfg, p_l["ln1"], x)
        x = x + L.apply_attn(cfg, p_l["attn"], h, positions, causal=False)
        x = x + L.apply_ffn(cfg, p_l["mlp"], L.apply_norm(cfg, p_l["ln2"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def embed_tokens(cfg: ArchConfig, embed: jax.Array,
                 tokens: jax.Array) -> jax.Array:
    """Decoder token embedding + sinusoidal positions."""
    S = tokens.shape[1]
    x = embed[tokens].astype(cfg.compute_jdtype)
    return x + sinusoid(S, cfg.d_model, x.dtype)


def dec_scan(cfg: ArchConfig, dec_blocks: Tree, x: jax.Array,
             enc_out: jax.Array, positions: jax.Array,
             remat: bool = True) -> jax.Array:
    """Scan a stacked slice of decoder blocks (self-attn + cross-attn
    into ``enc_out`` + FFN).  The whole-model ``decode_train`` scans all
    ``n_layers``; a pipeline stage scans only its own slice."""
    def body(x, p_l):
        h = L.apply_norm(cfg, p_l["ln1"], x)
        x = x + L.apply_attn(cfg, p_l["attn"], h, positions, causal=True)
        k, v = _cross_kv(cfg, p_l["xattn"], enc_out)
        x = x + _cross_attend(cfg, p_l["xattn"],
                              L.apply_norm(cfg, p_l["lnx"], x), k, v)
        x = x + L.apply_ffn(cfg, p_l["mlp"], L.apply_norm(cfg, p_l["ln2"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, dec_blocks)
    return x


def decode_train(cfg: ArchConfig, params: Tree, tokens: jax.Array,
                 enc_out: jax.Array, remat: bool = True) -> jax.Array:
    B, S = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    x = dec_scan(cfg, params["dec_blocks"], x, enc_out, jnp.arange(S),
                 remat)
    # shared head: final norm + vocab projection + the constrain no-op
    # path (identity off-mesh, so single-device tests need no mesh)
    return model_lib.head(cfg, params, x)


def whisper_apply(cfg: ArchConfig, params: Tree, batch: Tree,
                  remat: bool = True):
    enc_out = encode(cfg, params, batch["audio_embed"], remat)
    logits = decode_train(cfg, params, batch["tokens"], enc_out, remat)
    return logits, jnp.zeros((), jnp.float32)


def whisper_cache_specs(cfg: ArchConfig, batch: int, seq: int) -> Tree:
    from repro.models.model import stack_specs
    H, hd, dt = cfg.n_heads, cfg.hd, cfg.compute_jdtype
    enc = cfg.encoder_max_len
    self_kv = stack_specs(L.attn_cache_specs(cfg, batch, seq), cfg.n_layers)
    cross = stack_specs(
        {"k": ParamSpec((batch, enc, H, hd), dt, "zeros",
                        ("batch", "kv_seq", "heads", "head_dim")),
         "v": ParamSpec((batch, enc, H, hd), dt, "zeros",
                        ("batch", "kv_seq", "heads", "head_dim"))},
        cfg.n_layers)
    return {"self": self_kv, "cross": cross}


def prefill_cross_cache(cfg: ArchConfig, params: Tree, enc_out: jax.Array):
    """Precompute per-layer cross K/V from encoder output."""
    def body(_, p_l):
        k, v = _cross_kv(cfg, p_l["xattn"], enc_out)
        return None, {"k": k, "v": v}
    _, kv = jax.lax.scan(body, None, params["dec_blocks"])
    return kv


def whisper_prefill(cfg: ArchConfig, params: Tree, batch: Tree,
                    cache_len: int | None = None, remat: bool = True,
                    last_only: bool = True):
    """Encoder pass + decoder prefill; returns (logits, caches)."""
    enc_out = encode(cfg, params, batch["audio_embed"], remat)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    d = cfg.d_model
    x = params["embed"][tokens].astype(cfg.compute_jdtype)
    x = x + sinusoid(S, d, x.dtype)
    positions = jnp.arange(S)

    def body(x, p_l):
        h = L.apply_norm(cfg, p_l["ln1"], x)
        y, (k, v) = L.apply_attn(cfg, p_l["attn"], h, positions, causal=True,
                                 return_kv=True)
        x = x + y
        ck, cv = _cross_kv(cfg, p_l["xattn"], enc_out)
        x = x + _cross_attend(cfg, p_l["xattn"],
                              L.apply_norm(cfg, p_l["lnx"], x), ck, cv)
        x = x + L.apply_ffn(cfg, p_l["mlp"], L.apply_norm(cfg, p_l["ln2"], x))
        cache = {"self": {"k": L.ring_place(k, cache_len),
                          "v": L.ring_place(v, cache_len)},
                 "cross": {"k": ck, "v": cv}}
        return x, cache

    if remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    if last_only:
        x = x[:, -1:]          # norm is per-position: commutes with the slice
    logits = model_lib.head(cfg, params, x)
    return logits, {"self": caches["self"], "cross": caches["cross"]}


def whisper_decode_step(cfg: ArchConfig, params: Tree, token: jax.Array,
                        caches: Tree, pos: jax.Array):
    """One decoder token. caches = {'self': .., 'cross': ..}."""
    B = token.shape[0]
    d = cfg.d_model
    x = params["embed"][token].astype(cfg.compute_jdtype)
    pe = sinusoid(cfg.max_seq_len if cfg.max_seq_len < (1 << 16)
                  else (1 << 16), d, x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pe, jnp.minimum(pos, pe.shape[0] - 1),
                                         1, axis=0)[None]
    positions = jnp.broadcast_to(pos, (B, 1))

    def body(x, pc):
        p_l, c_self, c_cross = pc
        h = L.apply_norm(cfg, p_l["ln1"], x)
        y, c_self = L.apply_attn_decode(cfg, p_l["attn"], h, c_self, pos,
                                        positions)
        x = x + y
        cd = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", L.apply_norm(cfg, p_l["lnx"], x),
                       p_l["xattn"]["wq"].astype(cd))
        out = attn_lib.decode_attention(
            q, c_cross["k"], c_cross["v"],
            jnp.asarray(c_cross["k"].shape[1] - 1))
        x = x + jnp.einsum("bshk,hkd->bsd", out,
                           p_l["xattn"]["wo"].astype(cd))
        x = x + L.apply_ffn(cfg, p_l["mlp"], L.apply_norm(cfg, p_l["ln2"], x))
        return x, c_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], caches["self"], caches["cross"]))
    logits = model_lib.head(cfg, params, x)
    return logits, {"self": new_self, "cross": caches["cross"]}
