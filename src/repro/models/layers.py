"""Norms, FFNs, dense attention projections, and MoE with scatter dispatch."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamSpec
from repro.models import attention as attn_lib
from repro.models import flash as flash_lib
from repro.models import rope as rope_lib

Tree = Any


# ---------------------------------------------------------------- norms
def norm_specs(cfg: ArchConfig, d: Optional[int] = None) -> Tree:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), cfg.param_jdtype, "ones", ("embed",)),
                "bias": ParamSpec((d,), cfg.param_jdtype, "zeros", ("embed",))}
    return {"scale": ParamSpec((d,), cfg.param_jdtype, "ones", ("embed",))}


def apply_norm(cfg: ArchConfig, p: Tree, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.norm == "layernorm":
        x = x.astype(jnp.float32)
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + 1e-6)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(dt)
    if cfg.kernels == "pallas":
        # fused Pallas forward (one pass instead of the unfused f32
        # round trip) + analytic backward; layernorm stays jnp
        from repro.kernels.rmsnorm.ops import rmsnorm_train
        return rmsnorm_train(x, p["scale"])
    x = x.astype(jnp.float32)
    var = (x ** 2).mean(-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + 1e-6)
    # gemma-style (1 + scale) keeps init at identity; standard rmsnorm when
    # scale is initialised to ones.  We use plain scale*x with ones-init.
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- FFN
def ffn_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Tree:
    d, f, pd = cfg.d_model, d_ff or cfg.d_ff, cfg.param_jdtype
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d, f), pd, axes=("embed", "mlp")),
            "wi_up": ParamSpec((d, f), pd, axes=("embed", "mlp")),
            "wo": ParamSpec((f, d), pd, axes=("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), pd, axes=("embed", "mlp")),
        "wo": ParamSpec((f, d), pd, axes=("mlp", "embed")),
    }


def apply_ffn(cfg: ArchConfig, p: Tree, x: jax.Array) -> jax.Array:
    cd = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        g = x @ p["wi_gate"].astype(cd)
        u = x @ p["wi_up"].astype(cd)
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        return (act * u) @ p["wo"].astype(cd)
    h = jax.nn.gelu(x @ p["wi"].astype(cd))
    return h @ p["wo"].astype(cd)


# ---------------------------------------------------------------- attention
def attn_specs(cfg: ArchConfig) -> Tree:
    d, H, KV, hd, pd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                        cfg.param_jdtype)
    s = {
        "wq": ParamSpec((d, H, hd), pd, axes=("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, KV, hd), pd, axes=("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, KV, hd), pd, axes=("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), pd, axes=("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, hd), pd, "zeros", ("heads", "head_dim"))
        s["bk"] = ParamSpec((KV, hd), pd, "zeros", ("kv_heads", "head_dim"))
        s["bv"] = ParamSpec((KV, hd), pd, "zeros", ("kv_heads", "head_dim"))
    return s


def _project_qkv(cfg: ArchConfig, p: Tree, x: jax.Array):
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _pos_embed(cfg: ArchConfig, q, k, positions):
    if cfg.rope == "rope":
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = rope_lib.apply_mrope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_mrope(k, positions, cfg.rope_theta)
    return q, k


def apply_attn(cfg: ArchConfig, p: Tree, x: jax.Array, positions: jax.Array,
               *, causal: Optional[bool] = None, window: Optional[int] = None,
               chunk_q: int = 512, chunk_k: int = 1024,
               return_kv: bool = False):
    """Full-sequence (training / prefill) attention. x [B, S, d]."""
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _pos_embed(cfg, q, k, positions)
    out = flash_lib.flash_attention(
        q, k, v,
        causal=cfg.causal if causal is None else causal,
        window=cfg.sliding_window if window is None else window,
        softcap=cfg.attn_logit_softcap,
        chunk_q=chunk_q, chunk_k=chunk_k, impl=cfg.kernels)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


def ring_place(x_seq: jax.Array, cache_len: int) -> jax.Array:
    """Place the last ``cache_len`` sequence entries of ``x_seq`` [B,S,...]
    into ring-buffer slots ``t % cache_len`` (prefill -> decode handoff)."""
    S = x_seq.shape[1]
    W = min(cache_len, S)
    tail = x_seq[:, S - W:]
    slots = jnp.arange(S - W, S) % cache_len
    out = jnp.zeros((x_seq.shape[0], cache_len) + x_seq.shape[2:],
                    x_seq.dtype)
    return out.at[:, slots].set(tail)


def apply_attn_decode(cfg: ArchConfig, p: Tree, x: jax.Array, cache: Tree,
                      pos: jax.Array, positions: jax.Array,
                      *, window: Optional[int] = None):
    """One-token decode. x [B, 1, d]; cache {'k','v'} [B, S_c, KV, hd].

    Sliding-window archs use a **ring buffer** cache of exactly ``window``
    slots: entry ``pos`` lands in slot ``pos % window``, overwriting the
    token that just fell out of the window.  RoPE is applied at absolute
    positions before insertion, and softmax is permutation-invariant over
    keys, so scores are unaffected by the wrap.  This is what keeps the
    ``long_500k`` KV footprint at O(window) instead of O(500k) (DESIGN §5).
    """
    window = cfg.sliding_window if window is None else window
    S_c = cache["k"].shape[1]
    ring = window > 0 and S_c == window
    slot = jnp.remainder(pos, S_c) if ring else pos
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _pos_embed(cfg, q, k, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    out = attn_lib.decode_attention(
        q, k_cache, v_cache, pos,
        window=0 if ring else window,   # ring geometry enforces the window
        softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}


def attn_cache_specs(cfg: ArchConfig, batch: int, seq: int) -> Tree:
    hd = cfg.hd
    dt = cfg.compute_jdtype
    return {
        "k": ParamSpec((batch, seq, cfg.n_kv_heads, hd), dt, "zeros",
                       ("batch", "kv_seq", "kv_heads", "head_dim")),
        "v": ParamSpec((batch, seq, cfg.n_kv_heads, hd), dt, "zeros",
                       ("batch", "kv_seq", "kv_heads", "head_dim")),
    }


# ---------------------------------------------------------------- MoE
def moe_specs(cfg: ArchConfig) -> Tree:
    m = cfg.moe
    d, f, pd = cfg.d_model, m.d_ff_expert, cfg.param_jdtype
    s = {
        "router": ParamSpec((d, m.num_experts), jnp.float32,
                            axes=("embed", "experts")),
        "wi_gate": ParamSpec((m.num_experts, d, f), pd,
                             axes=("experts", "embed", "expert_mlp")),
        "wi_up": ParamSpec((m.num_experts, d, f), pd,
                           axes=("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((m.num_experts, f, d), pd,
                        axes=("experts", "expert_mlp", "embed")),
    }
    if m.num_shared:
        s["shared"] = ffn_specs(cfg, d_ff=m.num_shared * m.d_ff_expert)
    return s


def apply_moe(cfg: ArchConfig, p: Tree, x: jax.Array):
    """Capacity-bounded top-k MoE with scatter dispatch / gather combine.

    Dispatch is expressed as scatter-add into per-expert buffers rather than
    the GShard one-hot einsum: the einsum form costs ``O(T^2 * k * d)`` FLOPs
    (quadratic in tokens) which would dominate every roofline; scatter/gather
    moves the same bytes at zero matmul FLOPs. x: [B, S, d].
    Returns (y, aux_loss).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, k = m.num_experts, m.top_k

    logits = (xt.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(probs, k)                    # [T, k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): E * sum_e f_e * P_e
    dispatch_frac = jnp.zeros(E).at[sel.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(dispatch_frac * probs.mean(0))

    C = max(1, int(m.capacity_factor * T * k / E))
    e_flat = sel.reshape(T * k)                               # [T*k]
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # [T*k]
    keep = (pos_in_e >= 0) & (pos_in_e < C)
    slot = jnp.clip(pos_in_e, 0, C - 1)

    xk = jnp.repeat(xt, k, axis=0)                            # [T*k, d]
    buf = jnp.zeros((E, C, d), xt.dtype).at[e_flat, slot].add(
        xk * keep[:, None].astype(xt.dtype))

    cd = xt.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(cd))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cd))    # [E, C, d]

    gathered = eo[e_flat, slot]                               # [T*k, d]
    gathered = gathered * (weights.reshape(T * k, 1).astype(cd)
                           * keep[:, None].astype(cd))
    y = gathered.reshape(T, k, d).sum(1)

    if m.num_shared:
        y = y + apply_ffn(cfg, p["shared"], xt)
    return y.reshape(B, S, d), aux * m.aux_loss_coef
