"""Block registry: one (specs, apply, decode, cache_specs) tuple per kind.

Kinds:
  attn      — self-attention + dense FFN            (dense LMs, VLM backbone)
  moe       — self-attention + MoE FFN              (llama4-scout)
  mla       — multi-head latent attention + FFN     (deepseek dense layer)
  mla_moe   — MLA + MoE FFN                         (deepseek-v2)
  mlstm     — xLSTM matrix-memory block
  slstm     — xLSTM scalar-memory block
  hymba     — parallel attention ∥ mamba heads + FFN (hymba-1.5b)
  mamba     — pure selective-SSM block
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models import mla as mla_lib
from repro.models import ssm as ssm_lib

Tree = Any


def _residual_ffn(cfg, p, x):
    return x + L.apply_ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))


# ---------------------------------------------------------------- attn
def attn_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.ffn_specs(cfg)}


def attn_apply(cfg, p, x, positions):
    x = x + L.apply_attn(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                         positions)
    return _residual_ffn(cfg, p, x), 0.0


def attn_decode(cfg, p, x, cache, pos, positions):
    h, cache = L.apply_attn_decode(
        cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x), cache, pos, positions)
    x = x + h
    return _residual_ffn(cfg, p, x), cache


def attn_cache(cfg, batch, seq):
    # ring buffer for sliding-window archs: never cache beyond the window
    if cfg.sliding_window:
        seq = min(seq, cfg.sliding_window)
    return L.attn_cache_specs(cfg, batch, seq)


# ---------------------------------------------------------------- moe
def moe_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
            "ln2": L.norm_specs(cfg), "moe": L.moe_specs(cfg)}


def moe_apply(cfg, p, x, positions):
    x = x + L.apply_attn(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                         positions)
    y, aux = L.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, aux


def moe_decode(cfg, p, x, cache, pos, positions):
    h, cache = L.apply_attn_decode(
        cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x), cache, pos, positions)
    x = x + h
    y, _ = L.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, cache


# ---------------------------------------------------------------- mla
def mla_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "mla": mla_lib.mla_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.ffn_specs(cfg)}


def mla_apply(cfg, p, x, positions):
    x = x + mla_lib.apply_mla(cfg, p["mla"], L.apply_norm(cfg, p["ln1"], x),
                              positions)
    return _residual_ffn(cfg, p, x), 0.0


def mla_decode(cfg, p, x, cache, pos, positions):
    h, cache = mla_lib.apply_mla_decode(
        cfg, p["mla"], L.apply_norm(cfg, p["ln1"], x), cache, pos, positions)
    x = x + h
    return _residual_ffn(cfg, p, x), cache


def mla_cache(cfg, batch, seq):
    return mla_lib.mla_cache_specs(cfg, batch, seq)


# ---------------------------------------------------------------- mla_moe
def mla_moe_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "mla": mla_lib.mla_specs(cfg),
            "ln2": L.norm_specs(cfg), "moe": L.moe_specs(cfg)}


def mla_moe_apply(cfg, p, x, positions):
    x = x + mla_lib.apply_mla(cfg, p["mla"], L.apply_norm(cfg, p["ln1"], x),
                              positions)
    y, aux = L.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, aux


def mla_moe_decode(cfg, p, x, cache, pos, positions):
    h, cache = mla_lib.apply_mla_decode(
        cfg, p["mla"], L.apply_norm(cfg, p["ln1"], x), cache, pos, positions)
    x = x + h
    y, _ = L.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, cache


# ---------------------------------------------------------------- xLSTM
def mlstm_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "cell": ssm_lib.mlstm_specs(cfg)}


def mlstm_apply(cfg, p, x, positions):
    del positions
    return x + ssm_lib.apply_mlstm(cfg, p["cell"],
                                   L.apply_norm(cfg, p["ln1"], x)), 0.0


def mlstm_decode(cfg, p, x, cache, pos, positions):
    del pos, positions
    y, cache = ssm_lib.apply_mlstm_decode(
        cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x), cache)
    return x + y, cache


def mlstm_cache(cfg, batch, seq):
    del seq
    return ssm_lib.mlstm_cache_specs(cfg, batch)


def slstm_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "cell": ssm_lib.slstm_specs(cfg)}


def slstm_apply(cfg, p, x, positions):
    del positions
    return x + ssm_lib.apply_slstm(cfg, p["cell"],
                                   L.apply_norm(cfg, p["ln1"], x)), 0.0


def slstm_decode(cfg, p, x, cache, pos, positions):
    del pos, positions
    y, cache = ssm_lib.apply_slstm_decode(
        cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x), cache)
    return x + y, cache


def slstm_cache(cfg, batch, seq):
    del seq
    return ssm_lib.slstm_cache_specs(cfg, batch)


# ---------------------------------------------------------------- hymba
def hymba_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
            "mamba": ssm_lib.mamba_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.ffn_specs(cfg)}


def hymba_apply(cfg, p, x, positions):
    h = L.apply_norm(cfg, p["ln1"], x)
    ya = L.apply_attn(cfg, p["attn"], h, positions)
    ys = ssm_lib.apply_mamba(cfg, p["mamba"], h)
    x = x + 0.5 * (ya + ys)
    return _residual_ffn(cfg, p, x), 0.0


def hymba_decode(cfg, p, x, cache, pos, positions):
    h = L.apply_norm(cfg, p["ln1"], x)
    ya, kv = L.apply_attn_decode(cfg, p["attn"], h, cache["kv"], pos,
                                 positions)
    ys, st = ssm_lib.apply_mamba_decode(cfg, p["mamba"], h, cache["ssm"])
    x = x + 0.5 * (ya + ys)
    return _residual_ffn(cfg, p, x), {"kv": kv, "ssm": st}


def hymba_cache(cfg, batch, seq):
    if cfg.sliding_window:
        seq = min(seq, cfg.sliding_window)
    return {"kv": L.attn_cache_specs(cfg, batch, seq),
            "ssm": ssm_lib.mamba_cache_specs(cfg, batch)}


# ---------------------------------------------------------------- mamba
def mamba_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "cell": ssm_lib.mamba_specs(cfg)}


def mamba_apply(cfg, p, x, positions):
    del positions
    return x + ssm_lib.apply_mamba(cfg, p["cell"],
                                   L.apply_norm(cfg, p["ln1"], x)), 0.0


def mamba_decode(cfg, p, x, cache, pos, positions):
    del pos, positions
    y, cache = ssm_lib.apply_mamba_decode(
        cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x), cache)
    return x + y, cache


def mamba_cache(cfg, batch, seq):
    del seq
    return ssm_lib.mamba_cache_specs(cfg, batch)


# ---------------------------------------------------------------- prefill
# Each prefill runs the full-sequence path AND emits the decode cache so a
# serving stack can hand off prefill -> decode (SWA caches land in ring
# layout via L.ring_place).
def _pad_kv(cfg, k, v, cache_len):
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)
    return {"k": L.ring_place(k.astype(cfg.compute_jdtype), cache_len),
            "v": L.ring_place(v.astype(cfg.compute_jdtype), cache_len)}


def _attn_kv_prefill(cfg, p, x, positions, cache_len):
    y, (k, v) = L.apply_attn(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                             positions, return_kv=True)
    return y, _pad_kv(cfg, k, v, cache_len)


def attn_prefill(cfg, p, x, positions, cache_len):
    y, cache = _attn_kv_prefill(cfg, p, x, positions, cache_len)
    x = x + y
    return _residual_ffn(cfg, p, x), 0.0, cache


def moe_prefill(cfg, p, x, positions, cache_len):
    y, cache = _attn_kv_prefill(cfg, p, x, positions, cache_len)
    x = x + y
    y, aux = L.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, aux, cache


def _mla_prefill_inner(cfg, p, x, positions, cache_len):
    y, (c_kv, k_rope) = mla_lib.apply_mla(
        cfg, p["mla"], L.apply_norm(cfg, p["ln1"], x), positions,
        return_cache=True)
    cache = {"c_kv": L.ring_place(c_kv.astype(cfg.compute_jdtype), cache_len),
             "k_rope": L.ring_place(k_rope.astype(cfg.compute_jdtype),
                                    cache_len)}
    return y, cache


def mla_prefill(cfg, p, x, positions, cache_len):
    y, cache = _mla_prefill_inner(cfg, p, x, positions, cache_len)
    x = x + y
    return _residual_ffn(cfg, p, x), 0.0, cache


def mla_moe_prefill(cfg, p, x, positions, cache_len):
    y, cache = _mla_prefill_inner(cfg, p, x, positions, cache_len)
    x = x + y
    y, aux = L.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, aux, cache


def mlstm_prefill(cfg, p, x, positions, cache_len):
    del positions, cache_len
    y, st = ssm_lib.apply_mlstm(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                                return_state=True)
    return x + y, 0.0, st


def slstm_prefill(cfg, p, x, positions, cache_len):
    del positions, cache_len
    y, st = ssm_lib.apply_slstm(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                                return_state=True)
    return x + y, 0.0, st


def hymba_prefill(cfg, p, x, positions, cache_len):
    h = L.apply_norm(cfg, p["ln1"], x)
    ya, (k, v) = L.apply_attn(cfg, p["attn"], h, positions, return_kv=True)
    ys, st = ssm_lib.apply_mamba(cfg, p["mamba"], h, return_state=True)
    x = x + 0.5 * (ya + ys)
    cache = {"kv": _pad_kv(cfg, k, v, cache_len), "ssm": st}
    return _residual_ffn(cfg, p, x), 0.0, cache


def mamba_prefill(cfg, p, x, positions, cache_len):
    del positions, cache_len
    y, st = ssm_lib.apply_mamba(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                                return_state=True)
    return x + y, 0.0, st


REGISTRY = {
    "attn": (attn_specs, attn_apply, attn_decode, attn_cache, attn_prefill),
    "moe": (moe_specs, moe_apply, moe_decode, attn_cache, moe_prefill),
    "mla": (mla_specs, mla_apply, mla_decode, mla_cache, mla_prefill),
    "mla_moe": (mla_moe_specs, mla_moe_apply, mla_moe_decode, mla_cache,
                mla_moe_prefill),
    "mlstm": (mlstm_specs, mlstm_apply, mlstm_decode, mlstm_cache,
              mlstm_prefill),
    "slstm": (slstm_specs, slstm_apply, slstm_decode, slstm_cache,
              slstm_prefill),
    "hymba": (hymba_specs, hymba_apply, hymba_decode, hymba_cache,
              hymba_prefill),
    "mamba": (mamba_specs, mamba_apply, mamba_decode, mamba_cache,
              mamba_prefill),
}
