"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Training path: expand the compressed latent into per-head K/V and run
flash-style attention with distinct qk/v head dims.

Decode path: **absorbed** form — W_uk is folded into the query and W_uv into
the output so attention runs directly against the cached latent
``c_kv [B, S, r]`` + shared rope key ``k_rope [B, S, dr]``.  The cache is
``r + dr`` floats/token instead of ``2 * H * hd`` (576 vs 32768 for V2) —
this is the production memory win of MLA.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamSpec
from repro.models import attention as attn_lib
from repro.models import flash as flash_lib
from repro.models import rope as rope_lib

Tree = Any


def mla_specs(cfg: ArchConfig) -> Tree:
    a = cfg.mla
    d, H, pd = cfg.d_model, cfg.n_heads, cfg.param_jdtype
    qd = a.qk_nope_dim + a.qk_rope_dim
    s: Tree = {
        "w_dkv": ParamSpec((d, a.kv_lora_rank), pd, axes=("embed", "kv_lora")),
        "w_krope": ParamSpec((d, a.qk_rope_dim), pd, axes=("embed", "head_dim")),
        "w_uk": ParamSpec((a.kv_lora_rank, H, a.qk_nope_dim), pd,
                          axes=("kv_lora", "heads", "head_dim")),
        "w_uv": ParamSpec((a.kv_lora_rank, H, a.v_head_dim), pd,
                          axes=("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((H, a.v_head_dim, d), pd,
                        axes=("heads", "head_dim", "embed")),
    }
    if a.q_lora_rank:
        s["w_dq"] = ParamSpec((d, a.q_lora_rank), pd, axes=("embed", "q_lora"))
        s["w_uq"] = ParamSpec((a.q_lora_rank, H, qd), pd,
                              axes=("q_lora", "heads", "head_dim"))
    else:
        s["wq"] = ParamSpec((d, H, qd), pd, axes=("embed", "heads", "head_dim"))
    return s


def _queries(cfg: ArchConfig, p: Tree, x: jax.Array):
    a, cd = cfg.mla, x.dtype
    if a.q_lora_rank:
        cq = x @ p["w_dq"].astype(cd)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    return q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]   # nope, rope


def apply_mla(cfg: ArchConfig, p: Tree, x: jax.Array, positions: jax.Array,
              *, chunk_q: int = 512, chunk_k: int = 1024,
              return_cache: bool = False):
    """Training / prefill. x [B, S, d]."""
    a, cd = cfg.mla, x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(cfg, p, x)
    q_rope = rope_lib.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = x @ p["w_dkv"].astype(cd)                         # [B, S, r]
    k_rope = rope_lib.apply_rope(
        (x @ p["w_krope"].astype(cd))[:, :, None, :], positions,
        cfg.rope_theta)                                      # [B, S, 1, dr]
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(cd))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(cd))

    # concat rope dims so a single flash pass computes both inner products
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, a.qk_rope_dim))], axis=-1)
    scale = (a.qk_nope_dim + a.qk_rope_dim) ** -0.5
    out = flash_lib.flash_attention(
        q, k, v, causal=cfg.causal, softcap=cfg.attn_logit_softcap,
        chunk_q=chunk_q, chunk_k=chunk_k, scale=scale)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    if return_cache:
        return y, (c_kv, k_rope[:, :, 0, :])
    return y


def mla_cache_specs(cfg: ArchConfig, batch: int, seq: int) -> Tree:
    a, dt = cfg.mla, cfg.compute_jdtype
    return {
        "c_kv": ParamSpec((batch, seq, a.kv_lora_rank), dt, "zeros",
                          ("batch", "kv_seq", "kv_lora")),
        "k_rope": ParamSpec((batch, seq, a.qk_rope_dim), dt, "zeros",
                            ("batch", "kv_seq", "head_dim")),
    }


def apply_mla_decode(cfg: ArchConfig, p: Tree, x: jax.Array, cache: Tree,
                     pos: jax.Array, positions: jax.Array):
    """Absorbed decode. x [B, 1, d]. cache: c_kv [B,S,r], k_rope [B,S,dr]."""
    a, cd = cfg.mla, x.dtype
    B = x.shape[0]
    q_nope, q_rope = _queries(cfg, p, x)                     # [B,1,H,*]
    q_rope = rope_lib.apply_rope(q_rope, positions, cfg.rope_theta)

    c_new = x @ p["w_dkv"].astype(cd)                        # [B, 1, r]
    kr_new = rope_lib.apply_rope(
        (x @ p["w_krope"].astype(cd))[:, :, None, :], positions,
        cfg.rope_theta)[:, :, 0, :]                          # [B, 1, dr]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)

    # absorb W_uk into q: q_abs [B, H, r]
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"].astype(cd))
    s_nope = jnp.einsum("bhr,bsr->bhs", q_abs, c_kv,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], k_rope,
                        preferred_element_type=jnp.float32)
    scale = (a.qk_nope_dim + a.qk_rope_dim) ** -0.5
    s = (s_nope + s_rope) * scale
    S = c_kv.shape[1]
    ok = jnp.arange(S)[None, :] <= pos
    s = jnp.where(ok[:, None], s, attn_lib.NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    # attend in latent space, then absorb W_uv
    out_c = jnp.einsum("bhs,bsr->bhr", pattn.astype(cd), c_kv)
    out = jnp.einsum("bhr,rhk->bhk", out_c, p["w_uv"].astype(cd))
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(cd))[:, None]
    return y, {"c_kv": c_kv, "k_rope": k_rope}
