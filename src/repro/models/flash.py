"""Flash attention with a custom VJP (pure jnp; the memory-correct path).

Why custom VJP: autodiff through the online-softmax scan saves per-chunk
residuals — the *full* S^2 score tensor materializes in the backward pass,
defeating the point of chunking.  The FlashAttention-2 backward recomputes
each (q-chunk x kv-chunk) tile from (q, k, v, o, lse):

    Dsum_i = rowsum(do_i * o_i)
    p_ij  = exp(q_i k_j^T * scale + bias - lse_i)
    dv_j += p_ij^T do_i
    ds_ij = p_ij * (do_i v_j^T - Dsum_i) * scale
    dq_i += ds_ij k_j ;  dk_j += ds_ij^T q_i

so the working set stays O(chunk_q x chunk_k) in both directions.  This is
also the oracle for the Pallas TPU kernel (repro/kernels/flash_attention).

Supports GQA broadcast, causal, sliding window, q_offset, distinct qk/v
head dims.  (Soft-capping falls back to the autodiff path — no assigned
arch uses it.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _bias(qpos, kpos, causal, window, kv_len):
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        ok &= kpos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _pad_seq(x, mult):
    S = x.shape[1]
    pad = (-S) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, cq, ck, scale):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, cq, ck,
                             scale)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, cq, ck, scale):
    """Returns (out [B,Sq,H,Dv], lse [B,KV,G,Sq])."""
    B, Sq, H, Dq = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    qp = _pad_seq(q, cq)
    kp, vp = _pad_seq(k, ck), _pad_seq(v, ck)
    qc = qp.reshape(B, nq, cq, KV, G, Dq).transpose(1, 0, 2, 3, 4, 5)
    kc = kp.reshape(B, nk, ck, KV, Dq).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nk, ck, KV, Dv).transpose(1, 0, 2, 3, 4)

    def q_chunk(carry, qi_q):
        qi, qblk = qi_q
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def kv_chunk(inner, ki_kv):
            m, l, acc = inner
            ki, kblk, vblk = ki_kv
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _bias(qpos, kpos, causal, window,
                          jnp.asarray(Sk))[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_chunk, (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return carry, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_chunk, None, (jnp.arange(nq), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * cq, H, Dv)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, nq * cq)
    return out[:, :Sq].astype(v.dtype), lse[..., :Sq]


def _flash_fwd(q, k, v, causal, window, q_offset, cq, ck, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, cq, ck,
                               scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, cq, ck, scale, res, do):
    q, k, v, out, lse = res
    B, Sq, H, Dq = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    nq, nk = -(-Sq // cq), -(-Sk // ck)

    qp = _pad_seq(q, cq)
    kp, vp = _pad_seq(k, ck), _pad_seq(v, ck)
    dop = _pad_seq(do, cq)
    outp = _pad_seq(out, cq)
    qc = qp.reshape(B, nq, cq, KV, G, Dq).transpose(1, 0, 2, 3, 4, 5)
    kc = kp.reshape(B, nk, ck, KV, Dq).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nk, ck, KV, Dv).transpose(1, 0, 2, 3, 4)
    doc = dop.reshape(B, nq, cq, KV, G, Dv).transpose(1, 0, 2, 3, 4, 5)
    # Dsum_i = rowsum(do * o): [nq, B, KV, G, cq]
    dsum = jnp.einsum("bshd,bshd->bsh", dop.astype(jnp.float32),
                      outp.astype(jnp.float32))
    dsum = dsum.reshape(B, nq, cq, KV, G).transpose(1, 0, 3, 4, 2)
    lsep = jnp.pad(lse, ((0, 0),) * 3 + ((0, nq * cq - Sq),))
    lsec = lsep.reshape(B, KV, G, nq, cq).transpose(3, 0, 1, 2, 4)

    def kv_chunk(dq_acc, ki_kv):
        ki, kblk, vblk = ki_kv
        kpos = ki * ck + jnp.arange(ck)

        def q_chunk(inner, args):
            dk, dv = inner
            qi, qblk, doblk, ds_i, lse_i = args
            qpos = q_offset + qi * cq + jnp.arange(cq)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _bias(qpos, kpos, causal, window,
                          jnp.asarray(Sk))[None, None, None]
            p = jnp.exp(s - lse_i[..., None])           # [B,KV,G,cq,ck]
            dv = dv + jnp.einsum("bkgqc,bqkgd->bckd",
                                 p, doblk.astype(jnp.float32))
            dp = jnp.einsum("bqkgd,bckd->bkgqc",
                            doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - ds_i[..., None]) * scale
            dk = dk + jnp.einsum("bkgqc,bqkgd->bckd", ds,
                                 qblk.astype(jnp.float32))
            dq_c = jnp.einsum("bkgqc,bckd->bqkgd", ds,
                              kblk.astype(jnp.float32))
            return (dk, dv), dq_c

        dk0 = jnp.zeros((B, ck, KV, Dq), jnp.float32)
        dv0 = jnp.zeros((B, ck, KV, Dv), jnp.float32)
        (dk, dv), dq_cs = jax.lax.scan(
            q_chunk, (dk0, dv0),
            (jnp.arange(nq), qc, doc, dsum, lsec))
        dq_acc = dq_acc + dq_cs
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((nq, B, cq, KV, G, Dq), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_chunk, dq0,
                                  (jnp.arange(nk), kc, vc))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * cq, KV * G, Dq)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nk * ck, KV, Dq)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nk * ck, KV, Dv)
    return (dq[:, :Sq].astype(q.dtype), dk[:, :Sk].astype(k.dtype),
            dv[:, :Sk].astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------- pallas backend
# Same custom-VJP pairing with the Pallas TPU kernel as the forward: the
# kernel emits (out, lse) in one fused pass, and the backward REUSES the
# chunked jnp ``_flash_bwd`` above (oracle-identical gradients by
# construction — ``tests/test_flash_vjp.py`` covers that backward).  The
# kernel's block-position arithmetic hard-codes ``q_offset = Sk - Sq``
# (0 for training/prefill), so :func:`flash_attention` only routes here
# when that holds — anything else falls back to the jnp path.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_pallas(q, k, v, causal, window, q_offset, cq, ck, scale):
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    return flash_attention_fwd(q, k, v, causal, window, scale,
                               cq, ck, None, False)


def _flash_pallas_fwd(q, k, v, causal, window, q_offset, cq, ck, scale):
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    out, lse = flash_attention_fwd(q, k, v, causal, window, scale,
                                   cq, ck, None, True)
    return out, (q, k, v, out, lse)


_flash_pallas.defvjp(_flash_pallas_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, chunk_q=512, chunk_k=1024,
                    scale: Optional[float] = None, impl: str = "jnp"):
    """Drop-in for chunked_attention with a memory-correct backward.

    ``impl="pallas"`` (``cfg.kernels``) runs the fused Pallas forward
    kernel with the same chunked backward; it requires ``q_offset ==
    Sk - Sq`` (the kernel's implicit alignment) and no soft-capping —
    other calls silently use the jnp path, so decode/softcap callers
    need no special-casing.
    """
    from repro.models.probe import probe_enabled
    B, Sq, H, Dq = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else Dq ** -0.5
    if probe_enabled():
        chunk_q, chunk_k = Sq, Sk
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    if softcap > 0.0:
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_offset=q_offset,
                                 chunk_q=cq, chunk_k=ck, scale=scale)
    if impl == "pallas" and q_offset == Sk - Sq:
        return _flash_pallas(q, k, v, causal, window, q_offset, cq, ck,
                             scale)
    return _flash(q, k, v, causal, window, q_offset, cq, ck, scale)
