"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# M-RoPE splits the rotary half-dim into (temporal, height, width) sections.
MROPE_SECTIONS = (16, 24, 24)


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions [..., S] -> angles [..., S, dim//2]."""
    f = rope_freqs(dim, theta)
    return positions[..., None].astype(jnp.float32) * f


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    # x [..., D]; rotate interleaved-as-halves (llama convention).
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, S, H, D], positions [S] or [B, S]."""
    ang = rope_angles(positions, x.shape[-1], theta)     # [.., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if cos.ndim == 2:                                    # [S, D/2]
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:                                                # [B, S, D/2]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return _rotate(x, cos, sin)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=None) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x [B, S, H, D]; positions [3, B, S] (t/h/w streams, produced by the
    vision-frontend stub; text tokens carry identical t=h=w positions).
    Each section of the rotary half-dim uses its own position stream.
    """
    d2 = x.shape[-1] // 2
    if sections is None:
        if d2 == sum(MROPE_SECTIONS):
            sections = MROPE_SECTIONS          # qwen2-vl hd=128 split
        else:                                   # keep the 1/4:3/8:3/8 ratio
            t = d2 // 4
            h = (d2 - t) // 2
            sections = (t, h, d2 - t - h)
    assert sum(sections) == d2, (sections, d2)
    f = rope_freqs(x.shape[-1], theta)                   # [D/2]
    # angles per stream: [3, B, S, D/2]
    ang = positions[..., None].astype(jnp.float32) * f
    parts, start = [], 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                # [B, S, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def default_mrope_positions(batch: int, seq: int, offset=0) -> jax.Array:
    """Text-only fallback: all three streams share sequential positions."""
    p = jnp.broadcast_to(offset + jnp.arange(seq), (batch, seq))
    return jnp.broadcast_to(p[None], (3, batch, seq))
